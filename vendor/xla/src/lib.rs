//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The offline build environment has neither crates.io access nor a PJRT
//! shared library, so this crate supplies the exact API surface the
//! `flashattn::runtime` module compiles against:
//!
//! * **Host side is real**: [`Literal`] stores typed, shaped host data and
//!   supports `vec1`/`reshape`/`to_vec`/`shape`/`to_tuple`, so
//!   `Value <-> Literal` round-trips (and their tests/benches) work.
//! * **Device side degrades loudly**: [`PjRtClient::cpu`] returns an error
//!   explaining that PJRT execution is unavailable. Every caller in the
//!   workspace already handles a missing runtime (integration tests skip,
//!   benches print a notice), so `cargo test` passes without artifacts.
//!
//! Swapping in the real bindings is a Cargo.toml change only.

use std::borrow::Borrow;
use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT is unavailable in this build (stub `xla` crate vendored for the \
     offline environment) — artifact execution requires the real xla bindings";

/// Element dtypes the manifest can mention (F32/S32 are the ones used).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Shape of a dense array literal: dtype + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Array or tuple shape, as PJRT reports for execution results.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Literal element storage. Public so `NativeType` can mention it in its
/// method signatures, but not part of the supported API surface.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(storage: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(data: Vec<f32>) -> Storage {
        Storage::F32(data)
    }

    fn unwrap(storage: &Storage) -> Option<Vec<f32>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(data: Vec<i32>) -> Storage {
        Storage::I32(data)
    }

    fn unwrap(storage: &Storage) -> Option<Vec<i32>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A typed, shaped host tensor (or tuple of them).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    storage: Storage,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            dims: vec![data.len() as i64],
            storage: T::wrap(data.to_vec()),
        }
    }

    /// Tuple literal (execution results are 1-tuples of outputs).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::Pred, dims: vec![], storage: Storage::Tuple(parts) }
    }

    /// Reinterpret the element buffer under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        let have = self.element_count();
        if count as usize != have {
            return Err(Error::new(format!(
                "reshape to {dims:?} ({count} elems) from {have} elems"
            )));
        }
        Ok(Literal { ty: self.ty, dims: dims.to_vec(), storage: self.storage.clone() })
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(_) => 0,
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.storage {
            Storage::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
            _ => Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() }),
        }
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.storage {
            Storage::Tuple(parts) => Ok(Shape::Tuple(
                parts.iter().map(|p| p.shape()).collect::<Result<Vec<_>>>()?,
            )),
            _ => Ok(Shape::Array(ArrayShape { ty: self.ty, dims: self.dims.clone() })),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .ok_or_else(|| Error::new(format!("literal is {:?}, asked for {:?}", self.ty, T::TY)))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(parts) => Ok(parts),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module text (the stub stores the text verbatim).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client handle. The stub cannot create one: `cpu()` errors.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn tuple_shape_and_destructure() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        assert!(matches!(t.shape().unwrap(), Shape::Tuple(ref parts) if parts.len() == 2));
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn client_unavailable_is_loud() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT is unavailable"), "{e}");
    }
}
