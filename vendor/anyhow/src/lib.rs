//! Vendored minimal `anyhow`: the subset of the real crate's API this
//! workspace uses (`Error`, `Result`, `Context`, `anyhow!`, `bail!`,
//! `ensure!`), re-implemented over a plain message string because the
//! offline crate universe has no crates.io access.
//!
//! Semantics mirror anyhow where it matters to callers:
//! * `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion (what makes `?` work
//!   on io/parse/xla errors inside `fn ... -> anyhow::Result<T>`) does not
//!   overlap with the reflexive `From<T> for T`.
//! * `.context(..)` / `.with_context(..)` prepend "context: cause" the way
//!   anyhow's `{:#}` alternate formatting renders an error chain.

use std::fmt;

/// A type-erased error: a rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>` — `Err` defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, exactly like anyhow's.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("parsing int")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("parsing int:"), "{e}");
        assert_eq!(parse("-3").unwrap_err().to_string(), "negative: -3");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn nested_context_chains() {
        let base: Result<()> = Err(anyhow!("root cause {}", 42));
        let e = base.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root cause 42");
        assert_eq!(format!("{e:#}"), "outer: root cause 42");
    }
}
