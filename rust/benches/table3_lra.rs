//! Table 3: Long-Range-Arena accuracy + speedup. The paper shows flash and
//! block-sparse flash matching the vanilla Transformer's accuracy (they are
//! exact / near-exact) while training 2.4x / 2.8x faster; approximate
//! methods trade accuracy.
//!
//! Accuracy: REAL training runs of all six attention variants on the three
//! synthetic LRA-style tasks through the PJRT artifacts.
//! Speedup: the calibrated attention model at LRA shape (seq 1K-4K), geo-
//! meaned as in App. E.3.

use std::path::Path;

use flashattn::bench::{geomean, out_dir};
use flashattn::coordinator::tasks::{chance_accuracy, lra_tasks, run_task};
use flashattn::runtime::Runtime;
use flashattn::sim::baselines::Method;
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::table::Table;

fn sim_speedup(m: Method) -> String {
    // LRA tasks span seq 1K-4K; geometric mean of per-length speedups.
    let rl = Roofline::a100();
    let cfg = BenchConfig::default();
    let sps: Vec<f64> = [1024u64, 2048, 4096]
        .iter()
        .filter_map(|&n| rl.speedup_vs_standard(m, Pass::FwdBwd, n, &cfg))
        .collect();
    if sps.is_empty() {
        "-".into()
    } else {
        format!("{:.1}x", geomean(&sps))
    }
}

fn main() {
    let steps: usize =
        std::env::var("FLASHATTN_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(30);
    let models = [
        ("cls_reference", "Transformer (reference)", Some(Method::PyTorch)),
        ("cls_flash", "FlashAttention", Some(Method::FlashAttention)),
        ("cls_block_sparse", "Block-sparse FlashAttention", Some(Method::BlockSparseFlash)),
        ("cls_local", "Local Attention", Some(Method::LocalAttention)),
        ("cls_linformer", "Linformer", Some(Method::Linformer)),
        ("cls_linear", "Linear Attention", None),
    ];

    let mut rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("table3 requires artifacts: {e:#}");
            return;
        }
    };

    let n_ctx = rt.manifest.model("cls_flash").unwrap().cfg_usize("n_ctx").unwrap_or(128);
    let datasets = lra_tasks(n_ctx);
    let mut headers = vec!["Models".to_string()];
    headers.extend(datasets.iter().map(|d| d.name().to_string()));
    headers.push("Avg".into());
    headers.push("Speedup (model)".into());
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!("Table 3 — LRA-style accuracy ({} steps/task) + modelled speedup", steps),
        &hrefs,
    );

    let exec = flashattn::attn::Exec::new(4);
    for (tag, label, method) in models {
        let mut row = vec![label.to_string()];
        let mut accs = Vec::new();
        for ds in &datasets {
            match run_task(&mut rt, tag, ds.as_ref(), steps, 3, &exec) {
                Ok(res) => {
                    accs.push(res.accuracy);
                    row.push(format!("{:.3}", res.accuracy));
                }
                Err(e) => {
                    println!("  ({tag} on {}: {e:#})", ds.name());
                    row.push("err".into());
                }
            }
        }
        let avg =
            if accs.is_empty() { f64::NAN } else { accs.iter().sum::<f64>() / accs.len() as f64 };
        row.push(format!("{avg:.3}"));
        row.push(method.map(sim_speedup).unwrap_or_else(|| "2.3x*".into()));
        t.row(row);
    }
    t.print();
    t.write_csv(&out_dir().join("table3.csv")).unwrap();

    for ds in &datasets {
        println!("chance accuracy on {}: {:.3}", ds.name(), chance_accuracy(ds.as_ref()));
    }
    println!(
        "(paper Table 3: flash 59.8 avg vs Transformer 59.3 — exactness preserves accuracy; \
         2.4x/2.8x speedups. *Linear Attention speedup taken from the paper's 2.3x.)"
    );
}
