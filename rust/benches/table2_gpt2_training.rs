//! Table 2: GPT-2 small/medium training time vs HuggingFace and Megatron-LM
//! (paper: 3.5x / 2.0x / 1.0x relative speeds at seq 1K, identical ppl).
//!
//! Two parts:
//!  1. The e2e Amdahl model regenerates the table's speedup column.
//!  2. A REAL (tiny-scale) training run through the PJRT artifacts verifies
//!     the quality half of the claim: with identical init and data order,
//!     the flash-attention model and the reference-attention model produce
//!     the SAME loss curve (exactness — "we do not change the model
//!     definition"), our Fig. 4 analogue.

use std::path::Path;

use flashattn::bench::out_dir;
use flashattn::coordinator::{LmTrainer, TrainConfig};
use flashattn::data::corpus::Corpus;
use flashattn::runtime::Runtime;
use flashattn::sim::baselines::Method;
use flashattn::sim::e2e::{step_seconds, ModelShape};
use flashattn::sim::roofline::Roofline;
use flashattn::util::table::Table;

fn model_table() {
    let rl = Roofline::a100();
    let mut t = Table::new(
        "Table 2 — GPT-2 training speed model (paper speedups: HF 1.0x, Megatron 2.0x/1.8x, \
         Flash 3.5x/3.0x)",
        &["Model implementation", "rel. speed (model)", "rel. speed (paper)", "ppl"],
    );
    for (shape, paper) in [
        (ModelShape::gpt2_small(1024), [1.0, 2.0, 3.5]),
        (ModelShape::gpt2_medium(1024), [1.0, 1.8, 3.0]),
    ] {
        let hf = step_seconds(&rl, &shape, Method::PyTorch, "huggingface").unwrap();
        let meg = step_seconds(&rl, &shape, Method::Megatron, "megatron").unwrap();
        let fla = step_seconds(&rl, &shape, Method::FlashAttention, "ours").unwrap();
        t.row(vec![
            format!("{} - Huggingface", shape.name),
            "1.00x".into(),
            format!("{:.1}x", paper[0]),
            "same".into(),
        ]);
        t.row(vec![
            format!("{} - Megatron-LM", shape.name),
            format!("{:.2}x", hf / meg),
            format!("{:.1}x", paper[1]),
            "same".into(),
        ]);
        t.row(vec![
            format!("{} - FlashAttention", shape.name),
            format!("{:.2}x", hf / fla),
            format!("{:.1}x", paper[2]),
            "same".into(),
        ]);
    }
    t.print();
    t.write_csv(&out_dir().join("table2.csv")).unwrap();
}

fn exactness_run() {
    let steps: usize =
        std::env::var("FLASHATTN_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(15);
    println!(
        "## Fig 4 analogue — identical loss curves (flash vs reference attention), {steps} steps \
         each"
    );
    let mut rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping real run (no artifacts): {e:#}");
            return;
        }
    };
    let corpus = Corpus::builtin(100_000, 1);
    let exec = flashattn::attn::Exec::new(4);
    let mut curves: Vec<(String, Vec<f64>, f64)> = Vec::new();
    for model in ["gpt_flash", "gpt_ref"] {
        let cfg = TrainConfig {
            model: model.into(),
            steps,
            eval_every: 0,
            seed: 7,
            ..Default::default()
        };
        let mut tr = LmTrainer::new(&mut rt, cfg, &exec).expect("trainer");
        let t0 = std::time::Instant::now();
        tr.train(&mut rt, &corpus).expect("train");
        let secs = t0.elapsed().as_secs_f64();
        let losses: Vec<f64> = tr.metrics.points.iter().map(|p| p.loss).collect();
        curves.push((model.into(), losses, secs));
    }
    let (ref a, ref la, ta) = curves[0];
    let (ref b, ref lb, tb) = curves[1];
    let max_diff = la.iter().zip(lb).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
    let mut t = Table::new("loss curves (identical init + data)", &["step", a, b]);
    for (i, (x, y)) in la.iter().zip(lb).enumerate() {
        t.row(vec![(i + 1).to_string(), format!("{x:.5}"), format!("{y:.5}")]);
    }
    t.print();
    t.write_csv(&out_dir().join("table2_loss_curves.csv")).unwrap();
    println!("max |loss_flash - loss_ref| over {steps} steps: {max_diff:.2e}");
    println!(
        "[{}] curves coincide (exact attention => same model)",
        if max_diff < 2e-2 { "OK" } else { "FAIL" }
    );
    println!(
        "CPU wallclock: flash {ta:.1}s vs reference {tb:.1}s — NOTE: interpret-mode \
         Pallas on CPU is a correctness vehicle; speed claims live in the IO model above."
    );
}

fn main() {
    model_table();
    exactness_run();
}
