//! Table 5: long-document classification — accuracy rises with sequence
//! length because evidence is spread over the whole document (MIMIC-III /
//! ECtHR in the paper; our synthetic LongDoc generator, DESIGN.md §4).
//!
//! REAL training runs of the longdoc_ctx{64,128,256,512} flash artifacts;
//! every run sees documents of native length 512 truncated to its context.

use std::path::Path;

use flashattn::bench::out_dir;
use flashattn::coordinator::tasks::run_task;
use flashattn::data::longdoc::{expected_evidence_fraction, LongDoc};
use flashattn::runtime::Runtime;
use flashattn::util::table::Table;

fn main() {
    let steps: usize =
        std::env::var("FLASHATTN_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(30);
    let mut rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("table5 requires artifacts: {e:#}");
            return;
        }
    };
    let ds = LongDoc { doc_len: 512, n_evidence: 8 };
    let mut t = Table::new(
        &format!(
            "Table 5 — LongDoc accuracy vs context ({steps} steps; paper: F1 rises 52.8 -> 57.1 \
             on MIMIC)"
        ),
        &["context", "evidence visible", "accuracy", "chance"],
    );
    let mut accs = Vec::new();
    let exec = flashattn::attn::Exec::new(4);
    for (tag, ctx) in [
        ("longdoc_ctx64", 64usize),
        ("longdoc_ctx128", 128),
        ("longdoc_ctx256", 256),
        ("longdoc_ctx512", 512),
    ] {
        match run_task(&mut rt, tag, &ds, steps, 13, &exec) {
            Ok(res) => {
                accs.push(res.accuracy);
                t.row(vec![
                    ctx.to_string(),
                    format!("{:.0}%", expected_evidence_fraction(512, ctx) * 100.0),
                    format!("{:.3}", res.accuracy),
                    "0.100".into(),
                ]);
            }
            Err(e) => println!("({tag}: {e:#})"),
        }
    }
    t.print();
    t.write_csv(&out_dir().join("table5.csv")).unwrap();
    if accs.len() >= 2 {
        let ok = accs.last().unwrap() >= accs.first().unwrap();
        println!(
            "[{}] accuracy non-decreasing with context ({:.3} -> {:.3})",
            if ok { "OK" } else { "FAIL" },
            accs[0],
            accs[accs.len() - 1]
        );
    }
    println!("note: the full-context model can in principle reach 100%; truncated models are
information-bounded (e.g. 64/512 ctx sees only ~12% of the evidence).");
}
