//! Table 6: Path-X / Path-256 — the first Transformers to beat chance on
//! extreme-length pathfinder, *because* flash attention fits in memory
//! where standard attention OOMs.
//!
//! Two halves:
//!  1. Feasibility (the paper's actual mechanism): the memory model shows
//!     standard attention OOMs at Path-X scale (16K) on an A100-40GB while
//!     flash fits — that is WHY only flash could attempt the task.
//!  2. Quality at our scale: REAL runs of the flash classifier on the
//!     Pathfinder task at growing grid sizes (sequence 128 -> 512),
//!     checking better-than-chance accuracy.

use std::path::Path;

use flashattn::bench::{ms_cell, out_dir};
use flashattn::coordinator::tasks::run_task;
use flashattn::data::pathfinder::Pathfinder;
use flashattn::runtime::Runtime;
use flashattn::sim::baselines::{Method, SWEEP_METHODS};
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::table::Table;

fn feasibility() {
    let rl = Roofline::a100();
    let cfg = BenchConfig { batch: 8, heads: 8, ..Default::default() };
    let mut t = Table::new(
        "Table 6a — who can even run Path-X (16K) / Path-256 (64K)? (A100-40GB memory model)",
        &["method", "mem @16K (MB)", "runs 16K?", "mem @64K (MB)", "runs 64K?"],
    );
    for m in [
        Method::PyTorch,
        Method::Reformer,
        Method::Linformer,
        Method::LocalAttention,
        Method::FlashAttention,
        Method::BlockSparseFlash,
    ] {
        let m16 = rl.mem_mb(m, 16384, &cfg);
        let m64 = rl.mem_mb(m, 65536, &cfg);
        let runs16 = rl.time_ms(m, Pass::FwdBwd, 16384, &cfg).is_some();
        let runs64 = rl.time_ms(m, Pass::FwdBwd, 65536, &cfg).is_some();
        t.row(vec![
            m.name().into(),
            ms_cell(m16),
            if runs16 { "yes" } else { "OOM/cap" }.into(),
            ms_cell(m64),
            if runs64 { "yes" } else { "OOM/cap" }.into(),
        ]);
    }
    t.print();
    t.write_csv(&out_dir().join("table6_feasibility.csv")).unwrap();
    let std_oom = rl.time_ms(Method::PyTorch, Pass::FwdBwd, 16384, &cfg).is_none();
    let flash_ok = rl.time_ms(Method::FlashAttention, Pass::FwdBwd, 16384, &cfg).is_some();
    let bs_ok_64 = rl.time_ms(Method::BlockSparseFlash, Pass::FwdBwd, 65536, &cfg).is_some();
    println!(
        "[{}] standard OOMs at Path-X scale; flash fits; block-sparse flash fits Path-256",
        if std_oom && flash_ok && bs_ok_64 { "OK" } else { "FAIL" }
    );
    let _ = SWEEP_METHODS; // full grid available via tables9_21 bench
}

fn quality() {
    let steps: usize =
        std::env::var("FLASHATTN_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(40);
    println!(
        "## Table 6b — pathfinder accuracy at growing sequence length (real runs, {steps} steps)"
    );
    let mut rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping real runs: {e:#}");
            return;
        }
    };
    let mut t = Table::new(
        "Pathfinder (flash classifier): accuracy vs chance 0.5 (paper: Path-X 61.4%, Path-256 \
         63.1%)",
        &["sequence", "grid", "accuracy", "beats chance?"],
    );
    let exec = flashattn::attn::Exec::new(4);
    for (tag, seq) in
        [("longdoc_ctx128", 128usize), ("longdoc_ctx256", 256), ("longdoc_ctx512", 512)]
    {
        let ds = Pathfinder::for_seq(seq);
        match run_task(&mut rt, tag, &ds, steps, 21, &exec) {
            Ok(res) => {
                t.row(vec![
                    seq.to_string(),
                    format!("{0}x{0}", ds.side),
                    format!("{:.3}", res.accuracy),
                    if res.accuracy > 0.55 { "yes" } else { "marginal" }.into(),
                ]);
            }
            Err(e) => println!("({tag}: {e:#})"),
        }
    }
    t.print();
    t.write_csv(&out_dir().join("table6_quality.csv")).unwrap();
}

fn main() {
    feasibility();
    quality();
}
