//! Table 1: BERT-large MLPerf training time — the paper reports 20.0 min
//! (Nvidia MLPerf 1.1) vs 17.4 min (FlashAttention), a 15% end-to-end gain
//! at seq length 512.
//!
//! Reproduction: the Amdahl end-to-end model (sim::e2e) at BERT-large shape
//! gives the expected step-time ratio; applied to the MLPerf baseline time
//! it regenerates the table. A real (tiny-scale) training run demonstrating
//! the identical-loss property lives in table2_gpt2_training.rs.

use flashattn::bench::out_dir;
use flashattn::sim::baselines::Method;
use flashattn::sim::e2e::{attention_share, e2e_speedup, ModelShape};
use flashattn::sim::roofline::Roofline;
use flashattn::util::table::Table;

fn main() {
    let rl = Roofline::a100();
    let shape = ModelShape::bert_large(512);
    // Nvidia's MLPerf submission uses Apex FMHA, not naive PyTorch — the
    // relevant baseline for the 15% claim.
    let speedup = e2e_speedup(&rl, &shape, Method::ApexFmha, "ours").unwrap();
    let share = attention_share(&rl, &shape, Method::ApexFmha).unwrap();
    let paper_baseline_min = 20.0;
    let model_flash_min = paper_baseline_min / speedup;

    let mut t = Table::new(
        "Table 1 — BERT-large to 72.0% MLM accuracy, 8xA100 (paper: 20.0 vs 17.4 min)",
        &["BERT implementation", "training time (min)", "source"],
    );
    t.row(vec![
        "Nvidia MLPerf 1.1 (FMHA)".into(),
        format!("{paper_baseline_min:.1}"),
        "paper".into(),
    ]);
    t.row(vec![
        "FlashAttention (model)".into(),
        format!("{model_flash_min:.1}"),
        format!("e2e model: {speedup:.3}x speedup"),
    ]);
    t.row(vec!["FlashAttention (paper)".into(), "17.4".into(), "paper".into()]);
    t.print();
    t.write_csv(&out_dir().join("table1.csv")).unwrap();

    println!(
        "attention share of FMHA-baseline step at seq 512: {:.1}% -> end-to-end gain {:.1}% \
         (paper: 15%)",
        share * 100.0,
        (speedup - 1.0) * 100.0
    );
    let ok = (1.0..1.35).contains(&speedup);
    println!(
        "[{}] flash does not lose end-to-end; gain <= the paper's 15%",
        if ok { "OK" } else { "FAIL" }
    );
    println!(
        "documented deviation (EXPERIMENTS.md): at N=512 attention is only ~{:.0}% of a BERT\n\
         step, so a pure attention-swap model caps the gain near {:.0}%; the paper's full 15%\n\
         also includes their non-attention fusions on top of the MLPerf baseline.",
        share * 100.0,
        share * 100.0 * 0.5
    );
}
