//! Figure 3: runtime (left) and memory footprint (right) of FlashAttention
//! and block-sparse FlashAttention vs exact/approximate/sparse baselines,
//! sweeping sequence length 128 → 64K.
//!
//! Shape claims checked: flash up to 3x faster than PyTorch at common
//! lengths; approximate methods cross over between 512 and 2K; block-sparse
//! flash fastest everywhere; memory linear in N and up to 20x smaller than
//! exact baselines; everything except Linformer and the flash variants OOMs
//! before 64K on a 40GB card.

use flashattn::bench::{ms_cell, out_dir};
use flashattn::sim::baselines::{Method, SWEEP_METHODS};
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::table::Table;

const NS: [u64; 10] = [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536];

fn main() {
    let rl = Roofline::a100();
    let cfg = BenchConfig::default();

    // Left: fwd+bwd runtime.
    let mut headers: Vec<String> = vec!["method".into()];
    headers.extend(NS.iter().map(|n| n.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new("Fig 3 left — fwd+bwd runtime (ms), A100-40GB model", &hrefs);
    for m in SWEEP_METHODS {
        let mut row = vec![m.name().to_string()];
        for &n in &NS {
            row.push(ms_cell(rl.time_ms(*m, Pass::FwdBwd, n, &cfg)));
        }
        t.row(row);
    }
    t.print();
    t.write_csv(&out_dir().join("fig3_runtime.csv")).unwrap();

    // Right: memory footprint.
    let mut t = Table::new("Fig 3 right — attention memory (MB)", &hrefs);
    for m in SWEEP_METHODS {
        let mut row = vec![m.name().to_string()];
        for &n in &NS {
            row.push(ms_cell(rl.mem_mb(*m, n, &cfg)));
        }
        t.row(row);
    }
    t.print();
    t.write_csv(&out_dir().join("fig3_memory.csv")).unwrap();

    // Claim checklist.
    let check = |name: &str, ok: bool| println!("  [{}] {}", if ok { "OK" } else { "FAIL" }, name);
    println!("shape checks:");
    let sp1k = rl.speedup_vs_standard(Method::FlashAttention, Pass::FwdBwd, 1024, &cfg).unwrap();
    check(&format!("flash faster than PyTorch at 1K ({sp1k:.2}x)"), sp1k > 1.4);
    let f = |m: Method, n: u64| rl.time_ms(m, Pass::FwdBwd, n, &cfg);
    check(
        "flash beats Linformer at 256",
        f(Method::FlashAttention, 256) < f(Method::Linformer, 256),
    );
    check(
        "Linformer beats flash at 8K (crossover happened)",
        f(Method::Linformer, 8192) < f(Method::FlashAttention, 8192),
    );
    let bs_fastest_64k = SWEEP_METHODS.iter().all(|m| {
        f(*m, 65536).map(|t| t * 1.2 >= f(Method::BlockSparseFlash, 65536).unwrap()).unwrap_or(true)
    });
    check("block-sparse flash fastest at 64K", bs_fastest_64k);
    let mem_ratio = rl.mem_mb(Method::PyTorch, 4096, &cfg).unwrap()
        / rl.mem_mb(Method::FlashAttention, 4096, &cfg).unwrap();
    check(
        &format!("memory saving vs exact at 4K ({mem_ratio:.0}x, paper: up to 20x)"),
        mem_ratio > 10.0,
    );
    let survivors: Vec<&str> = SWEEP_METHODS
        .iter()
        .filter(|m| f(**m, 65536).is_some())
        .map(|m| m.name())
        .collect();
    println!("  survivors at 64K: {survivors:?} (paper: Linformer + flash variants)");
}
