//! Figure 1 (right) + Figures 5–8: FlashAttention speedup over the PyTorch
//! standard implementation, across sequence lengths, devices (A100 /
//! RTX3090 / T4), head dims (64 / 128) and mask/dropout combinations.
//!
//! Paper claims reproduced: 7.6x peak attention speedup on GPT-2 shapes
//! (Fig. 1), 2-4x typical (Fig. 5), smaller speedups on T4 (Fig. 8,
//! smaller SRAM), larger speedup with dropout+mask (kernel fusion).

use flashattn::bench::{ms_cell, out_dir};
use flashattn::sim::baselines::Method;
use flashattn::sim::device::GpuSpec;
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::table::Table;

fn speedup_table(spec: GpuSpec, d: u64, cfg0: BenchConfig, pass: Pass, tag: &str) -> Table {
    let rl = Roofline::new(spec);
    let mut t = Table::new(
        &format!("Speedup over PyTorch attention — {} d={} {:?} {}", rl.spec.name, d, pass, tag),
        &["seq len", "PyTorch (ms)", "Flash (ms)", "speedup"],
    );
    for n in [128u64, 256, 512, 1024, 2048, 4096] {
        let cfg = BenchConfig { d, ..cfg0 };
        let py = rl.time_ms(Method::PyTorch, pass, n, &cfg);
        let fl = rl.time_ms(Method::FlashAttention, pass, n, &cfg);
        let sp = match (py, fl) {
            (Some(p), Some(f)) => format!("{:.2}x", p / f),
            _ => "-".into(),
        };
        t.row(vec![n.to_string(), ms_cell(py), ms_cell(fl), sp]);
    }
    t
}

fn main() {
    println!("=== Fig 1 right: GPT-2 attention speedup (batch 64, 16 heads, d 64) ===\n");
    let gpt2 =
        BenchConfig { batch: 64, heads: 16, dropout: true, masked: true, ..Default::default() };
    let t = speedup_table(GpuSpec::a100_40gb(), 64, gpt2, Pass::FwdBwd, "dropout+mask");
    t.print();
    t.write_csv(&out_dir().join("fig1_gpt2_speedup.csv")).unwrap();

    println!("=== Fig 5: A100, d=64, all mask/dropout combos (fwd+bwd) ===\n");
    for (dropout, masked) in [(false, false), (true, false), (false, true), (true, true)] {
        let cfg = BenchConfig { dropout, masked, ..Default::default() };
        speedup_table(
            GpuSpec::a100_40gb(),
            64,
            cfg,
            Pass::FwdBwd,
            &format!("dropout={dropout} mask={masked}"),
        )
        .print();
    }

    println!("=== Fig 6: A100, head dim 128 ===\n");
    let cfg = BenchConfig { batch: 16, heads: 12, ..Default::default() };
    speedup_table(GpuSpec::a100_40gb(), 128, cfg, Pass::FwdBwd, "d128").print();

    println!("=== Fig 7: RTX 3090 ===\n");
    let cfg = BenchConfig { batch: 12, heads: 12, ..Default::default() };
    speedup_table(GpuSpec::rtx3090(), 64, cfg, Pass::FwdBwd, "").print();

    println!("=== Fig 8: T4 (fwd+bwd and fwd-only) ===\n");
    let cfg = BenchConfig { batch: 12, heads: 12, ..Default::default() };
    speedup_table(GpuSpec::t4(), 64, cfg, Pass::FwdBwd, "").print();
    speedup_table(GpuSpec::t4(), 64, cfg, Pass::Fwd, "inference").print();

    // Shape assertions (who wins, where): printed as a checklist.
    let rl_a100 = Roofline::a100();
    let rl_t4 = Roofline::new(GpuSpec::t4());
    let base = BenchConfig::default();
    let peak: f64 = (7..13)
        .map(|i| {
            rl_a100
                .speedup_vs_standard(
                    Method::FlashAttention,
                    Pass::Fwd,
                    1 << i,
                    &BenchConfig { batch: 64, heads: 16, dropout: true, masked: true, ..base },
                )
                .unwrap_or(0.0)
        })
        .fold(0.0, f64::max);
    println!("peak attention speedup (GPT-2 shapes): {peak:.1}x (paper: up to 7.6x)");
    let s_a100 =
        rl_a100.speedup_vs_standard(Method::FlashAttention, Pass::Fwd, 1024, &base).unwrap();
    let s_t4 = rl_t4.speedup_vs_standard(Method::FlashAttention, Pass::Fwd, 1024, &base).unwrap();
    println!(
        "T4 speedup {s_t4:.2}x <= A100 speedup {s_a100:.2}x (paper Fig. 8: smaller SRAM, less \
         speedup): {}",
        if s_t4 <= s_a100 * 1.05 { "OK" } else { "MISMATCH" }
    );
}
