//! Hot-path microbenchmarks (real wall-clock, this machine) used by the
//! EXPERIMENTS.md §Perf iteration log:
//!
//!  * pure-Rust mirrors: flash_forward vs standard_forward per [n, d] slice
//!    (the instrumented engine behind fig2);
//!  * fast-kernel head-to-head, forward AND backward: flash (faithful
//!    Algorithms 1/4) vs flash2 (Q-outer fwd; two-phase Q-outer dQ +
//!    column-parallel dK/dV bwd) at n ∈ {512, 1K, 4K}, emitting
//!    BENCH_attn.json (mean ns/iter per kernel and pass) so future PRs can
//!    track the perf trajectory;
//!  * batched multi-head scheduler vs the per-slice loop it replaced
//!    (attn::batched, fwd AND bwd): one pool over every slice·block work
//!    item vs one pool spin-up per slice, same worker budget — rows land
//!    in BENCH_attn.json under "batched";
//!  * sharded sequence-parallel driver vs the single-device pair
//!    (attn::distributed ring schedule, fwd AND bwd, bitwise-identical
//!    arithmetic): rows land in BENCH_attn.json under "sharded" and the
//!    gate bounds the scheduling overhead;
//!  * block-sparse vs dense fast pair on the same tiling (butterfly +
//!    local_global §3.3 patterns, fwd AND bwd): rows land under
//!    "sparse" with their density, and the gate fails the build if
//!    block-sparse at ≤50% density ever loses to dense flash2;
//!  * guardrail overhead: a guarded execution handle
//!    (`Exec::new(w).with_plan(&none).validated()`) vs the plain one on
//!    the batched entry points, fwd AND bwd — rows land under
//!    "guardrail" and the gate bounds the fault-free cost of the
//!    execution plane;
//!  * persistent pool vs per-call scope: the same batched workload on
//!    `Exec::new(w)` (workers parked between calls) vs `Exec::scoped(w)`
//!    (spawn + join per call) at small n, fwd AND bwd — rows land under
//!    "pool" and the gate fails if the persistent pool ever loses;
//!  * serving tier: a ContinuousBatcher drains a mixed prefill+decode
//!    wave (paged KV cache, split-KV `flash2_decode`) — rows land under
//!    "serving" as tokens/sec and the gate enforces a throughput floor;
//!  * PJRT artifact execution: flash vs reference attention artifacts, and
//!    the fused train step (the L3 request path);
//!  * Value<->Literal conversion overhead (the coordinator's serialization
//!    cost per step).
//!
//! `BENCH_SMOKE=1` shrinks sizes and iteration counts so CI can run the
//! whole bench cheaply; BENCH_attn.json is still written (flagged
//! `"smoke": true`) and the CI perf-regression gate
//! (python/check_bench.py) parses it and fails on any (pass, n) cell
//! where flash2 lost to flash, or where batched lost to the per-slice
//! loop.

use std::path::Path;
use std::time::Instant;

use flashattn::attn::batched::{flash2_backward_batched, flash2_forward_batched};
use flashattn::attn::block_sparse::{block_sparse2_backward, block_sparse2_forward};
use flashattn::attn::distributed::{flash_backward_sharded, flash_forward_sharded};
use flashattn::attn::faults::FaultPlan;
use flashattn::attn::flash::{flash_backward, flash_forward, Blocks};
use flashattn::attn::flash2::{flash2_backward, flash2_forward};
use flashattn::attn::masks::BlockMask;
use flashattn::attn::standard::standard_forward;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::bench::{mean_time, median_time};
use flashattn::runtime::{Runtime, Value};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;
use flashattn::util::table::Table;

/// Head dim and worker budget shared by both head-to-head sections AND the
/// BENCH_attn.json header — the JSON row keys embed WORKERS
/// ("flash2_w{WORKERS}_ns"), and python/check_bench.py resolves them via
/// the header's "workers" field, so these must stay a single definition.
const D: usize = 64;
const WORKERS: usize = 4;

fn mirrors() {
    let mut t = Table::new(
        "pure-Rust mirrors (per [n,d]=[n,64] slice, median of 5)",
        &["n", "standard (ms)", "flash (ms)", "flash blocks"],
    );
    for n in [128usize, 256, 512, 1024] {
        let mut rng = SplitMix64::new(0);
        let q = Tensor::randn(&[n, 64], &mut rng, 1.0);
        let k = Tensor::randn(&[n, 64], &mut rng, 1.0);
        let v = Tensor::randn(&[n, 64], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::from_sram(48 * 1024, 64, n);
        let ts = median_time(5, || {
            std::hint::black_box(standard_forward(&q, &k, &v, &cfg, &mut Hbm::new()));
        });
        let tf = median_time(5, || {
            std::hint::black_box(flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new()));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", ts * 1e3),
            format!("{:.2}", tf * 1e3),
            format!("({},{})", blocks.b_r, blocks.b_c),
        ]);
    }
    t.print();
}

/// flash vs flash2 head-to-head at d=64, forward and backward — the
/// kernels the production paths route through vs the instrumented
/// references they are tested against. Returns the BENCH_attn.json result
/// rows. The backward comparison runs both kernels on the same
/// Blocks::for_backward square tiling (the regime the two-phase kernel
/// targets; see sim::cost::flash2_bwd) and the same flash2-forward
/// outputs.
fn fast_kernel_head_to_head(smoke: bool) -> Vec<String> {
    let (d, workers) = (D, WORKERS);
    let mut t = Table::new(
        "fast kernel head-to-head (per [n,64] slice, mean ns/iter)",
        &[
            "n",
            "flash fwd (ms)",
            "flash2 fwd w1 (ms)",
            "flash2 fwd w4 (ms)",
            "flash bwd (ms)",
            "flash2 bwd w1 (ms)",
            "flash2 bwd w4 (ms)",
        ],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let sizes: &[usize] = if smoke { &[128, 256] } else { &[512, 1024, 4096] };
    for &n in sizes {
        let mut rng = SplitMix64::new(1);
        let q = Tensor::randn(&[n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[n, d], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::from_sram(48 * 1024, d, n);
        let bwd_blocks = Blocks::for_backward(48 * 1024, d);
        let iters = if smoke { 5 } else if n >= 4096 { 2 } else { 5 };
        let (ex1, exw) = (Exec::scoped(1), Exec::scoped(workers));
        let t_flash = mean_time(iters, || {
            std::hint::black_box(flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new()));
        });
        let t_f2_w1 = mean_time(iters, || {
            std::hint::black_box(flash2_forward(&q, &k, &v, &cfg, blocks, &ex1, &mut Hbm::new()));
        });
        let t_f2_w4 = mean_time(iters, || {
            std::hint::black_box(flash2_forward(
                &q, &k, &v, &cfg, blocks, &exw, &mut Hbm::new(),
            ));
        });
        // Backward: both kernels consume the same forward outputs.
        let fwd = flash2_forward(&q, &k, &v, &cfg, bwd_blocks, &exw, &mut Hbm::new());
        let bwd_iters = if smoke { 5 } else if n >= 4096 { 1 } else { 3 };
        let t_bwd_flash = mean_time(bwd_iters, || {
            std::hint::black_box(flash_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, bwd_blocks, &mut Hbm::new(),
            ));
        });
        let t_bwd_f2_w1 = mean_time(bwd_iters, || {
            std::hint::black_box(flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, bwd_blocks, &ex1, &mut Hbm::new(),
            ));
        });
        let t_bwd_f2_w4 = mean_time(bwd_iters, || {
            std::hint::black_box(flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, bwd_blocks, &exw, &mut Hbm::new(),
            ));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_flash * 1e3),
            format!("{:.2}", t_f2_w1 * 1e3),
            format!("{:.2}", t_f2_w4 * 1e3),
            format!("{:.2}", t_bwd_flash * 1e3),
            format!("{:.2}", t_bwd_f2_w1 * 1e3),
            format!("{:.2}", t_bwd_f2_w4 * 1e3),
        ]);
        json_rows.push(format!(
            "    {{\"n\": {n}, \"flash_ns\": {:.0}, \"flash2_w1_ns\": {:.0}, \
             \"flash2_w{workers}_ns\": {:.0}, \"speedup_w1\": {:.3}, \
             \"speedup_w{workers}\": {:.3}, \"flash_bwd_ns\": {:.0}, \
             \"flash2_bwd_w1_ns\": {:.0}, \"flash2_bwd_w{workers}_ns\": {:.0}, \
             \"speedup_bwd_w1\": {:.3}, \"speedup_bwd_w{workers}\": {:.3}}}",
            t_flash * 1e9,
            t_f2_w1 * 1e9,
            t_f2_w4 * 1e9,
            t_flash / t_f2_w1,
            t_flash / t_f2_w4,
            t_bwd_flash * 1e9,
            t_bwd_f2_w1 * 1e9,
            t_bwd_f2_w4 * 1e9,
            t_bwd_flash / t_bwd_f2_w1,
            t_bwd_flash / t_bwd_f2_w4,
        ));
    }
    t.print();
    json_rows
}

/// Batched multi-head scheduler vs the per-slice loop it replaced, on the
/// same worker budget: `slices` (batch × heads) [n, 64] slices run either
/// as one `flash2_forward_batched`/`flash2_backward_batched` call (every
/// slice·block work item in one pool) or as `slices` per-slice kernel
/// invocations (one pool spin-up each — the old hot-path shape). Returns
/// BENCH_attn.json "batched" rows; the acceptance bar is batched no
/// slower on every (pass, n) cell.
fn batched_head_to_head(smoke: bool) -> Vec<String> {
    let (d, workers) = (D, WORKERS);
    let (batch, heads) = (2usize, 4usize);
    let slices = batch * heads;
    let mut t = Table::new(
        "batched scheduler vs per-slice loop (2x4 slices of [n,64], mean ns/iter)",
        &[
            "n",
            "per-slice fwd (ms)",
            "batched fwd (ms)",
            "per-slice bwd (ms)",
            "batched bwd (ms)",
        ],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let sizes: &[usize] = if smoke { &[128, 256] } else { &[512, 1024, 4096] };
    for &n in sizes {
        let mut rng = SplitMix64::new(2);
        let q = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::from_sram(48 * 1024, d, n);
        let bwd_blocks = Blocks::for_backward(48 * 1024, d);
        // The per-slice loop gets its slices pre-cut (a real per-slice
        // caller holds them already) — only kernel time is measured.
        let cut = |t4: &Tensor| -> Vec<Tensor> {
            (0..slices)
                .map(|s| {
                    Tensor::from_vec(&[n, d], t4.data[s * n * d..(s + 1) * n * d].to_vec())
                })
                .collect()
        };
        let (qs, ks, vs, dos) = (cut(&q), cut(&k), cut(&v), cut(&dout));
        let per_cfg: Vec<AttnConfig> =
            (0..slices).map(|s| AttnConfig { bh_index: s as u32, ..cfg.clone() }).collect();
        let iters = if smoke { 5 } else if n >= 4096 { 1 } else { 2 };
        // The per-slice loop spins threads up per call (the scoped
        // oracle); the batched side schedules onto the persistent pool.
        let scoped = Exec::scoped(workers);
        let pool = Exec::new(workers);
        let t_loop_fwd = mean_time(iters, || {
            for s in 0..slices {
                std::hint::black_box(flash2_forward(
                    &qs[s], &ks[s], &vs[s], &per_cfg[s], blocks, &scoped, &mut Hbm::new(),
                ));
            }
        });
        let t_batched_fwd = mean_time(iters, || {
            std::hint::black_box(flash2_forward_batched(
                &q, &k, &v, &cfg, blocks, &pool, &mut Hbm::new(),
            ));
        });
        // Backward: both sides consume the same (batched) forward outputs.
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, bwd_blocks, &pool, &mut Hbm::new())
            .expect("fault-free")
            .0;
        let fwd_o_slices = cut(&fwd.o);
        let t_loop_bwd = mean_time(iters, || {
            for s in 0..slices {
                std::hint::black_box(flash2_backward(
                    &qs[s], &ks[s], &vs[s], &fwd_o_slices[s], &dos[s], fwd.stats.slice(s),
                    &per_cfg[s], bwd_blocks, &scoped, &mut Hbm::new(),
                ));
            }
        });
        let t_batched_bwd = mean_time(iters, || {
            std::hint::black_box(flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, bwd_blocks, &pool,
                &mut Hbm::new(),
            ));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_loop_fwd * 1e3),
            format!("{:.2}", t_batched_fwd * 1e3),
            format!("{:.2}", t_loop_bwd * 1e3),
            format!("{:.2}", t_batched_bwd * 1e3),
        ]);
        json_rows.push(format!(
            "    {{\"n\": {n}, \"slices\": {slices}, \"per_slice_fwd_ns\": {:.0}, \
             \"batched_fwd_ns\": {:.0}, \"fwd_speedup\": {:.3}, \"per_slice_bwd_ns\": {:.0}, \
             \"batched_bwd_ns\": {:.0}, \"bwd_speedup\": {:.3}}}",
            t_loop_fwd * 1e9,
            t_batched_fwd * 1e9,
            t_loop_fwd / t_batched_fwd,
            t_loop_bwd * 1e9,
            t_batched_bwd * 1e9,
            t_loop_bwd / t_batched_bwd,
        ));
    }
    t.print();
    json_rows
}

/// Sharded sequence-parallel driver vs the single-device fast pair on
/// the same worker budget (fwd and bwd). The ring schedule performs the
/// single-device kernel's arithmetic bit for bit (asserted in
/// attn::distributed tests), so any time it loses is scheduling
/// overhead — the JSON rows feed python/check_bench.py, which fails the
/// build if sharding regresses past the allowed overhead bound.
fn sharded_head_to_head(smoke: bool) -> Vec<String> {
    let (d, workers) = (D, WORKERS);
    let shards = 4usize;
    let mut t = Table::new(
        "sharded driver vs single device (per [n,64] slice, mean ns/iter)",
        &["n", "single fwd (ms)", "sharded fwd (ms)", "single bwd (ms)", "sharded bwd (ms)"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let sizes: &[usize] = if smoke { &[128, 256] } else { &[512, 1024, 4096] };
    for &n in sizes {
        let mut rng = SplitMix64::new(3);
        let q = Tensor::randn(&[n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[n, d], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::from_sram(48 * 1024, d, n);
        let bwd_blocks = Blocks::for_backward(48 * 1024, d);
        let iters = if smoke {
            5
        } else if n >= 4096 {
            2
        } else {
            5
        };
        let scoped = Exec::scoped(workers);
        let pool = Exec::new(workers);
        let t_single_fwd = mean_time(iters, || {
            std::hint::black_box(flash2_forward(
                &q, &k, &v, &cfg, blocks, &scoped, &mut Hbm::new(),
            ));
        });
        let t_sharded_fwd = mean_time(iters, || {
            std::hint::black_box(flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &pool));
        });
        // Backward: both sides consume the same forward outputs.
        let fwd = flash2_forward(&q, &k, &v, &cfg, bwd_blocks, &scoped, &mut Hbm::new());
        let bwd_iters = if smoke {
            5
        } else if n >= 4096 {
            1
        } else {
            3
        };
        let t_single_bwd = mean_time(bwd_iters, || {
            std::hint::black_box(flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, bwd_blocks, &scoped,
                &mut Hbm::new(),
            ));
        });
        let t_sharded_bwd = mean_time(bwd_iters, || {
            std::hint::black_box(flash_backward_sharded(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, bwd_blocks, shards, &pool,
            ));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_single_fwd * 1e3),
            format!("{:.2}", t_sharded_fwd * 1e3),
            format!("{:.2}", t_single_bwd * 1e3),
            format!("{:.2}", t_sharded_bwd * 1e3),
        ]);
        json_rows.push(format!(
            "    {{\"n\": {n}, \"shards\": {shards}, \"single_fwd_ns\": {:.0}, \
             \"sharded_fwd_ns\": {:.0}, \"fwd_overhead\": {:.3}, \"single_bwd_ns\": {:.0}, \
             \"sharded_bwd_ns\": {:.0}, \"bwd_overhead\": {:.3}}}",
            t_single_fwd * 1e9,
            t_sharded_fwd * 1e9,
            t_sharded_fwd / t_single_fwd,
            t_single_bwd * 1e9,
            t_sharded_bwd * 1e9,
            t_sharded_bwd / t_single_bwd,
        ));
    }
    t.print();
    json_rows
}

/// Block-sparse vs dense fast pair on the SAME tile grid (a 32×32 mask
/// grid at every size, so the §3.3 patterns stay well under 50%
/// density: butterfly ≈ 0.34, local_global ≈ 0.15). The sparse kernels
/// run the identical per-tile arithmetic and skip zero blocks, so at
/// ≤50% density losing to dense is a scheduling regression, not noise —
/// python/check_bench.py gates exactly those cells. Rows land in
/// BENCH_attn.json under "sparse" with their measured density.
fn sparse_head_to_head(smoke: bool) -> Vec<String> {
    let (d, workers) = (D, WORKERS);
    const TILES: usize = 32;
    let mut t = Table::new(
        "block-sparse vs dense flash2 (per [n,64] slice, same tiling, mean ns/iter)",
        &[
            "n",
            "pattern",
            "density",
            "dense fwd (ms)",
            "sparse fwd (ms)",
            "dense bwd (ms)",
            "sparse bwd (ms)",
        ],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let sizes: &[usize] = if smoke { &[128, 256] } else { &[512, 1024, 4096] };
    for &n in sizes {
        let blocks = Blocks::explicit(n / TILES, n / TILES);
        let mut rng = SplitMix64::new(4);
        let q = Tensor::randn(&[n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[n, d], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let iters = if smoke { 5 } else if n >= 4096 { 2 } else { 5 };
        let bwd_iters = if smoke { 5 } else if n >= 4096 { 1 } else { 3 };
        let scoped = Exec::scoped(workers);
        let pool = Exec::new(workers);
        // Dense side: the flash2 pair on the same tiling, measured once
        // per size (both patterns compare against it).
        let t_dense_fwd = mean_time(iters, || {
            std::hint::black_box(flash2_forward(
                &q, &k, &v, &cfg, blocks, &scoped, &mut Hbm::new(),
            ));
        });
        let dense_fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &scoped, &mut Hbm::new());
        let t_dense_bwd = mean_time(bwd_iters, || {
            std::hint::black_box(flash2_backward(
                &q, &k, &v, &dense_fwd.o, &dout, dense_fwd.stats(), &cfg, blocks, &scoped,
                &mut Hbm::new(),
            ));
        });
        for pattern in ["butterfly", "local_global"] {
            let mask = if pattern == "butterfly" {
                BlockMask::butterfly(TILES, TILES)
            } else {
                BlockMask::local_global(TILES, TILES, 1, 1)
            };
            let density = mask.sparsity();
            let t_sparse_fwd = mean_time(iters, || {
                std::hint::black_box(block_sparse2_forward(
                    &q, &k, &v, &mask, &cfg, blocks, &pool, &mut Hbm::new(),
                ));
            });
            let sparse_fwd =
                block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &pool, &mut Hbm::new());
            let t_sparse_bwd = mean_time(bwd_iters, || {
                std::hint::black_box(block_sparse2_backward(
                    &q, &k, &v, &sparse_fwd.o, &dout, sparse_fwd.stats(), &mask, &cfg, blocks,
                    &pool, &mut Hbm::new(),
                ));
            });
            t.row(vec![
                n.to_string(),
                pattern.to_string(),
                format!("{density:.3}"),
                format!("{:.2}", t_dense_fwd * 1e3),
                format!("{:.2}", t_sparse_fwd * 1e3),
                format!("{:.2}", t_dense_bwd * 1e3),
                format!("{:.2}", t_sparse_bwd * 1e3),
            ]);
            json_rows.push(format!(
                "    {{\"n\": {n}, \"pattern\": \"{pattern}\", \"density\": {density:.4}, \
                 \"dense_fwd_ns\": {:.0}, \"sparse_fwd_ns\": {:.0}, \"fwd_speedup\": {:.3}, \
                 \"dense_bwd_ns\": {:.0}, \"sparse_bwd_ns\": {:.0}, \"bwd_speedup\": {:.3}}}",
                t_dense_fwd * 1e9,
                t_sparse_fwd * 1e9,
                t_dense_fwd / t_sparse_fwd,
                t_dense_bwd * 1e9,
                t_sparse_bwd * 1e9,
                t_dense_bwd / t_sparse_bwd,
            ));
        }
    }
    t.print();
    json_rows
}

/// Fault-free overhead of the guardrailed execution handle vs the plain
/// one on the identical workload: `Exec::new(w).with_plan(&none).validated()`
/// adds only the disabled-plan probe plus the per-item finiteness scan,
/// which is O(output) against the kernel's O(n·n_k·d) arithmetic. Rows
/// land in BENCH_attn.json under "guardrail" (keys kept from the
/// pre-`Exec` checked-twin era); python/check_bench.py fails the build
/// if the guarded handle ever costs more than the allowed fault-free
/// overhead on any (pass, n) cell.
fn guardrail_head_to_head(smoke: bool) -> Vec<String> {
    let (d, workers) = (D, WORKERS);
    let (batch, heads) = (2usize, 4usize);
    let mut t = Table::new(
        "guardrail overhead: guarded vs plain Exec, batched (2x4 slices of [n,64], mean ns/iter)",
        &["n", "plain fwd (ms)", "checked fwd (ms)", "plain bwd (ms)", "checked bwd (ms)"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let plan = FaultPlan::none();
    let plain = Exec::new(workers);
    let guarded = Exec::new(workers).with_plan(&plan).validated();
    let sizes: &[usize] = if smoke { &[128, 256] } else { &[512, 1024, 4096] };
    for &n in sizes {
        let mut rng = SplitMix64::new(5);
        let q = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::from_sram(48 * 1024, d, n);
        let bwd_blocks = Blocks::for_backward(48 * 1024, d);
        let iters = if smoke { 5 } else if n >= 4096 { 1 } else { 2 };
        let t_plain_fwd = mean_time(iters, || {
            std::hint::black_box(flash2_forward_batched(
                &q, &k, &v, &cfg, blocks, &plain, &mut Hbm::new(),
            ));
        });
        let t_checked_fwd = mean_time(iters, || {
            std::hint::black_box(
                flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded, &mut Hbm::new())
                    .expect("fault-free"),
            );
        });
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, bwd_blocks, &plain, &mut Hbm::new())
            .expect("fault-free")
            .0;
        let t_plain_bwd = mean_time(iters, || {
            std::hint::black_box(flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, bwd_blocks, &plain,
                &mut Hbm::new(),
            ));
        });
        let t_checked_bwd = mean_time(iters, || {
            std::hint::black_box(
                flash2_backward_batched(
                    &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, bwd_blocks, &guarded,
                    &mut Hbm::new(),
                )
                .expect("fault-free"),
            );
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_plain_fwd * 1e3),
            format!("{:.2}", t_checked_fwd * 1e3),
            format!("{:.2}", t_plain_bwd * 1e3),
            format!("{:.2}", t_checked_bwd * 1e3),
        ]);
        json_rows.push(format!(
            "    {{\"n\": {n}, \"plain_fwd_ns\": {:.0}, \"checked_fwd_ns\": {:.0}, \
             \"fwd_overhead\": {:.3}, \"plain_bwd_ns\": {:.0}, \"checked_bwd_ns\": {:.0}, \
             \"bwd_overhead\": {:.3}}}",
            t_plain_fwd * 1e9,
            t_checked_fwd * 1e9,
            t_checked_fwd / t_plain_fwd,
            t_plain_bwd * 1e9,
            t_checked_bwd * 1e9,
            t_checked_bwd / t_plain_bwd,
        ));
    }
    t.print();
    json_rows
}

/// Persistent pool vs per-call thread scope on the SAME batched workload
/// — the cost the `Exec` runtime exists to delete. Both sides run the
/// identical canonical batched entries with the same worker budget; the
/// only difference is the handle's mode: `Exec::scoped(w)` spawns and
/// joins `w` threads every call (the pre-pool behaviour), `Exec::new(w)`
/// schedules onto workers parked since the warm-up call. Deliberately
/// small n — that's where per-call spawn/join is a visible fraction of
/// the work. Rows land in BENCH_attn.json under "pool";
/// python/check_bench.py fails the build if the persistent pool ever
/// loses to per-call scoping on any (pass, n) cell.
fn pool_head_to_head(smoke: bool) -> Vec<String> {
    let (d, workers) = (D, WORKERS);
    let (batch, heads) = (2usize, 4usize);
    let mut t = Table::new(
        "persistent pool vs per-call scope (2x4 slices of [n,64], mean ns/iter)",
        &["n", "scoped fwd (ms)", "pool fwd (ms)", "scoped bwd (ms)", "pool bwd (ms)"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let scoped = Exec::scoped(workers);
    let pool = Exec::new(workers);
    let sizes: &[usize] = if smoke { &[128, 256] } else { &[128, 256, 512] };
    for &n in sizes {
        let mut rng = SplitMix64::new(6);
        let q = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[batch, heads, n, d], &mut rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::from_sram(48 * 1024, d, n);
        let bwd_blocks = Blocks::for_backward(48 * 1024, d);
        let iters = if smoke { 5 } else { 10 };
        // Warm both handles outside the timed region (first pool call
        // spawns the workers; every later call reuses them).
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, bwd_blocks, &pool, &mut Hbm::new())
            .expect("fault-free")
            .0;
        std::hint::black_box(
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &scoped, &mut Hbm::new())
                .expect("fault-free"),
        );
        let t_scoped_fwd = mean_time(iters, || {
            std::hint::black_box(flash2_forward_batched(
                &q, &k, &v, &cfg, blocks, &scoped, &mut Hbm::new(),
            ));
        });
        let t_pool_fwd = mean_time(iters, || {
            std::hint::black_box(flash2_forward_batched(
                &q, &k, &v, &cfg, blocks, &pool, &mut Hbm::new(),
            ));
        });
        let t_scoped_bwd = mean_time(iters, || {
            std::hint::black_box(flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, bwd_blocks, &scoped,
                &mut Hbm::new(),
            ));
        });
        let t_pool_bwd = mean_time(iters, || {
            std::hint::black_box(flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, bwd_blocks, &pool,
                &mut Hbm::new(),
            ));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_scoped_fwd * 1e3),
            format!("{:.2}", t_pool_fwd * 1e3),
            format!("{:.2}", t_scoped_bwd * 1e3),
            format!("{:.2}", t_pool_bwd * 1e3),
        ]);
        json_rows.push(format!(
            "    {{\"n\": {n}, \"scoped_fwd_ns\": {:.0}, \"pool_fwd_ns\": {:.0}, \
             \"fwd_speedup\": {:.3}, \"scoped_bwd_ns\": {:.0}, \"pool_bwd_ns\": {:.0}, \
             \"bwd_speedup\": {:.3}}}",
            t_scoped_fwd * 1e9,
            t_pool_fwd * 1e9,
            t_scoped_fwd / t_pool_fwd,
            t_scoped_bwd * 1e9,
            t_pool_bwd * 1e9,
            t_scoped_bwd / t_pool_bwd,
        ));
    }
    t.print();
    json_rows
}

/// The serving tier under a prefill+decode mix: a ContinuousBatcher fed
/// a wave of mixed-length requests (short chat turns joining and
/// leaving around long documents — the TGI admission pattern), driven
/// to completion on the persistent pool. The figure of merit is
/// **tokens/sec under load** — generated tokens over serve wall-clock,
/// prefill joins, split-KV decode steps, cache filtering and all. Rows
/// land in BENCH_attn.json under "serving"; python/check_bench.py fails
/// the build if throughput ever falls below the section floor.
fn serving_head_to_head(smoke: bool) -> Vec<String> {
    let workers = WORKERS;
    let mut t = Table::new(
        "continuous batching serve (prefill+decode mix, split-KV decode)",
        &["n_ctx", "requests", "tokens", "ms", "tokens/sec"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let exec = Exec::new(workers);
    // (base prompt length, request count, new tokens per request)
    let grid: &[(usize, usize, usize)] =
        if smoke { &[(32, 4, 4)] } else { &[(64, 8, 8), (256, 8, 8)] };
    for &(n_ctx, requests, new_tokens) in grid {
        let cfg = flashattn::coordinator::server::BatcherConfig {
            d: D,
            b_c: 32,
            span_tiles: 2,
            // Roughly half the wave fits at once: later requests join
            // as earlier ones finish — the continuous-batching regime.
            token_budget: (n_ctx + new_tokens) * requests.div_ceil(2),
        };
        let submit_all = |b: &mut flashattn::coordinator::server::ContinuousBatcher| {
            for r in 0..requests {
                // Mixed lengths: every 4th request is a long document.
                let prompt_len = if r % 4 == 3 { n_ctx * 2 } else { n_ctx / 2 + r };
                b.submit(flashattn::coordinator::server::DecodeRequest {
                    id: r as u64,
                    prompt_len,
                    max_new_tokens: new_tokens,
                    seed: 0xBE7 + r as u64,
                });
            }
        };
        let iters = if smoke { 2 } else { 5 };
        let mut tokens = 0usize;
        let elapsed = mean_time(iters, || {
            let mut b = flashattn::coordinator::server::ContinuousBatcher::new(cfg.clone());
            submit_all(&mut b);
            let report = b.run(&exec, &mut Hbm::new());
            assert!(report.evicted.is_empty(), "fault-free serve must not evict");
            tokens = report.generated_tokens;
        });
        let tps = tokens as f64 / elapsed;
        t.row(vec![
            n_ctx.to_string(),
            requests.to_string(),
            tokens.to_string(),
            format!("{:.2}", elapsed * 1e3),
            format!("{tps:.0}"),
        ]);
        json_rows.push(format!(
            "    {{\"n_ctx\": {n_ctx}, \"requests\": {requests}, \"tokens\": {tokens}, \
             \"serve_ns\": {:.0}, \"tokens_per_sec\": {tps:.1}}}",
            elapsed * 1e9,
        ));
    }
    t.print();
    json_rows
}

/// Assemble BENCH_attn.json (head-to-head + batched + sharded + sparse +
/// guardrail + pool + serving rows) at the repo root regardless of the
/// cwd cargo bench picked.
#[allow(clippy::too_many_arguments)]
fn write_bench_json(
    smoke: bool,
    results: &[String],
    batched: &[String],
    sharded: &[String],
    sparse: &[String],
    guardrail: &[String],
    pool: &[String],
    serving: &[String],
) {
    let (d, workers) = (D, WORKERS);
    let json = format!(
        "{{\n  \"bench\": \"attn_mirror_hotpath\",\n  \"unit\": \"ns_per_iter\",\n  \
         \"d\": {d},\n  \"workers\": {workers},\n  \"smoke\": {smoke},\n  \
         \"results\": [\n{}\n  ],\n  \"batched\": [\n{}\n  ],\n  \"sharded\": [\n{}\n  ],\n  \
         \"sparse\": [\n{}\n  ],\n  \"guardrail\": [\n{}\n  ],\n  \"pool\": [\n{}\n  ],\n  \
         \"serving\": [\n{}\n  ]\n}}\n",
        results.join(",\n"),
        batched.join(",\n"),
        sharded.join(",\n"),
        sparse.join(",\n"),
        guardrail.join(",\n"),
        pool.join(",\n"),
        serving.join(",\n")
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_attn.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => println!("could not write BENCH_attn.json: {e}"),
    }
}

fn artifacts() {
    let mut rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping artifact microbench: {e:#}");
            return;
        }
    };
    let mut rng = SplitMix64::new(1);
    let mk = |rng: &mut SplitMix64| Value::F32 {
        shape: vec![8, 128, 64],
        data: rng.normal_vec(8 * 128 * 64, 1.0),
    };
    let q = mk(&mut rng);
    let k = mk(&mut rng);
    let v = mk(&mut rng);
    let inputs = vec![q, k, v];

    let mut t = Table::new("PJRT artifact execution (CPU, median of 5)", &["artifact", "ms"]);
    for name in ["attn_ref_fwd", "attn_flash_fwd", "attn_flash_fwd_causal", "attn_bsparse_fwd"] {
        rt.load(name).expect("compile");
        let tm = median_time(5, || {
            rt.run(name, &inputs).expect("run");
        });
        t.row(vec![name.into(), format!("{:.2}", tm * 1e3)]);
    }
    t.print();
    println!(
        "NOTE: interpret-mode Pallas lowers to scalar-ish HLO loops — CPU wallclock of the \
         flash artifacts is a correctness vehicle, not a TPU performance proxy (DESIGN.md §3)."
    );

    // Value<->Literal conversion cost (per train-step state round trip).
    let big = Value::F32 { shape: vec![256, 128], data: vec![1.0; 256 * 128] };
    let conv = median_time(20, || {
        let lit = big.to_literal().unwrap();
        std::hint::black_box(Value::from_literal(&lit).unwrap());
    });
    println!("Value<->Literal round trip (256x128 f32): {:.3} ms", conv * 1e3);

    // Fused train step end-to-end (the serving-relevant hot path).
    if rt.manifest.artifacts.contains_key("gpt_flash_train_step") {
        use flashattn::coordinator::{LmTrainer, TrainConfig};
        use flashattn::data::corpus::Corpus;
        let corpus = Corpus::builtin(50_000, 2);
        let cfg = TrainConfig {
            model: "gpt_flash".into(),
            steps: 1,
            eval_every: 0,
            ..Default::default()
        };
        let mut tr = LmTrainer::new(&mut rt, cfg, &Exec::new(WORKERS)).unwrap();
        let batch = corpus.lm_batch(tr.batch, tr.n_ctx, &mut SplitMix64::new(3));
        tr.step(&mut rt, &batch).unwrap(); // warmup: includes artifact compile
        let t0 = Instant::now();
        let iters = 5;
        for _ in 0..iters {
            tr.step(&mut rt, &batch).unwrap();
        }
        println!(
            "gpt_flash fused train step: {:.0} ms/step (mean over {iters}, post-compile)",
            t0.elapsed().as_secs_f64() * 1e3 / iters as f64
        );
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    if !smoke {
        mirrors();
    }
    let results = fast_kernel_head_to_head(smoke);
    let batched = batched_head_to_head(smoke);
    let sharded = sharded_head_to_head(smoke);
    let sparse = sparse_head_to_head(smoke);
    let guardrail = guardrail_head_to_head(smoke);
    let pool = pool_head_to_head(smoke);
    let serving = serving_head_to_head(smoke);
    write_bench_json(smoke, &results, &batched, &sharded, &sparse, &guardrail, &pool, &serving);
    artifacts();
}
