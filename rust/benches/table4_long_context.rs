//! Table 4: language modelling with longer context — GPT-2 small with
//! FlashAttention at 4x the context is *still faster* than Megatron at 1K
//! while reaching better perplexity (18.2 -> 17.5).
//!
//! Speed column: the e2e model at context 1K/2K/4K.
//! Quality column: REAL training runs of the ctx-{64,128,256} artifacts on
//! the same corpus — eval loss improves monotonically with context length
//! (scaled-down analogue of the 0.7 ppl gain).

use std::path::Path;

use flashattn::bench::out_dir;
use flashattn::coordinator::{LmTrainer, TrainConfig};
use flashattn::data::corpus::Corpus;
use flashattn::runtime::Runtime;
use flashattn::sim::baselines::Method;
use flashattn::sim::e2e::{step_seconds, ModelShape};
use flashattn::sim::roofline::Roofline;
use flashattn::util::table::Table;

fn speed_model() {
    let rl = Roofline::a100();
    let meg_1k =
        step_seconds(&rl, &ModelShape::gpt2_small(1024), Method::Megatron, "megatron").unwrap();
    let mut t = Table::new(
        "Table 4 — speed model (paper: Megatron 1K = 1.0x; Flash 1K/2K/4K = 1.7x/1.6x/1.3x)",
        &["implementation", "context", "tokens/step", "rel. speed (model)", "paper"],
    );
    t.row(vec!["Megatron-LM".into(), "1k".into(), "32k".into(), "1.00x".into(), "1.0x".into()]);
    for (ctx, paper) in [(1024u64, "1.7x"), (2048, "1.6x"), (4096, "1.3x")] {
        // Same token budget per step: batch shrinks as context grows.
        let mut shape = ModelShape::gpt2_small(ctx);
        shape.batch = 32 * 1024 / ctx;
        let s = step_seconds(&rl, &shape, Method::FlashAttention, "ours").unwrap();
        t.row(vec![
            "FlashAttention".into(),
            format!("{}k", ctx / 1024),
            "32k".into(),
            format!("{:.2}x", meg_1k / s),
            paper.into(),
        ]);
    }
    t.print();
    t.write_csv(&out_dir().join("table4_speed.csv")).unwrap();
    let rl_check = meg_1k
        / step_seconds(&rl, &{
            let mut s = ModelShape::gpt2_small(4096);
            s.batch = 8;
            s
        }, Method::FlashAttention, "ours")
        .unwrap();
    println!(
        "[{}] flash@4K still faster than Megatron@1K (model {rl_check:.2}x > 1.0)",
        if rl_check > 1.0 { "OK" } else { "FAIL" }
    );
}

fn quality_runs() {
    let steps: usize =
        std::env::var("FLASHATTN_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(40);
    println!("## quality: eval loss vs context length (real runs, {steps} steps)");
    let mut rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping real runs: {e:#}");
            return;
        }
    };
    // One corpus; models with longer context see longer windows.
    let corpus = Corpus::builtin(300_000, 11);
    let mut t = Table::new(
        "eval loss by context (paper Table 4: ppl 18.2 -> 17.6 -> 17.5 as ctx grows)",
        &["model", "context", "eval loss", "eval ppl"],
    );
    let mut losses = Vec::new();
    let exec = flashattn::attn::Exec::new(4);
    for tag in ["gpt_flash_ctx64", "gpt_flash", "gpt_flash_ctx256"] {
        let cfg =
            TrainConfig { model: tag.into(), steps, eval_every: 0, seed: 5, ..Default::default() };
        let mut tr = match LmTrainer::new(&mut rt, cfg, &exec) {
            Ok(tr) => tr,
            Err(e) => {
                println!("({tag}: {e:#})");
                continue;
            }
        };
        tr.train(&mut rt, &corpus).expect("train");
        let eval = tr.eval_loss(&mut rt, &corpus.eval_batch(tr.batch, tr.n_ctx)).expect("eval");
        losses.push(eval);
        t.row(vec![
            tag.into(),
            tr.n_ctx.to_string(),
            format!("{eval:.4}"),
            format!("{:.2}", eval.exp()),
        ]);
    }
    t.print();
    t.write_csv(&out_dir().join("table4_quality.csv")).unwrap();
    if losses.len() == 3 {
        let ok = losses[2] <= losses[0];
        println!(
            "[{}] longer context => lower eval loss ({:.4} -> {:.4})",
            if ok { "OK" } else { "FAIL" },
            losses[0],
            losses[2]
        );
    }
}

fn main() {
    speed_model();
    quality_runs();
}
