//! Table 7: FlashAttention vs Apex FMHA (the MLPerf fused-MHA kernel) at
//! BERT shapes (batch 64, 16 heads, d 64, mask+dropout, N <= 512).
//!
//! Paper shape: flash slightly FASTER forward (no N² store), slightly
//! SLOWER backward (recomputation FLOPs), combined crossover at N=256.

use flashattn::bench::{ms_cell, out_dir};
use flashattn::sim::baselines::Method;
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::table::Table;

fn main() {
    let rl = Roofline::a100();
    let cfg =
        BenchConfig { batch: 64, heads: 16, dropout: true, masked: true, ..Default::default() };
    let paper: &[(&str, [f64; 3])] = &[
        ("Apex FMHA forward", [0.10, 0.29, 1.14]),
        ("FlashAttention forward", [0.08, 0.22, 0.81]),
        ("Apex FMHA backward", [0.17, 0.52, 1.81]),
        ("FlashAttention backward", [0.20, 0.53, 2.00]),
        ("Apex FMHA fwd+bwd", [0.27, 0.81, 2.95]),
        ("FlashAttention fwd+bwd", [0.28, 0.75, 2.81]),
    ];
    let ns = [128u64, 256, 512];
    let mut t = Table::new(
        "Table 7 — Flash vs Apex FMHA (ms; model | paper)",
        &["Attention Method", "128", "256", "512"],
    );
    let rows: [(&str, Method, Pass); 6] = [
        ("Apex FMHA forward", Method::ApexFmha, Pass::Fwd),
        ("FlashAttention forward", Method::FlashAttention, Pass::Fwd),
        ("Apex FMHA backward", Method::ApexFmha, Pass::Bwd),
        ("FlashAttention backward", Method::FlashAttention, Pass::Bwd),
        ("Apex FMHA fwd+bwd", Method::ApexFmha, Pass::FwdBwd),
        ("FlashAttention fwd+bwd", Method::FlashAttention, Pass::FwdBwd),
    ];
    for (i, (label, m, pass)) in rows.iter().enumerate() {
        let mut row = vec![label.to_string()];
        for (j, &n) in ns.iter().enumerate() {
            let model = rl.time_ms(*m, *pass, n, &cfg);
            row.push(format!("{} | {:.2}", ms_cell(model), paper[i].1[j]));
        }
        t.row(row);
    }
    t.print();
    t.write_csv(&out_dir().join("table7.csv")).unwrap();

    // Shape checks.
    let f = |m: Method, p: Pass, n: u64| rl.time_ms(m, p, n, &cfg).unwrap();
    let fwd_faster_512 =
        f(Method::FlashAttention, Pass::Fwd, 512) < f(Method::ApexFmha, Pass::Fwd, 512);
    let bwd_slower_512 =
        f(Method::FlashAttention, Pass::Bwd, 512) > f(Method::ApexFmha, Pass::Bwd, 512);
    let combined_wins_512 =
        f(Method::FlashAttention, Pass::FwdBwd, 512) < f(Method::ApexFmha, Pass::FwdBwd, 512);
    println!(
        "[{}] flash forward faster than FMHA at 512",
        if fwd_faster_512 { "OK" } else { "FAIL" }
    );
    println!(
        "[{}] flash backward slower than FMHA at 512 (recompute FLOPs)",
        if bwd_slower_512 { "OK" } else { "FAIL" }
    );
    println!(
        "[{}] flash combined wins at 512 (paper: 5% faster)",
        if combined_wins_512 { "OK" } else { "FAIL" }
    );
}
