//! Tables 9–21 (Appendix E.6): the full benchmarking grid — forward,
//! backward, and combined runtimes for all 12 methods × 10 sequence
//! lengths × {dropout} × {masking}, plus the memory-usage table, printed
//! in exactly the paper's layout, with the paper's own numbers alongside
//! at the calibration-independent columns for comparison.

use flashattn::bench::{ms_cell, out_dir};
use flashattn::sim::baselines::SWEEP_METHODS;
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::table::Table;

const NS: [u64; 10] = [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536];

fn sweep(rl: &Roofline, pass: Pass, dropout: bool, masked: bool, table_no: u32) {
    let cfg = BenchConfig { dropout, masked, ..Default::default() };
    let mut headers: Vec<String> = vec!["Attention Method".into()];
    headers.extend(NS.iter().map(|n| n.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!(
            "Table {table_no} — {:?} runtime (ms), dropout={} masking={}",
            pass, dropout, masked
        ),
        &hrefs,
    );
    for m in SWEEP_METHODS {
        let mut row = vec![m.name().to_string()];
        for &n in &NS {
            row.push(ms_cell(rl.time_ms(*m, pass, n, &cfg)));
        }
        t.row(row);
    }
    t.print();
    t.write_csv(&out_dir().join(format!("table{table_no}.csv"))).unwrap();
}

fn main() {
    let rl = Roofline::a100();
    // Table 8's grid: (dropout, masking) x (fwd, bwd, combined).
    let combos: [(bool, bool, [u32; 3]); 4] = [
        (true, true, [9, 10, 11]),
        (false, true, [12, 13, 14]),
        (true, false, [15, 16, 17]),
        (false, false, [18, 19, 20]),
    ];
    for (dropout, masked, tables) in combos {
        sweep(&rl, Pass::Fwd, dropout, masked, tables[0]);
        sweep(&rl, Pass::Bwd, dropout, masked, tables[1]);
        sweep(&rl, Pass::FwdBwd, dropout, masked, tables[2]);
    }

    // Table 21: memory usage (combined, no dropout/mask).
    let cfg = BenchConfig::default();
    let mut headers: Vec<String> = vec!["Attention Method".into()];
    headers.extend(NS.iter().map(|n| n.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new("Table 21 — memory usage (MB)", &hrefs);
    for m in SWEEP_METHODS {
        let mut row = vec![m.name().to_string()];
        for &n in &NS {
            row.push(ms_cell(rl.mem_mb(*m, n, &cfg)));
        }
        t.row(row);
    }
    t.print();
    t.write_csv(&out_dir().join("table21.csv")).unwrap();

    // Paper-vs-model comparison at an extrapolated column (N=4096, Table 18
    // fwd / Table 19 bwd / Table 21 mem) — N=1024 is the calibration anchor,
    // so 4096 tests the *structural* extrapolation.
    println!("## paper-vs-model at N=4096 (model calibrated only at N=1024)");
    let paper_fwd_4096: &[(&str, f64)] = &[
        ("PyTorch Attention", 16.47),
        ("Reformer", 41.11),
        ("Local Attention", 11.56),
        ("Linformer", 2.09),
        ("Smyrf", 22.23),
        ("LSformer", 21.71),
        ("Block Sparse", 16.15),
        ("Longformer", 11.07),
        ("BigBird", 11.59),
        ("FlashAttention", 8.42),
        ("Block-Sparse FlashAttention", 0.96),
    ];
    let cfg = BenchConfig::default();
    let mut t =
        Table::new("fwd @4096: paper vs model", &["method", "paper (ms)", "model (ms)", "ratio"]);
    for (name, paper) in paper_fwd_4096 {
        let m = SWEEP_METHODS.iter().find(|m| m.name() == *name).unwrap();
        if let Some(model) = rl.time_ms(*m, Pass::Fwd, 4096, &cfg) {
            t.row(vec![
                name.to_string(),
                format!("{paper:.2}"),
                format!("{model:.2}"),
                format!("{:.2}", model / paper),
            ]);
        }
    }
    t.print();
    t.write_csv(&out_dir().join("paper_vs_model_4096.csv")).unwrap();
}
