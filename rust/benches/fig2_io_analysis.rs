//! Figure 2: the IO analysis that anchors the whole paper.
//!
//! * Left  — GFLOPs / HBM-GB / runtime of standard vs Flash attention at
//!   GPT-2-medium shape (N=1024, d=64, 16 heads, batch 64, fwd+bwd).
//!   Measured two ways: analytic counts (sim::cost) and the *instrumented
//!   pure-Rust mirrors* executing the real algorithms with an HBM counter.
//! * Middle — forward runtime vs block size B_c: HBM accesses fall, then
//!   runtime flattens when compute-bound (paper: beyond B_c=256).
//! * Right — block-sparse runtime vs sparsity at N=4096: runtime improves
//!   proportionally to the nonzero fraction (Proposition 4).

use flashattn::attn::flash::{flash_backward, flash_forward, Blocks};
use flashattn::attn::masks::BlockMask;
use flashattn::attn::standard::{standard_backward, standard_forward};
use flashattn::attn::AttnConfig;
use flashattn::bench::out_dir;
use flashattn::sim::baselines::Method;
use flashattn::sim::cost;
use flashattn::sim::hbm::Hbm;
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;
use flashattn::util::table::Table;

fn main() {
    fig2_left();
    fig2_middle();
    fig2_right();
}

fn fig2_left() {
    // GPT-2 medium attention: N=1024, d=64, 16 heads, batch 64, fp16.
    let cfg = BenchConfig { batch: 64, heads: 16, ..Default::default() };
    let (n, d) = (1024u64, 64u64);
    let bh = cfg.bh();
    let rl = Roofline::a100();
    let blocks = Method::flash_blocks(&rl.spec, d, n);

    let std_c = cost::standard_fwd(n, d, false, false).add(cost::standard_bwd(n, d, false, false));
    let fla_c = cost::flash_fwd(n, d, blocks, false, false)
        .add(cost::flash_bwd(n, d, blocks, false, false));

    let gf = |c: &cost::Cost| c.flops as f64 * bh as f64 / 1e9;
    let gb = |c: &cost::Cost| c.hbm_elems as f64 * cfg.bytes_per_elem * bh as f64 / 1e9;
    let ms = |m: Method| rl.time_ms(m, Pass::FwdBwd, n, &cfg).unwrap();

    let mut t = Table::new(
        "Fig 2 left — GPT-2 medium attention fwd+bwd (paper: std 66.6 GF / 40.3 GB / 41.7 ms; \
         flash 75.2 GF / 4.4 GB / 7.3 ms)",
        &["Attention", "GFLOPs", "HBM R/W (GB)", "Runtime (ms)"],
    );
    t.row(vec![
        "Standard".into(),
        format!("{:.1}", gf(&std_c)),
        format!("{:.1}", gb(&std_c)),
        format!("{:.1}", ms(Method::PyTorch)),
    ]);
    t.row(vec![
        "FlashAttention".into(),
        format!("{:.1}", gf(&fla_c)),
        format!("{:.1}", gb(&fla_c)),
        format!("{:.1}", ms(Method::FlashAttention)),
    ]);
    t.print();
    t.write_csv(&out_dir().join("fig2_left.csv")).unwrap();
    println!(
        "shape ratios — FLOPs flash/std: {:.2} (paper 1.13: recompute costs MORE flops), \
         HBM std/flash: {:.1}x (paper 9.2x), runtime std/flash: {:.1}x (paper 5.7x).\n\
         Absolute GFLOPs differ from the paper by a per-GPU/causal accounting constant; \
         the ordering (more FLOPs, far less IO, faster) is the claim under test.",
        gf(&fla_c) / gf(&std_c),
        gb(&std_c) / gb(&fla_c),
        ms(Method::PyTorch) / ms(Method::FlashAttention)
    );

    // Instrumented validation: run the actual mirrored algorithms at a
    // scaled shape and check measured accesses match the analytic counts.
    let (ni, di) = (256usize, 32usize);
    let mut rng = SplitMix64::new(0);
    let q = Tensor::randn(&[ni, di], &mut rng, 1.0);
    let k = Tensor::randn(&[ni, di], &mut rng, 1.0);
    let v = Tensor::randn(&[ni, di], &mut rng, 1.0);
    let acfg = AttnConfig::default();
    let bl = Blocks::explicit(32, 64);

    let mut h_std = Hbm::new();
    let out = standard_forward(&q, &k, &v, &acfg, &mut h_std);
    standard_backward(&q, &k, &v, &out.o, &acfg, &mut h_std);
    let pred_std = cost::standard_fwd(ni as u64, di as u64, false, false)
        .add(cost::standard_bwd(ni as u64, di as u64, false, false));

    let mut h_fla = Hbm::new();
    let f = flash_forward(&q, &k, &v, &acfg, bl, &mut h_fla);
    flash_backward(&q, &k, &v, &f.o, &out.o, f.stats(), &acfg, bl, &mut h_fla);
    let pred_fla = cost::flash_fwd(ni as u64, di as u64, bl, false, false)
        .add(cost::flash_bwd(ni as u64, di as u64, bl, false, false));

    let mut h_fl2 = Hbm::new();
    flashattn::attn::flash2::flash2_forward(&q, &k, &v, &acfg, bl, 4, &mut h_fl2);
    let pred_fl2 = cost::flash2_fwd(ni as u64, di as u64, bl, false, false);

    println!("instrumented-vs-analytic (N={ni}, d={di}):");
    println!(
        "  standard: measured {} vs analytic {}  ({})",
        h_std.accesses(),
        pred_std.hbm_elems,
        if h_std.accesses() == pred_std.hbm_elems { "EXACT" } else { "≈" }
    );
    println!(
        "  flash:    measured {} vs analytic {}  ({})",
        h_fla.accesses(),
        pred_fla.hbm_elems,
        if h_fla.accesses() == pred_fla.hbm_elems { "EXACT" } else { "≈" }
    );
    println!(
        "  flash2:   measured {} vs analytic {} fwd-only ({}); O/stats stores {} = N·d + N",
        h_fl2.accesses(),
        pred_fl2.hbm_elems,
        if h_fl2.accesses() == pred_fl2.hbm_elems { "EXACT" } else { "≈" },
        h_fl2.stores
    );
    println!();
}

fn fig2_middle() {
    // Forward runtime + HBM accesses vs block size B_c at N=1024 d=64.
    let (n, d) = (1024u64, 64u64);
    let cfg = BenchConfig { batch: 64, heads: 16, ..Default::default() };
    let rl = Roofline::a100();
    let mut t = Table::new(
        "Fig 2 middle — fwd runtime vs block size (runtime falls with HBM accesses, flattens \
         when compute-bound)",
        &["B_c", "HBM accesses (M elems)", "model fwd (ms)"],
    );
    for bc in [16u64, 32, 64, 128, 256, 512, 1024] {
        let blocks = Blocks::explicit(64.min(bc as usize), bc as usize);
        let c = cost::flash_fwd(n, d, blocks, false, false);
        let bytes = c.hbm_elems as f64 * cfg.bytes_per_elem * cfg.bh() as f64;
        let flops = c.flops as f64 * cfg.bh() as f64;
        let ms = (bytes / rl.spec.eff_bw() + flops / rl.spec.eff_flops_fp16()) * 1e3;
        t.row(vec![
            bc.to_string(),
            format!("{:.1}", c.hbm_elems as f64 * cfg.bh() as f64 / 1e6),
            format!("{ms:.2}"),
        ]);
    }
    t.print();
    t.write_csv(&out_dir().join("fig2_middle.csv")).unwrap();
}

fn fig2_right() {
    // Block-sparse runtime vs sparsity at N=4096 (fwd+bwd).
    let (n, d) = (4096u64, 64u64);
    let cfg = BenchConfig { batch: 64, heads: 16, ..Default::default() };
    let rl = Roofline::a100();
    let blocks = Blocks::explicit(64, 256);
    let t_r = (n as usize) / 64;
    let t_c = (n as usize) / 256;
    let mut dense_ms = None;
    let mut t = Table::new(
        "Fig 2 right — block-sparse flash runtime ∝ sparsity (N=4096, fwd+bwd)",
        &["nonzero fraction s", "model (ms)", "vs dense flash"],
    );
    for keep_every in [1usize, 2, 4, 8] {
        // Structured mask: keep every k-th column block (plus diagonal).
        let mut mask = BlockMask::zeros(t_r, t_c);
        for i in 0..t_r {
            for j in 0..t_c {
                if j % keep_every == 0 || j == (i * t_c) / t_r {
                    mask.set(i, j, true);
                }
            }
        }
        let c = cost::block_sparse_fwd(n, d, blocks, &mask, false)
            .add(cost::block_sparse_bwd(n, d, blocks, &mask, false));
        let bytes = c.hbm_elems as f64 * cfg.bytes_per_elem * cfg.bh() as f64;
        let flops = c.flops as f64 * cfg.bh() as f64;
        let ms = (bytes / rl.spec.eff_bw() + flops / rl.spec.eff_flops_fp16()) * 1e3;
        let dense = *dense_ms.get_or_insert(ms); // first row (s=1) is the baseline
        t.row(vec![
            format!("{:.3}", mask.sparsity()),
            format!("{ms:.2}"),
            format!("{:.2}x", dense / ms),
        ]);
    }
    t.print();
    t.write_csv(&out_dir().join("fig2_right.csv")).unwrap();
}
