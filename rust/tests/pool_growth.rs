//! Pool-growth wall: the shared worker pool grows lazily — a thread is
//! spawned only when a run asks for more concurrency than there are
//! parked workers (up to the 256-thread cap), and the pool never
//! shrinks. These grids prove the growth path is invisible to the
//! numerics: sweeping one handle's worker count up, down, and back up
//! again (so calls land on a cold pool, a growing pool, and an
//! over-provisioned pool) always reproduces the single-worker output
//! and modeled traffic bit for bit. The caller-assist `w = 1` path —
//! which never touches the shared pool at all — is pinned against the
//! per-call scoped oracle separately. The audit half of this grid
//! (item→slot fingerprints across the same growth sweep) lives in
//! `rust/tests/audit.rs::growth_grid_fingerprints_are_worker_count_invariant`.

use flashattn::attn::batched::{flash2_backward_batched, flash2_forward_batched};
use flashattn::attn::distributed::flash_forward_sharded;
use flashattn::attn::flash::Blocks;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

fn rand(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = SplitMix64::new(seed);
    Tensor::randn(shape, &mut rng, 1.0)
}

/// One batched forward + backward pass: outputs and aggregate traffic.
fn batched_pass(exec: &Exec) -> (Vec<Vec<f32>>, u64) {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0x60_1);
    let k = rand(&[b, h, n, d], 0x60_2);
    let v = rand(&[b, h, n, d], 0x60_3);
    let dout = rand(&[b, h, n, d], 0x60_4);
    let cfg = AttnConfig::new().causal();
    let mut hbm = Hbm::new();
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, exec, &mut hbm)
        .expect("fault-free")
        .0;
    let g = flash2_backward_batched(
        &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, exec, &mut hbm,
    )
    .expect("fault-free")
    .0;
    (
        vec![fwd.o.data, fwd.stats.lse, g.dq.data, g.dk.data, g.dv.data],
        hbm.accesses(),
    )
}

#[test]
fn growth_sweep_never_changes_outputs_or_traffic() {
    // 2·2 slices × 8 row blocks = 32 items, so worker counts up to 32
    // all get real concurrency. The sweep deliberately rises, falls,
    // and rises again: the pool only ever grows, so later small-w calls
    // run on an over-provisioned pool and later large-w calls force
    // fresh spawns mid-stream. None of it may show in the results.
    let base = batched_pass(&Exec::new(1));
    for &w in &[1usize, 2, 3, 5, 8, 13, 21, 32, 16, 4, 1, 32] {
        assert_eq!(batched_pass(&Exec::new(w)), base, "fresh handle w={w}");
    }
    // The same sweep through one long-lived handle (with_workers), so
    // parked workers from earlier calls serve later ones.
    let handle = Exec::new(1);
    for &w in &[1usize, 5, 32, 2, 21, 1] {
        assert_eq!(batched_pass(&handle.clone().with_workers(w)), base, "reused handle w={w}");
    }
}

#[test]
fn caller_assist_w1_matches_the_scoped_oracle() {
    // workers = 1 never touches the shared pool: the calling thread
    // drains everything itself. That path must be bitwise identical to
    // the per-call scoped oracle at w = 1 — and stay that way after the
    // shared pool has been grown by unrelated larger runs.
    let scoped = batched_pass(&Exec::scoped(1));
    assert_eq!(batched_pass(&Exec::new(1)), scoped, "cold caller-assist path");
    let _ = batched_pass(&Exec::new(16));
    assert_eq!(batched_pass(&Exec::new(1)), scoped, "caller-assist after pool growth");
}

#[test]
fn oversubscribed_workers_are_clamped_to_items() {
    // Asking for far more workers than items (and more than the pool
    // cap) must neither deadlock nor perturb results: w clamps to the
    // item count, and helpers past the cap queue behind parked threads.
    let base = batched_pass(&Exec::new(1));
    for &w in &[33usize, 64, 257, 10_000] {
        assert_eq!(batched_pass(&Exec::new(w)), base, "oversubscribed w={w}");
    }
}

#[test]
fn growth_is_schedule_agnostic() {
    // Interleave a second schedule (ring-sharded forward) with the
    // batched growth sweep: workers parked by one schedule serve the
    // other, at every pool size along the way.
    let (n, d, shards) = (64usize, 8usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x61_1);
    let k = rand(&[n, d], 0x61_2);
    let v = rand(&[n, d], 0x61_3);
    let cfg = AttnConfig::new().causal();
    let ring = |exec: &Exec| {
        let (out, _) =
            flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, exec).expect("fault-free");
        (out.o.data, out.l, out.m)
    };
    let batched_base = batched_pass(&Exec::new(1));
    let ring_base = ring(&Exec::new(1));
    for &w in &[2usize, 7, 24, 3, 32] {
        let exec = Exec::new(w);
        assert_eq!(ring(&exec), ring_base, "ring w={w}");
        assert_eq!(batched_pass(&exec), batched_base, "batched w={w}");
    }
}
