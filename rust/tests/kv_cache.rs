//! Property tests for the paged KV cache (`attn::kv_cache`): the
//! TGI-style ragged-batch lifecycle — append / filter / concatenate —
//! must preserve exact tile contents, filtered-out pages must never be
//! read (counted-access assertion), and a cache grown through an
//! arbitrary join/leave history must replay bitwise against a fresh
//! cache fed the same rows.

use flashattn::attn::kv_cache::{KvBatch, RequestCache};
use flashattn::sim::hbm::Hbm;
use flashattn::util::rng::SplitMix64;

/// Deterministic per-request row stream: request `id`, row `pos`.
fn rows_for(id: u64, lo: usize, count: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut ks = Vec::with_capacity(count * d);
    let mut vs = Vec::with_capacity(count * d);
    for pos in lo..lo + count {
        let mut rk = SplitMix64::new(id.wrapping_mul(1_000_003) ^ (pos as u64) ^ 0xC0FF);
        let mut rv = SplitMix64::new(id.wrapping_mul(2_000_003) ^ (pos as u64));
        ks.extend(rk.normal_vec(d, 1.0));
        vs.extend(rv.normal_vec(d, 1.0));
    }
    (ks, vs)
}

/// Read every page of a cache back out through the counted tile
/// accessors, reassembling the flat [len, d] K and V images.
fn read_back(cache: &RequestCache, hbm: &mut Hbm) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::with_capacity(cache.len() * cache.d());
    let mut v = Vec::with_capacity(cache.len() * cache.d());
    for t in 0..cache.pages() {
        k.extend_from_slice(cache.k_tile(t, hbm));
        v.extend_from_slice(cache.v_tile(t, hbm));
    }
    (k, v)
}

#[test]
fn ragged_appends_round_trip_bitwise_with_exact_page_geometry_and_traffic() {
    let (b_c, d) = (8usize, 4usize);
    let mut cache = RequestCache::new(b_c, d);
    let mut hbm = Hbm::new();
    let mut flat_k = Vec::new();
    let mut flat_v = Vec::new();
    let mut len = 0usize;
    // Chunks chosen to hit: fill-within-page, exact page boundary,
    // page-straddling burst, and the single-token decode append.
    for take in [3usize, 5, 8, 11, 1, 1, 6] {
        let (ks, vs) = rows_for(1, len, take, d);
        cache.append_kv(&ks, &vs, take, &mut hbm);
        flat_k.extend_from_slice(&ks);
        flat_v.extend_from_slice(&vs);
        len += take;
        assert_eq!(cache.len(), len);
        assert_eq!(cache.pages(), len.div_ceil(b_c), "page count after {len} rows");
    }
    // Append traffic: every element stored exactly once, nothing moved.
    assert_eq!(hbm.accesses(), (2 * len * d) as u64, "append writes each element once");
    assert_eq!(hbm.loads, 0, "append never reads");
    // Only the last page may be partial.
    for p in 0..cache.pages() {
        let expect = if p + 1 < cache.pages() { b_c } else { len - p * b_c };
        assert_eq!(cache.page_rows(p), expect, "page {p}");
    }
    // Counted read-back reassembles the exact flat image...
    let mut rd = Hbm::new();
    let (k_img, v_img) = read_back(&cache, &mut rd);
    assert_eq!(k_img, flat_k);
    assert_eq!(v_img, flat_v);
    assert_eq!(rd.accesses(), (2 * len * d) as u64, "tile reads stream each element once");
    // ...and the uncounted snapshot marshal is the same bytes for free.
    let before = rd.accesses();
    assert_eq!(cache.snapshot_k(), flat_k);
    assert_eq!(cache.snapshot_v(), flat_v);
    assert_eq!(rd.accesses(), before, "snapshots are uncounted marshals");
}

#[test]
fn filter_keeps_exact_contents_and_never_reads_the_dropped_pages() {
    let (b_c, d) = (4usize, 8usize);
    let mut batch = KvBatch::new(b_c, d);
    let mut hbm = Hbm::new();
    let lens = [(10u64, 9usize), (11, 4), (12, 17), (13, 1)];
    for &(id, n) in &lens {
        batch.admit(id);
        let (ks, vs) = rows_for(id, 0, n, d);
        batch.append_kv(id, &ks, &vs, n, &mut hbm);
    }
    let snap_before: Vec<(u64, Vec<f32>, Vec<f32>)> = batch
        .ids()
        .iter()
        .map(|&id| {
            let c = batch.get(id).unwrap();
            (id, c.snapshot_k(), c.snapshot_v())
        })
        .collect();

    // Drop 11 and 13 (the TGI filter on request exit). Zero traffic:
    // page ownership moves, no element is read or written.
    let t0 = hbm.accesses();
    let batch = batch.filter(&[10, 12]);
    assert_eq!(hbm.accesses(), t0, "filter is a metadata move");
    assert_eq!(batch.ids(), vec![10, 12], "batch order preserved");
    assert_eq!(batch.total_tokens(), 9 + 17);

    // Kept caches are bitwise untouched...
    for &(id, ref ks, ref vs) in snap_before.iter().filter(|(id, ..)| *id == 10 || *id == 12) {
        let c = batch.get(id).unwrap();
        assert_eq!(&c.snapshot_k(), ks);
        assert_eq!(&c.snapshot_v(), vs);
    }
    // ...and a full counted sweep of the surviving batch accounts for
    // exactly the kept pages: if any dropped page were still reachable
    // and read, the element count could not balance.
    let mut rd = Hbm::new();
    for &id in &batch.ids() {
        read_back(batch.get(id).unwrap(), &mut rd);
    }
    assert_eq!(rd.accesses(), (2 * (9 + 17) * d) as u64, "only kept pages are readable");
    assert!(batch.get(11).is_none() && batch.get(13).is_none());
}

#[test]
fn concatenate_preserves_order_ids_and_exact_tile_contents() {
    let (b_c, d) = (8usize, 4usize);
    let mut a = KvBatch::new(b_c, d);
    let mut b = KvBatch::new(b_c, d);
    let mut hbm = Hbm::new();
    for &(id, n) in &[(1u64, 11usize), (2, 3)] {
        a.admit(id);
        let (ks, vs) = rows_for(id, 0, n, d);
        a.append_kv(id, &ks, &vs, n, &mut hbm);
    }
    for &(id, n) in &[(7u64, 8usize), (8, 5)] {
        b.admit(id);
        let (ks, vs) = rows_for(id, 0, n, d);
        b.append_kv(id, &ks, &vs, n, &mut hbm);
    }
    let t0 = hbm.accesses();
    let joined = KvBatch::concatenate(a, b);
    assert_eq!(hbm.accesses(), t0, "concatenate is a metadata move");
    assert_eq!(joined.ids(), vec![1, 2, 7, 8], "a-then-b order");
    assert_eq!(joined.total_tokens(), 11 + 3 + 8 + 5);
    for &(id, n) in &[(1u64, 11usize), (2, 3), (7, 8), (8, 5)] {
        let (ks, vs) = rows_for(id, 0, n, d);
        let c = joined.get(id).unwrap();
        assert_eq!(c.snapshot_k(), ks, "request {id} K image");
        assert_eq!(c.snapshot_v(), vs, "request {id} V image");
    }
}

/// The serving lifecycle property: a cache grown through an arbitrary
/// join → append → leave → append history holds, for every surviving
/// request, exactly the bytes a fresh cache fed the same rows holds.
#[test]
fn grown_then_filtered_batch_replays_bitwise_against_fresh_caches() {
    let (b_c, d) = (4usize, 4usize);
    let mut batch = KvBatch::new(b_c, d);
    let mut hbm = Hbm::new();
    let mut produced: Vec<(u64, usize)> = Vec::new();
    // Phase 1: three requests join and prefill.
    for &(id, n) in &[(100u64, 6usize), (101, 13), (102, 2)] {
        batch.admit(id);
        let (ks, vs) = rows_for(id, 0, n, d);
        batch.append_kv(id, &ks, &vs, n, &mut hbm);
        produced.push((id, n));
    }
    // Phase 2: a few decode steps append one row to everyone.
    for _step in 0..3 {
        for entry in produced.iter_mut() {
            let (ks, vs) = rows_for(entry.0, entry.1, 1, d);
            batch.append_kv(entry.0, &ks, &vs, 1, &mut hbm);
            entry.1 += 1;
        }
    }
    // Phase 3: 101 finishes and is filtered out; a new request joins.
    let mut batch = batch.filter(&[100, 102]);
    produced.retain(|(id, _)| *id != 101);
    batch.admit(103);
    let (ks, vs) = rows_for(103, 0, 7, d);
    batch.append_kv(103, &ks, &vs, 7, &mut hbm);
    produced.push((103, 7));
    // Phase 4: more decode steps for the survivors.
    for _step in 0..2 {
        for entry in produced.iter_mut() {
            let (ks, vs) = rows_for(entry.0, entry.1, 1, d);
            batch.append_kv(entry.0, &ks, &vs, 1, &mut hbm);
            entry.1 += 1;
        }
    }
    // Every survivor replays bitwise against a fresh single-shot cache.
    for &(id, n) in &produced {
        let (ks, vs) = rows_for(id, 0, n, d);
        let mut fresh = RequestCache::new(b_c, d);
        fresh.append_kv(&ks, &vs, n, &mut Hbm::new());
        let grown = batch.get(id).unwrap();
        assert_eq!(grown.len(), n, "request {id}");
        assert_eq!(grown.snapshot_k(), fresh.snapshot_k(), "request {id} K image");
        assert_eq!(grown.snapshot_v(), fresh.snapshot_v(), "request {id} V image");
        // Page-for-page, not just flattened: the tile geometry itself
        // must be history-independent.
        assert_eq!(grown.pages(), fresh.pages(), "request {id} page count");
        let (gk, gv) = read_back(grown, &mut Hbm::new());
        let (fk, fv) = read_back(&fresh, &mut Hbm::new());
        assert_eq!((gk, gv), (fk, fv), "request {id} tiles");
    }
}
