//! Integration tests for the paper's IO-complexity results: the analytic
//! closed forms in sim::cost must match the *instrumented* algorithm
//! mirrors access-for-access, and the asymptotics of Theorems 2/5 and
//! Propositions 3/4 must hold over parameter sweeps.

use flashattn::attn::batched::{
    block_sparse2_backward_batched, block_sparse2_forward_batched, flash2_backward_batched,
    flash2_backward_many, flash2_forward_batched, flash2_forward_many, AttnGradSlice, AttnSlice,
};
use flashattn::attn::block_sparse::{
    block_sparse2_backward, block_sparse2_forward, block_sparse_forward,
};
use flashattn::attn::distributed::{
    block_sparse_forward_sharded_tree, flash_backward_sharded, flash_forward_sharded,
    flash_forward_sharded_tree, merge_partials, shard_ranges,
};
use flashattn::attn::faults::{FaultKind, FaultPlan, FaultSite};
use flashattn::attn::flash::{flash_backward, flash_forward, Blocks};
use flashattn::attn::flash2::{flash2_backward, flash2_decode, flash2_forward};
use flashattn::attn::masks::BlockMask;
use flashattn::attn::standard::{standard_backward, standard_forward};
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::cost;
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::prop::{for_each_case, usize_in};
use flashattn::util::rng::SplitMix64;

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = SplitMix64::new(seed);
    (
        Tensor::randn(&[n, d], &mut rng, 1.0),
        Tensor::randn(&[n, d], &mut rng, 1.0),
        Tensor::randn(&[n, d], &mut rng, 1.0),
    )
}

#[test]
fn standard_fwd_analytic_matches_instrumented_exactly() {
    for (n, d) in [(64usize, 8usize), (128, 16), (96, 32)] {
        let (q, k, v) = qkv(n, d, 0);
        let mut hbm = Hbm::new();
        standard_forward(&q, &k, &v, &AttnConfig::default(), &mut hbm);
        let pred = cost::standard_fwd(n as u64, d as u64, false, false);
        assert_eq!(hbm.accesses(), pred.hbm_elems, "n={n} d={d}");
    }
}

#[test]
fn standard_bwd_analytic_matches_instrumented_exactly() {
    let (n, d) = (64usize, 8usize);
    let (q, k, v) = qkv(n, d, 1);
    let dout = Tensor::full(&[n, d], 1.0);
    let mut hbm = Hbm::new();
    standard_backward(&q, &k, &v, &dout, &AttnConfig::default(), &mut hbm);
    let pred = cost::standard_bwd(n as u64, d as u64, false, false);
    assert_eq!(hbm.accesses(), pred.hbm_elems);
}

#[test]
fn flash_fwd_analytic_matches_instrumented_exactly() {
    // Divisible tilings: the closed form is exact.
    for (n, d, br, bc) in [(128usize, 16usize, 16usize, 32usize), (256, 8, 32, 64), (64, 4, 8, 8)] {
        let (q, k, v) = qkv(n, d, 2);
        let blocks = Blocks::explicit(br, bc);
        let mut hbm = Hbm::new();
        flash_forward(&q, &k, &v, &AttnConfig::default(), blocks, &mut hbm);
        let pred = cost::flash_fwd(n as u64, d as u64, blocks, false, false);
        assert_eq!(hbm.accesses(), pred.hbm_elems, "n={n} d={d} blocks=({br},{bc})");
    }
}

#[test]
fn flash_bwd_analytic_matches_instrumented_exactly() {
    let (n, d, br, bc) = (128usize, 16usize, 16usize, 32usize);
    let (q, k, v) = qkv(n, d, 3);
    let blocks = Blocks::explicit(br, bc);
    let cfg = AttnConfig::default();
    let fwd = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
    let dout = Tensor::full(&[n, d], 1.0);
    let mut hbm = Hbm::new();
    flash_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut hbm);
    let pred = cost::flash_bwd(n as u64, d as u64, blocks, false, false);
    assert_eq!(hbm.accesses(), pred.hbm_elems);
}

#[test]
fn flash2_fwd_analytic_matches_instrumented_exactly() {
    // Divisible tilings: the closed form is exact, for any worker count.
    for (n, d, br, bc) in [(128usize, 16usize, 16usize, 32usize), (256, 8, 32, 64), (64, 4, 8, 8)] {
        let (q, k, v) = qkv(n, d, 12);
        let blocks = Blocks::explicit(br, bc);
        let cfg = AttnConfig::default();
        for workers in [1usize, 3, 8] {
            let mut hbm = Hbm::new();
            flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(workers), &mut hbm);
            let pred = cost::flash2_fwd(n as u64, d as u64, blocks, false, false);
            assert_eq!(
                hbm.accesses(),
                pred.hbm_elems,
                "n={n} d={d} blocks=({br},{bc}) workers={workers}"
            );
        }
    }
}

#[test]
fn flash2_bwd_analytic_matches_instrumented_exactly() {
    // Divisible tilings: the closed form is exact, for any worker count.
    for (n, d, br, bc) in [(128usize, 16usize, 16usize, 32usize), (256, 8, 32, 64), (64, 4, 8, 8)] {
        let (q, k, v) = qkv(n, d, 15);
        let blocks = Blocks::explicit(br, bc);
        let cfg = AttnConfig::default();
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(2), &mut Hbm::new());
        let dout = Tensor::full(&[n, d], 1.0);
        for workers in [1usize, 3, 8] {
            let mut hbm = Hbm::new();
            flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::new(workers),
                &mut hbm,
            );
            let pred = cost::flash2_bwd(n as u64, d as u64, blocks, false, false);
            assert_eq!(
                hbm.accesses(),
                pred.hbm_elems,
                "n={n} d={d} blocks=({br},{bc}) workers={workers}"
            );
        }
    }
}

fn qkv4(b: usize, h: usize, n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = SplitMix64::new(seed);
    (
        Tensor::randn(&[b, h, n, d], &mut rng, 1.0),
        Tensor::randn(&[b, h, n, d], &mut rng, 1.0),
        Tensor::randn(&[b, h, n, d], &mut rng, 1.0),
    )
}

#[test]
fn flash2_fwd_batched_analytic_matches_instrumented_exactly() {
    // The tentpole IO constraint, asserted access-for-access: folding
    // batch·head·row-block work into one pool must leave the per-slice
    // HBM count untouched, so measured == slices × per-slice closed form,
    // for any worker count.
    for (b, h, n, d, br, bc) in [
        (2usize, 3usize, 128usize, 16usize, 16usize, 32usize),
        (1, 4, 64, 8, 8, 8),
        (3, 1, 96, 4, 32, 16),
    ] {
        let (q, k, v) = qkv4(b, h, n, d, 21);
        let blocks = Blocks::explicit(br, bc);
        for workers in [1usize, 3, 8] {
            let mut hbm = Hbm::new();
            flash2_forward_batched(
                &q, &k, &v, &AttnConfig::default(), blocks, &Exec::new(workers), &mut hbm,
            )
            .expect("fault-free");
            let pred =
                cost::flash2_fwd_batched((b * h) as u64, n as u64, d as u64, blocks, false, false);
            assert_eq!(
                hbm.accesses(),
                pred.hbm_elems,
                "b={b} h={h} n={n} d={d} blocks=({br},{bc}) workers={workers}"
            );
            assert_eq!(
                pred.hbm_elems,
                (b * h) as u64
                    * cost::flash2_fwd(n as u64, d as u64, blocks, false, false).hbm_elems
            );
        }
    }
}

#[test]
fn flash2_bwd_batched_analytic_matches_instrumented_exactly() {
    for (b, h, n, d, br, bc) in
        [(2usize, 3usize, 128usize, 16usize, 16usize, 32usize), (1, 4, 64, 8, 8, 8)]
    {
        let (q, k, v) = qkv4(b, h, n, d, 22);
        let blocks = Blocks::explicit(br, bc);
        let cfg = AttnConfig::default();
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(2), &mut Hbm::new())
            .expect("fault-free")
            .0;
        let dout = Tensor::full(&[b, h, n, d], 1.0);
        for workers in [1usize, 3, 8] {
            let mut hbm = Hbm::new();
            flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &Exec::new(workers), &mut hbm,
            )
            .expect("fault-free");
            let pred =
                cost::flash2_bwd_batched((b * h) as u64, n as u64, d as u64, blocks, false, false);
            assert_eq!(
                hbm.accesses(),
                pred.hbm_elems,
                "b={b} h={h} n={n} d={d} blocks=({br},{bc}) workers={workers}"
            );
        }
    }
}

#[test]
fn flash2_batched_causal_analytic_matches_instrumented() {
    // Causal tile-skip accounting survives batching (fwd and bwd).
    let (b, h, n, d) = (2usize, 2usize, 128usize, 8usize);
    let (q, k, v) = qkv4(b, h, n, d, 23);
    let blocks = Blocks::explicit(16, 16);
    let cfg = AttnConfig::new().causal();
    let mut h_fwd = Hbm::new();
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(4), &mut h_fwd)
        .expect("fault-free")
        .0;
    assert_eq!(
        h_fwd.accesses(),
        cost::flash2_fwd_batched(4, n as u64, d as u64, blocks, true, false).hbm_elems
    );
    let dout = Tensor::full(&[b, h, n, d], 1.0);
    let mut h_bwd = Hbm::new();
    flash2_backward_batched(
        &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &Exec::new(4), &mut h_bwd,
    )
    .expect("fault-free");
    assert_eq!(
        h_bwd.accesses(),
        cost::flash2_bwd_batched(4, n as u64, d as u64, blocks, true, false).hbm_elems
    );
}

#[test]
fn flash2_bwd_causal_analytic_matches_instrumented() {
    let (n, d, br, bc) = (128usize, 8usize, 16usize, 16usize);
    let (q, k, v) = qkv(n, d, 16);
    let blocks = Blocks::explicit(br, bc);
    let cfg = AttnConfig::new().causal();
    let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(4), &mut Hbm::new());
    let dout = Tensor::full(&[n, d], 1.0);
    let mut hbm = Hbm::new();
    flash2_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::new(4), &mut hbm);
    let pred = cost::flash2_bwd(n as u64, d as u64, blocks, true, false);
    assert_eq!(hbm.accesses(), pred.hbm_elems);
}

#[test]
fn flash2_bwd_measured_strictly_below_algorithm4() {
    // The backward acceptance claim, measured end to end: on the same
    // (square) tiling the two-phase kernel's instrumented traffic is both
    // equal to its closed form and strictly below the instrumented
    // Algorithm 4 reference — it deleted the per-tile dQ round trips.
    let (n, d) = (256usize, 16usize);
    let (q, k, v) = qkv(n, d, 17);
    let blocks = Blocks::explicit(32, 32); // T_r = T_c = 8, divisible
    let cfg = AttnConfig::default();
    let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(4), &mut Hbm::new());
    let dout = Tensor::full(&[n, d], 1.0);

    let mut h_fast = Hbm::new();
    flash2_backward(
        &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::new(4), &mut h_fast,
    );
    let mut h_slow = Hbm::new();
    flash_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut h_slow);

    assert_eq!(
        h_fast.accesses(),
        cost::flash2_bwd(n as u64, d as u64, blocks, false, false).hbm_elems,
        "flash2_backward must match its closed form"
    );
    assert_eq!(
        h_slow.accesses(),
        cost::flash_bwd(n as u64, d as u64, blocks, false, false).hbm_elems,
        "flash_backward must match its closed form"
    );
    assert!(
        h_fast.accesses() < h_slow.accesses(),
        "flash2_bwd {} must be strictly below Algorithm 4's {}",
        h_fast.accesses(),
        h_slow.accesses()
    );
}

#[test]
fn flash2_fwd_shard_analytic_matches_instrumented_offset_kernel() {
    // The kv_offset plumbing's accounting mirror: an instrumented flash2
    // run on a key shard (global column offset, causal tile-skip judged
    // in global coordinates) must match the closed form
    // access-for-access on divisible tilings. A high shard under a
    // causal mask loads strictly fewer K/V tiles than a low one.
    let (n, d) = (128usize, 8usize);
    let (q, k, v) = qkv(n, d, 31);
    let blocks = Blocks::explicit(16, 16);
    let mut measured = Vec::new();
    for (lo, hi) in [(0usize, 64usize), (64, 128), (32, 96)] {
        for causal in [false, true] {
            let cfg = AttnConfig { causal, kv_offset: lo, ..Default::default() };
            let ks = k.slice_rows(lo, hi);
            let vs = v.slice_rows(lo, hi);
            let mut hbm = Hbm::new();
            flash2_forward(&q, &ks, &vs, &cfg, blocks, &Exec::new(3), &mut hbm);
            let pred =
                cost::flash2_fwd_shard(n as u64, d as u64, blocks, lo as u64, hi as u64, causal);
            assert_eq!(hbm.accesses(), pred.hbm_elems, "lo={lo} hi={hi} causal={causal}");
            measured.push((lo, causal, hbm.accesses()));
        }
    }
    let at = |lo: usize, causal: bool| {
        measured.iter().find(|&&(l, c, _)| l == lo && c == causal).unwrap().2
    };
    assert!(at(64, true) < at(0, true), "high causal shard must skip more tiles");
    assert_eq!(at(64, false), at(0, false), "non-causal shards of equal width match");
}

#[test]
fn flash2_causal_analytic_matches_instrumented() {
    let (n, d, br, bc) = (128usize, 8usize, 16usize, 16usize);
    let (q, k, v) = qkv(n, d, 13);
    let blocks = Blocks::explicit(br, bc);
    let mut hbm = Hbm::new();
    flash2_forward(&q, &k, &v, &AttnConfig::new().causal(), blocks, &Exec::new(4), &mut hbm);
    let pred = cost::flash2_fwd(n as u64, d as u64, blocks, true, false);
    assert_eq!(hbm.accesses(), pred.hbm_elems);
}

#[test]
fn flash2_writes_o_and_stats_exactly_once_vs_flash_per_iteration() {
    // The tentpole IO claim, measured: Algorithm 1 stores the O/l/m
    // accumulators once per live (i, j) pair plus the init — Θ(T_c·N·d) —
    // while the Q-outer kernel stores O and the logsumexp exactly once:
    // N·d + N floats, regardless of tiling or worker count.
    let (n, d) = (256usize, 16usize);
    let (q, k, v) = qkv(n, d, 14);
    let blocks = Blocks::explicit(32, 32); // T_r = T_c = 8, divisible
    let t_c = 8u64;

    let mut h_flash = Hbm::new();
    flash_forward(&q, &k, &v, &AttnConfig::default(), blocks, &mut h_flash);
    let mut h_flash2 = Hbm::new();
    flash2_forward(&q, &k, &v, &AttnConfig::default(), blocks, &Exec::new(4), &mut h_flash2);

    let nd = (n * d) as u64;
    assert_eq!(h_flash2.stores, nd + n as u64, "flash2 single epilogue write");
    assert_eq!(
        h_flash.stores,
        (1 + t_c) * (nd + 2 * n as u64),
        "flash rewrites accumulators once per K/V block"
    );
    assert!(h_flash.stores > t_c * h_flash2.stores / 2);
    assert_eq!(cost::flash2_fwd_stores(n as u64, d as u64), h_flash2.stores);
    assert_eq!(cost::flash_fwd_stores(n as u64, d as u64, blocks, false), h_flash.stores);
}

#[test]
fn flash_fwd_causal_analytic_matches_instrumented() {
    let (n, d, br, bc) = (128usize, 8usize, 16usize, 16usize);
    let (q, k, v) = qkv(n, d, 4);
    let blocks = Blocks::explicit(br, bc);
    let cfg = AttnConfig::new().causal();
    let mut hbm = Hbm::new();
    flash_forward(&q, &k, &v, &cfg, blocks, &mut hbm);
    let pred = cost::flash_fwd(n as u64, d as u64, blocks, true, false);
    assert_eq!(hbm.accesses(), pred.hbm_elems);
}

#[test]
fn block_sparse_analytic_matches_instrumented() {
    let (n, d, br, bc) = (128usize, 8usize, 16usize, 16usize);
    let (q, k, v) = qkv(n, d, 5);
    let blocks = Blocks::explicit(br, bc);
    let mask = BlockMask::butterfly(n / br, n / bc);
    let mut hbm = Hbm::new();
    block_sparse_forward(&q, &k, &v, &mask, &AttnConfig::default(), blocks, &mut hbm);
    let pred = cost::block_sparse_fwd(n as u64, d as u64, blocks, &mask, false);
    assert_eq!(hbm.accesses(), pred.hbm_elems);
}

#[test]
fn block_sparse2_fwd_analytic_matches_instrumented_exactly() {
    // The sparse pair's IO wall: measured traffic of the fast sparse
    // forward == the closed form, access for access — butterfly and
    // local_global patterns, causal on/off, divisible AND ragged
    // tilings, any worker count.
    for (n, d, br, bc) in
        [(128usize, 8usize, 16usize, 16usize), (256, 16, 32, 64), (100, 8, 16, 24)]
    {
        let (q, k, v) = qkv(n, d, 41);
        let blocks = Blocks::explicit(br, bc);
        let (t_r, t_c) = (n.div_ceil(br), n.div_ceil(bc));
        for mask in [BlockMask::butterfly(t_r, t_c), BlockMask::local_global(t_r, t_c, 1, 1)] {
            for causal in [false, true] {
                let cfg = AttnConfig { causal, ..Default::default() };
                for workers in [1usize, 3, 8] {
                    let mut hbm = Hbm::new();
                    block_sparse2_forward(
                        &q, &k, &v, &mask, &cfg, blocks, &Exec::new(workers), &mut hbm,
                    );
                    let pred = cost::block_sparse2_fwd(
                        n as u64, n as u64, d as u64, blocks, &mask, causal, false,
                    );
                    assert_eq!(
                        hbm.accesses(),
                        pred.hbm_elems,
                        "n={n} d={d} blocks=({br},{bc}) causal={causal} workers={workers}"
                    );
                }
            }
        }
    }
}

#[test]
fn block_sparse2_bwd_analytic_matches_instrumented_exactly() {
    for (n, d, br, bc) in [(128usize, 8usize, 16usize, 16usize), (96, 16, 32, 32), (100, 8, 16, 24)]
    {
        let (q, k, v) = qkv(n, d, 42);
        let blocks = Blocks::explicit(br, bc);
        let (t_r, t_c) = (n.div_ceil(br), n.div_ceil(bc));
        let dout = Tensor::full(&[n, d], 1.0);
        for mask in [BlockMask::butterfly(t_r, t_c), BlockMask::local_global(t_r, t_c, 1, 1)] {
            for causal in [false, true] {
                let cfg = AttnConfig { causal, ..Default::default() };
                let fwd = block_sparse2_forward(
                    &q, &k, &v, &mask, &cfg, blocks, &Exec::new(2), &mut Hbm::new(),
                );
                for workers in [1usize, 3, 8] {
                    let mut hbm = Hbm::new();
                    block_sparse2_backward(
                        &q, &k, &v, &fwd.o, &dout, fwd.stats(), &mask, &cfg, blocks,
                        &Exec::new(workers), &mut hbm,
                    );
                    let pred = cost::block_sparse2_bwd(
                        n as u64, n as u64, d as u64, blocks, &mask, causal, false,
                    );
                    assert_eq!(
                        hbm.accesses(),
                        pred.hbm_elems,
                        "n={n} d={d} blocks=({br},{bc}) causal={causal} workers={workers}"
                    );
                }
            }
        }
    }
}

#[test]
fn proposition4_block_sparse2_traffic_strictly_decreasing_in_sparsity() {
    // Prop. 4 on the production kernels, measured: clearing live blocks
    // strictly decreases instrumented traffic in BOTH passes, and dense
    // masks reproduce the dense pair's counts exactly.
    let (n, d) = (128usize, 8usize);
    let (q, k, v) = qkv(n, d, 43);
    let blocks = Blocks::explicit(16, 16);
    let dout = Tensor::full(&[n, d], 1.0);
    let cfg = AttnConfig::default();
    let measure = |mask: &BlockMask| -> (u64, u64) {
        let mut hf = Hbm::new();
        let fwd = block_sparse2_forward(&q, &k, &v, mask, &cfg, blocks, &Exec::new(2), &mut hf);
        let mut hb = Hbm::new();
        block_sparse2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), mask, &cfg, blocks, &Exec::new(2), &mut hb,
        );
        (hf.accesses(), hb.accesses())
    };
    let mut mask = BlockMask::dense(8, 8);
    let (dense_f, dense_b) = measure(&mask);
    // Dense mask: exactly the dense pair's instrumented traffic.
    let mut hf2 = Hbm::new();
    let fwd2 = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(2), &mut hf2);
    let mut hb2 = Hbm::new();
    flash2_backward(
        &q, &k, &v, &fwd2.o, &dout, fwd2.stats(), &cfg, blocks, &Exec::new(2), &mut hb2,
    );
    assert_eq!(dense_f, hf2.accesses(), "dense-mask fwd != flash2 fwd traffic");
    assert_eq!(dense_b, hb2.accesses(), "dense-mask bwd != flash2 bwd traffic");
    // Strict decrease, block by block.
    let (mut prev_f, mut prev_b) = (dense_f, dense_b);
    for (i, j) in [(0usize, 5usize), (4, 4), (7, 1), (2, 6), (6, 0)] {
        mask.set(i, j, false);
        let (f, b) = measure(&mask);
        assert!(f < prev_f, "fwd not strictly below after clearing ({i},{j})");
        assert!(b < prev_b, "bwd not strictly below after clearing ({i},{j})");
        (prev_f, prev_b) = (f, b);
    }
}

#[test]
fn block_sparse2_sharded_mask_slice_analytic_matches_instrumented() {
    // The sharded-mask-slice case: an instrumented sparse kernel run on
    // a tile-aligned key shard (global mask window via kv_offset) must
    // match `block_sparse2_fwd_slice` access for access, and the
    // shards' streaming terms partition the unsharded kernel's.
    let (n, d) = (128usize, 8usize);
    let (q, k, v) = qkv(n, d, 44);
    let blocks = Blocks::explicit(16, 16);
    let mask = BlockMask::butterfly(8, 8);
    for causal in [false, true] {
        let mut kv_terms = 0u64;
        for (lo, hi) in [(0usize, 64usize), (64, 96), (96, 128)] {
            let cfg = AttnConfig { causal, kv_offset: lo, ..Default::default() };
            let ks = k.slice_rows(lo, hi);
            let vs = v.slice_rows(lo, hi);
            let mut hbm = Hbm::new();
            block_sparse2_forward(&q, &ks, &vs, &mask, &cfg, blocks, &Exec::new(3), &mut hbm);
            let pred = cost::block_sparse2_fwd_slice(
                n as u64, d as u64, blocks, &mask, causal, false, lo as u64, hi as u64,
            );
            assert_eq!(hbm.accesses(), pred.hbm_elems, "lo={lo} hi={hi} causal={causal}");
            kv_terms += hbm.accesses() - (2 * n * d + n) as u64;
        }
        let mut h_full = Hbm::new();
        let cfg = AttnConfig { causal, ..Default::default() };
        block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &Exec::new(3), &mut h_full);
        assert_eq!(
            kv_terms,
            h_full.accesses() - (2 * n * d + n) as u64,
            "shard K/V streaming terms must partition the unsharded kernel's (causal={causal})"
        );
    }
}

#[test]
fn theorem2_flash_quadratic_in_n_inverse_in_m() {
    // Θ(N²d²/M): fix d; doubling N quadruples the dominant term; doubling
    // B_c (∝ M) halves it.
    let d = 64u64;
    let c = |n: u64, bc: usize| {
        cost::flash_fwd(n, d, Blocks::explicit(64, bc), false, false).hbm_elems as f64
    };
    let r_n = c(16384, 128) / c(8192, 128);
    assert!((3.5..4.3).contains(&r_n), "N-scaling {r_n}");
    let r_m = c(16384, 128) / c(16384, 256);
    assert!((1.7..2.2).contains(&r_m), "M-scaling {r_m}");
}

#[test]
fn theorem2_standard_quadratic_in_n_independent_of_m() {
    let d = 64u64;
    let c = |n: u64| cost::standard_fwd(n, d, false, false).hbm_elems as f64;
    let r = c(16384) / c(8192);
    assert!((3.8..4.1).contains(&r), "{r}");
}

#[test]
fn proposition3_lower_bound_at_m_equals_nd() {
    // With M = Nd (whole input in SRAM), flash still moves Ω(Nd): inputs
    // and outputs must cross HBM at least once.
    let (n, d) = (1024u64, 64u64);
    let blocks = Blocks::from_sram((n * d) as usize, d as usize, n as usize);
    let c = cost::flash_fwd(n, d, blocks, false, false);
    assert!(c.hbm_elems >= 3 * n * d, "below the Ω(Nd) floor: {}", c.hbm_elems);
}

#[test]
fn proposition4_block_sparse_proportional_to_sparsity() {
    for_each_case("prop4", 8, |rng| {
        let t = usize_in(rng, 4, 16);
        let n = (t * 32) as u64;
        let blocks = Blocks::explicit(32, 32);
        let density = 0.2 + 0.8 * rng.next_f64();
        let mut mask = BlockMask::zeros(t, t);
        for i in 0..t {
            mask.set(i, i, true);
            for j in 0..t {
                if rng.next_f64() < density {
                    mask.set(i, j, true);
                }
            }
        }
        let dense = BlockMask::dense(t, t);
        let cs = cost::block_sparse_fwd(n, 64, blocks, &mask, false).hbm_elems as f64;
        let cd = cost::block_sparse_fwd(n, 64, blocks, &dense, false).hbm_elems as f64;
        let ratio = cs / cd;
        let s = mask.sparsity();
        assert!((ratio - s).abs() < 0.3, "ratio {ratio} vs s {s}");
    });
}

#[test]
fn theorem1_flash_exact_over_random_workloads() {
    // Exactness + O(N) extra memory, property-tested across shapes.
    for_each_case("thm1", 10, |rng| {
        let n = usize_in(rng, 4, 64);
        let d = *flashattn::util::prop::choose(rng, &[2usize, 4, 8, 16]);
        let q = Tensor::randn(&[n, d], rng, 1.0);
        let k = Tensor::randn(&[n, d], rng, 1.0);
        let v = Tensor::randn(&[n, d], rng, 1.0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::explicit(usize_in(rng, 1, n), usize_in(rng, 1, n));
        let std = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        let fla = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
        assert!(std.o.max_abs_diff(&fla.o) < 1e-4);
        assert_eq!(fla.l.len() + fla.m.len(), 2 * n); // O(N) statistics
    });
}

// ---------------------------------------------------------------------
// Pooled and sharded driver coverage (invariant R4): every production
// forward/backward entry point is pinned to the cost model — directly
// where the driver exposes its aggregate counter, at retry-item
// granularity where it models traffic per device instead.
// ---------------------------------------------------------------------

#[test]
fn flash2_fwd_many_ragged_slices_analytic_matches_instrumented_exactly() {
    // flash2_forward_many: heterogeneous shapes and configs through one
    // pool; measured traffic == the sum of the per-slice closed forms,
    // for any worker count.
    let d = 8usize;
    let blocks = Blocks::explicit(8, 8);
    let shapes = [(64usize, false), (32, true), (48, false)];
    let data: Vec<(Tensor, Tensor, Tensor)> =
        shapes.iter().enumerate().map(|(i, &(n, _))| qkv(n, d, 70 + i as u64)).collect();
    let slices: Vec<AttnSlice<'_>> = data
        .iter()
        .zip(&shapes)
        .map(|((q, k, v), &(n, causal))| AttnSlice {
            q: &q.data,
            k: &k.data,
            v: &v.data,
            n,
            n_k: n,
            d,
            cfg: AttnConfig { causal, ..Default::default() },
        })
        .collect();
    let pred: u64 = shapes
        .iter()
        .map(|&(n, causal)| cost::flash2_fwd(n as u64, d as u64, blocks, causal, false).hbm_elems)
        .sum();
    for workers in [1usize, 2, 5] {
        let mut hbm = Hbm::new();
        let ex = Exec::new(workers);
        let (outs, _) =
            flash2_forward_many(&slices, blocks, &ex, &mut hbm).expect("fault-free");
        assert_eq!(outs.len(), shapes.len());
        assert_eq!(hbm.accesses(), pred, "workers={workers}");
    }
}

#[test]
fn flash2_bwd_many_ragged_slices_analytic_matches_instrumented_exactly() {
    let d = 8usize;
    let blocks = Blocks::explicit(8, 8);
    let shapes = [(64usize, false), (32, true)];
    let data: Vec<(Tensor, Tensor, Tensor)> =
        shapes.iter().enumerate().map(|(i, &(n, _))| qkv(n, d, 80 + i as u64)).collect();
    let fwd_slices: Vec<AttnSlice<'_>> = data
        .iter()
        .zip(&shapes)
        .map(|((q, k, v), &(n, causal))| AttnSlice {
            q: &q.data,
            k: &k.data,
            v: &v.data,
            n,
            n_k: n,
            d,
            cfg: AttnConfig { causal, ..Default::default() },
        })
        .collect();
    let (outs, _) = flash2_forward_many(&fwd_slices, blocks, &Exec::new(2), &mut Hbm::new())
        .expect("fault-free");
    let douts: Vec<Tensor> = shapes.iter().map(|&(n, _)| Tensor::full(&[n, d], 1.0)).collect();
    let grad_slices: Vec<AttnGradSlice<'_>> = data
        .iter()
        .zip(&shapes)
        .zip(outs.iter().zip(&douts))
        .map(|(((q, k, v), &(n, causal)), (out, dout))| AttnGradSlice {
            q: &q.data,
            k: &k.data,
            v: &v.data,
            o: &out.o.data,
            dout: &dout.data,
            lse: &out.lse,
            n,
            n_k: n,
            d,
            cfg: AttnConfig { causal, ..Default::default() },
        })
        .collect();
    let pred: u64 = shapes
        .iter()
        .map(|&(n, causal)| cost::flash2_bwd(n as u64, d as u64, blocks, causal, false).hbm_elems)
        .sum();
    for workers in [1usize, 2, 5] {
        let mut hbm = Hbm::new();
        let (grads, _) = flash2_backward_many(&grad_slices, blocks, &Exec::new(workers), &mut hbm)
            .expect("fault-free");
        assert_eq!(grads.len(), shapes.len());
        assert_eq!(hbm.accesses(), pred, "workers={workers}");
    }
}

#[test]
fn block_sparse2_fwd_batched_per_head_masks_analytic_matches_instrumented() {
    // block_sparse2_forward_batched with one mask per head: measured ==
    // batch × Σ_heads per-slice sparse closed form, any worker count.
    let (b, h, n, d) = (2usize, 2usize, 64usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / 8, n / 8);
    let masks = [BlockMask::butterfly(t_r, t_c), BlockMask::local_global(t_r, t_c, 1, 1)];
    let (q, k, v) = qkv4(b, h, n, d, 71);
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let per_batch: u64 = masks
            .iter()
            .map(|m| {
                cost::block_sparse2_fwd(n as u64, n as u64, d as u64, blocks, m, causal, false)
                    .hbm_elems
            })
            .sum();
        let pred = b as u64 * per_batch;
        for workers in [1usize, 3, 8] {
            let mut hbm = Hbm::new();
            block_sparse2_forward_batched(
                &q, &k, &v, &masks, &cfg, blocks, &Exec::new(workers), &mut hbm,
            )
            .expect("fault-free");
            assert_eq!(hbm.accesses(), pred, "causal={causal} workers={workers}");
        }
    }
}

#[test]
fn block_sparse2_bwd_batched_per_head_masks_analytic_matches_instrumented() {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / 8, n / 8);
    let masks = [BlockMask::butterfly(t_r, t_c), BlockMask::local_global(t_r, t_c, 1, 1)];
    let (q, k, v) = qkv4(b, h, n, d, 72);
    let dout = Tensor::full(&[b, h, n, d], 1.0);
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let fwd = block_sparse2_forward_batched(
            &q, &k, &v, &masks, &cfg, blocks, &Exec::new(2), &mut Hbm::new(),
        )
        .expect("fault-free")
        .0;
        let per_batch: u64 = masks
            .iter()
            .map(|m| {
                cost::block_sparse2_bwd(n as u64, n as u64, d as u64, blocks, m, causal, false)
                    .hbm_elems
            })
            .sum();
        let pred = b as u64 * per_batch;
        for workers in [1usize, 3, 8] {
            let mut hbm = Hbm::new();
            block_sparse2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &masks, &cfg, blocks, &Exec::new(workers),
                &mut hbm,
            )
            .expect("fault-free");
            assert_eq!(hbm.accesses(), pred, "causal={causal} workers={workers}");
        }
    }
}

#[test]
fn flash_fwd_sharded_retry_item_matches_closed_form_access_for_access() {
    // The ring driver models its traffic per device rather than through
    // one aggregate counter, so the wall pins it at item granularity: a
    // faulted row-block item re-streams exactly its closed form (Q row
    // block + every live shard's K/V tiles + the O/lse store), and
    // recovery is bitwise.
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let (q, k, v) = qkv(n, d, 73);
    let rb = 3usize;
    let (nu, du, rbu) = (n as u64, d as u64, 3u64);
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let baseline = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
            .expect("fault-free")
            .0;
        let plan = FaultPlan::none().with(FaultSite::RingFwd, rb, 0, FaultKind::WorkerPanic);
        let guarded = Exec::new(2).with_plan(&plan).validated();
        let (out, report) = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &guarded)
            .expect("must recover");
        assert_eq!(out.o.data, baseline.o.data, "causal={causal}");
        let stream: u64 = shard_ranges(n, blocks.b_c, shards)
            .iter()
            .map(|sh| {
                cost::flash2_fwd_shard_item(nu, du, blocks, rbu, sh.lo as u64, sh.hi as u64, causal)
            })
            .sum();
        let br = blocks.b_r as u64;
        let expected = br * du + stream + (br * du + br);
        assert_eq!(report.retry_hbm.accesses(), expected, "causal={causal}");
    }
}

#[test]
fn flash_bwd_sharded_retry_item_matches_closed_form_access_for_access() {
    // dQ mirror of the forward test: Q/dO/D/L row block in, the shard
    // streams, dQ out — the ring backward's per-item closed form.
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let (q, k, v) = qkv(n, d, 74);
    let dout = Tensor::full(&[n, d], 1.0);
    let rb = 2usize;
    let (nu, du, rbu) = (n as u64, d as u64, 2u64);
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let fwd = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
            .expect("fault-free")
            .0;
        let baseline = flash_backward_sharded(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards, &Exec::new(1),
        )
        .expect("fault-free")
        .0;
        let plan = FaultPlan::none().with(FaultSite::RingDq, rb, 0, FaultKind::WorkerPanic);
        let guarded = Exec::new(2).with_plan(&plan).validated();
        let (grads, report) = flash_backward_sharded(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards, &guarded,
        )
        .expect("must recover");
        assert_eq!(grads.dq.data, baseline.dq.data, "causal={causal}");
        assert_eq!(grads.dk.data, baseline.dk.data, "causal={causal}");
        assert_eq!(grads.dv.data, baseline.dv.data, "causal={causal}");
        let stream: u64 = shard_ranges(n, blocks.b_c, shards)
            .iter()
            .map(|sh| {
                cost::flash2_fwd_shard_item(nu, du, blocks, rbu, sh.lo as u64, sh.hi as u64, causal)
            })
            .sum();
        let br = blocks.b_r as u64;
        let expected = (2 * br * du + 2 * br) + stream + br * du;
        assert_eq!(report.retry_hbm.accesses(), expected, "causal={causal}");
    }
}

#[test]
fn flash_fwd_sharded_tree_partial_retry_matches_closed_form() {
    // A tree partial item streams exactly its own shard: the retry of
    // flat item (shard 1, row block 2) pays that shard's K/V tiles plus
    // the Q load and partial store, nothing of shard 0.
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let t_r = n / blocks.b_r;
    let (q, k, v) = qkv(n, d, 75);
    let cfg = AttnConfig::default();
    let baseline = flash_forward_sharded_tree(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
        .expect("fault-free")
        .0;
    let item = t_r + 2; // flat (live shard, row block) = (1, 2)
    let plan = FaultPlan::none().with(FaultSite::TreePartial, item, 0, FaultKind::WorkerPanic);
    let (out, report) = flash_forward_sharded_tree(
        &q, &k, &v, &cfg, blocks, shards, &Exec::new(2).with_plan(&plan),
    )
    .expect("must recover");
    assert_eq!(out.o.data, baseline.o.data);
    assert_eq!(out.m, baseline.m);
    assert_eq!(out.l, baseline.l);
    let sh = shard_ranges(n, blocks.b_c, shards)[1];
    let (lo, hi) = (sh.lo as u64, sh.hi as u64);
    let stream = cost::flash2_fwd_shard_item(n as u64, d as u64, blocks, 2, lo, hi, false);
    let br = blocks.b_r as u64;
    let du = d as u64;
    assert_eq!(report.retry_hbm.accesses(), br * du + stream + (br * du + br));
}

#[test]
fn block_sparse_fwd_sharded_tree_matches_per_shard_closed_forms() {
    // The sparse tree driver runs the sparse kernel whole per shard and
    // reports no aggregate counter, so the wall reconstructs its exact
    // per-shard work: each shard's instrumented traffic must equal
    // `block_sparse2_fwd_slice` on that key range, and re-merging the
    // partials must reproduce block_sparse_forward_sharded_tree's output
    // bitwise.
    let (n, d, shards) = (64usize, 8usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / 8, n / 8);
    let mask = BlockMask::local_global(t_r, t_c, 1, 1);
    let (q, k, v) = qkv(n, d, 76);
    let cfg = AttnConfig::default();
    let driver =
        block_sparse_forward_sharded_tree(&q, &k, &v, &mask, &cfg, blocks, shards, &Exec::new(2))
            .expect("fault-free")
            .0;
    let mut partials = Vec::new();
    for sh in shard_ranges(n, blocks.b_c, shards) {
        let ks = k.slice_rows(sh.lo, sh.hi);
        let vs = v.slice_rows(sh.lo, sh.hi);
        let shard_cfg = cfg.for_shard(sh.lo);
        let mut hbm = Hbm::new();
        let p =
            block_sparse2_forward(&q, &ks, &vs, &mask, &shard_cfg, blocks, &Exec::new(2), &mut hbm);
        let pred = cost::block_sparse2_fwd_slice(
            n as u64, d as u64, blocks, &mask, false, false, sh.lo as u64, sh.hi as u64,
        );
        assert_eq!(hbm.accesses(), pred.hbm_elems, "shard {}..{}", sh.lo, sh.hi);
        partials.push(p.into_attn_output());
    }
    let merged = partials.into_iter().reduce(|a, b| merge_partials(&a, &b)).unwrap();
    assert_eq!(driver.o.data, merged.o.data, "driver != re-merged shard partials");
    assert_eq!(driver.m, merged.m);
    assert_eq!(driver.l, merged.l);
}

/// Split-KV decode traffic: the instrumented `flash2_decode` must match
/// `cost::flash2_decode` access-for-access over the (n_k, span size,
/// causal) grid, for every worker count — the per-span Q replication,
/// the per-live-tile K/V streams and score spill+reload, and the single
/// epilogue store are all modeled exactly, ragged edges included.
#[test]
fn flash2_decode_analytic_matches_instrumented_exactly() {
    for &(n, n_k, d, b_c, span_tiles) in &[
        (1usize, 96usize, 16usize, 8usize, 2usize),
        (1, 100, 8, 8, 3), // ragged last column tile AND ragged last span
        (3, 64, 16, 16, 1),
        (2, 72, 8, 8, 100), // one span covers everything
    ] {
        let mut rng = SplitMix64::new(0xDE + n_k as u64);
        let q = Tensor::randn(&[n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[n_k, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n_k, d], &mut rng, 1.0);
        let blocks = Blocks::explicit(b_c, b_c);
        for causal in [false, true] {
            let cfg = if causal { AttnConfig::new().causal() } else { AttnConfig::new() };
            let pred = cost::flash2_decode(
                n as u64,
                n_k as u64,
                d as u64,
                blocks,
                span_tiles as u64,
                causal,
                false,
            );
            for workers in [1usize, 3, 8] {
                let mut hbm = Hbm::new();
                flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &Exec::new(workers), &mut hbm)
                    .expect("fault-free decode");
                assert_eq!(
                    hbm.accesses(),
                    pred.hbm_elems,
                    "n={n} n_k={n_k} d={d} b_c={b_c} span_tiles={span_tiles} \
                     causal={causal} workers={workers}"
                );
            }
        }
    }
}

/// The decode item/merge split of the same closed form: summing
/// `cost::flash2_decode_item` over every span plus the merge-side
/// reloads and the epilogue reproduces the kernel's measured total —
/// the decomposition the fault plane charges per retried span.
#[test]
fn flash2_decode_item_forms_partition_the_measured_total() {
    let (n, n_k, d, b_c, span_tiles) = (2usize, 100usize, 8usize, 8usize, 3usize);
    let mut rng = SplitMix64::new(0xDEC0);
    let q = Tensor::randn(&[n, d], &mut rng, 1.0);
    let k = Tensor::randn(&[n_k, d], &mut rng, 1.0);
    let v = Tensor::randn(&[n_k, d], &mut rng, 1.0);
    let blocks = Blocks::explicit(b_c, b_c);
    let cfg = AttnConfig::new();
    let mut hbm = Hbm::new();
    flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &Exec::new(2), &mut hbm)
        .expect("fault-free decode");
    let t_c = n_k.div_ceil(b_c) as u64;
    let spans = t_c.div_ceil(span_tiles as u64);
    let items: u64 = (0..spans)
        .map(|sp| {
            cost::flash2_decode_item(
                n as u64,
                n_k as u64,
                d as u64,
                blocks,
                span_tiles as u64,
                sp,
                false,
            )
        })
        .sum();
    let merge: u64 = (0..t_c)
        .map(|j| {
            let c0 = j * b_c as u64;
            let bc = ((j + 1) * b_c as u64).min(n_k as u64) - c0;
            n as u64 * bc + bc * d as u64
        })
        .sum();
    let epilogue = (n * d + n) as u64;
    assert_eq!(hbm.accesses(), items + merge + epilogue);
}
