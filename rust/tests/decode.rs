//! The split-KV decode parity wall: `flash2_decode` must be **bitwise
//! identical** to `flash2_forward` for the same config and block
//! geometry — for any worker count, any span size, causal or not,
//! sharded (`kv_offset`) or not, dropout or not — and a decode over a
//! paged-cache snapshot must be bitwise the decode over the original
//! flat rows. These are equalities, not tolerances: the decode merge
//! replays the fused sweep's own absorb body in global tile order, so
//! any drift is a bug, not rounding.

use flashattn::attn::flash::Blocks;
use flashattn::attn::flash2::{flash2_decode, flash2_forward, Flash2Output};
use flashattn::attn::kv_cache::RequestCache;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

fn qkv(n: usize, n_k: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = SplitMix64::new(seed);
    (
        Tensor::randn(&[n, d], &mut rng, 1.0),
        Tensor::randn(&[n_k, d], &mut rng, 1.0),
        Tensor::randn(&[n_k, d], &mut rng, 1.0),
    )
}

fn assert_bitwise(a: &Flash2Output, b: &Flash2Output, ctx: &str) {
    assert_eq!(a.o.data, b.o.data, "O drifted: {ctx}");
    let same_lse = a.lse.len() == b.lse.len()
        && a.lse.iter().zip(&b.lse).all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same_lse, "lse drifted: {ctx}");
}

/// The tentpole equality: every (kv_len, span size, worker count,
/// causal) cell of the grid reproduces the fused kernel bit for bit,
/// including the 1-span (span covers everything) and span-ragged (last
/// span shorter) edges and the ragged last column tile.
#[test]
fn decode_bitwise_matches_fused_forward_across_the_grid() {
    for &(n, n_k, d, b_c) in
        &[(1usize, 96usize, 16usize, 8usize), (1, 100, 8, 8), (3, 64, 16, 16), (2, 7, 8, 4)]
    {
        let (q, k, v) = qkv(n, n_k, d, 0xD0 + n as u64);
        let blocks = Blocks::explicit(b_c, b_c);
        for causal in [false, true] {
            let cfg =
                if causal { AttnConfig::new().causal() } else { AttnConfig::new() };
            let oracle = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new());
            let t_c = n_k.div_ceil(b_c);
            // span_tiles = 1 (one tile per item), a mid size that leaves
            // the last span ragged, exactly-covering, and over-covering
            // (single span).
            for span_tiles in [1usize, 2, 3, t_c, t_c + 7] {
                for workers in [1usize, 2, 5] {
                    let exec = Exec::new(workers);
                    let mut hbm = Hbm::new();
                    let (out, report) =
                        flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &exec, &mut hbm)
                            .expect("fault-free decode");
                    assert_eq!(report.faults(), 0);
                    let ctx = format!(
                        "n={n} n_k={n_k} d={d} b_c={b_c} causal={causal} \
                         span_tiles={span_tiles} workers={workers}"
                    );
                    assert_bitwise(&out, &oracle, &ctx);
                }
            }
        }
    }
}

/// Sharded decode: a nonzero `kv_offset` (the ring/sequence-parallel
/// layout) must flow through scoring, the dropout counter hash (which
/// keys on *global* columns), and the merge identically in both
/// kernels. The causal case with an offset beyond the local rows is the
/// fully-masked edge: both kernels must agree on the defined zero-row /
/// `-inf` result.
#[test]
fn decode_matches_fused_forward_under_kv_offset_shards() {
    let (n, n_k, d, b_c) = (2usize, 48usize, 8usize, 8usize);
    let (q, k, v) = qkv(n, n_k, d, 7);
    let blocks = Blocks::explicit(b_c, b_c);
    for offset in [8usize, 20, 40] {
        // A shard of columns [offset, offset+n_k) of a longer sequence;
        // dropout makes the global column index value-relevant.
        let cfg = AttnConfig::new().dropout(0.25, 0xD15C).kv_len(offset + n_k).for_shard(offset);
        let oracle = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(2), &mut Hbm::new());
        for span_tiles in [1usize, 2] {
            let (out, _) =
                flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &Exec::new(5), &mut Hbm::new())
                    .expect("fault-free decode");
            assert_bitwise(&out, &oracle, &format!("offset={offset} span_tiles={span_tiles}"));
        }
        // Causal + far offset: every key is above the local diagonal.
        let masked = AttnConfig::new().causal().kv_len(offset + n_k).for_shard(offset);
        let oracle =
            flash2_forward(&q, &k, &v, &masked, blocks, &Exec::new(1), &mut Hbm::new());
        assert!(oracle.lse.iter().all(|&x| x == f32::NEG_INFINITY));
        let (out, _) =
            flash2_decode(&q, &k, &v, &masked, blocks, 2, &Exec::new(2), &mut Hbm::new())
                .expect("fully-masked decode");
        assert_bitwise(&out, &oracle, &format!("fully-masked offset={offset}"));
    }
}

/// Padding mask (`kv_len` short of the buffered keys): padded tiles are
/// streamed-and-masked, never skipped, in both kernels — values AND
/// traffic must agree.
#[test]
fn decode_matches_fused_forward_with_padded_kv() {
    let (n, n_k, d, b_c) = (1usize, 64usize, 16usize, 8usize);
    let (q, k, v) = qkv(n, n_k, d, 11);
    let blocks = Blocks::explicit(b_c, b_c);
    for kv_len in [1usize, 13, 40, 64] {
        let cfg = AttnConfig::new().kv_len(kv_len);
        let oracle = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new());
        let (out, _) =
            flash2_decode(&q, &k, &v, &cfg, blocks, 2, &Exec::new(2), &mut Hbm::new())
                .expect("fault-free decode");
        assert_bitwise(&out, &oracle, &format!("kv_len={kv_len}"));
    }
}

/// Dropout rides the same per-(row, column) counter hash in the shared
/// absorb body, so even the sampled-mask regime is a bitwise equality.
#[test]
fn decode_matches_fused_forward_with_dropout() {
    let (n, n_k, d, b_c) = (2usize, 40usize, 8usize, 8usize);
    let (q, k, v) = qkv(n, n_k, d, 13);
    let blocks = Blocks::explicit(b_c, b_c);
    let cfg = AttnConfig::new().dropout(0.3, 0xD120);
    let oracle = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new());
    for workers in [1usize, 2, 5] {
        let (out, _) =
            flash2_decode(&q, &k, &v, &cfg, blocks, 1, &Exec::new(workers), &mut Hbm::new())
                .expect("fault-free decode");
        assert_bitwise(&out, &oracle, &format!("dropout workers={workers}"));
    }
}

/// Span/worker invariance stated directly: every (span_tiles, workers)
/// cell produces one identical byte-level result.
#[test]
fn decode_result_is_invariant_across_span_sizes_and_worker_counts() {
    let (n, n_k, d, b_c) = (1usize, 72usize, 16usize, 8usize);
    let (q, k, v) = qkv(n, n_k, d, 17);
    let blocks = Blocks::explicit(b_c, b_c);
    let cfg = AttnConfig::new();
    let mut reference: Option<Flash2Output> = None;
    for span_tiles in [1usize, 2, 4, 9] {
        for workers in [1usize, 2, 5] {
            let (out, _) =
                flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &Exec::new(workers), &mut Hbm::new())
                    .expect("fault-free decode");
            match &reference {
                None => reference = Some(out),
                Some(r) => {
                    assert_bitwise(&out, r, &format!("span_tiles={span_tiles} workers={workers}"))
                }
            }
        }
    }
}

/// Degenerate inputs share the fused kernel's defined semantics: no
/// keys → zero rows, lse = -inf, zero traffic.
#[test]
fn decode_with_no_keys_is_the_defined_empty_result() {
    let (q, k, v) = qkv(1, 0, 8, 19);
    let mut hbm = Hbm::new();
    let (out, report) = flash2_decode(
        &q,
        &k,
        &v,
        &AttnConfig::new(),
        Blocks::explicit(8, 8),
        2,
        &Exec::new(2),
        &mut hbm,
    )
    .expect("empty decode");
    assert!(out.o.data.iter().all(|&x| x == 0.0));
    assert!(out.lse.iter().all(|&x| x == f32::NEG_INFINITY));
    assert_eq!(hbm.accesses(), 0, "empty decode must cost nothing");
    assert_eq!(report.faults(), 0);
    let oracle =
        flash2_forward(&q, &k, &v, &AttnConfig::new(), Blocks::explicit(8, 8), &Exec::new(1), &mut Hbm::new());
    assert_bitwise(&out, &oracle, "n_k=0");
}

/// The serving path end to end: rows appended raggedly into a paged
/// cache, snapshotted back out, and decoded — bitwise the decode over
/// the original flat rows (pages preserve exact tile contents and the
/// snapshot is a bit-exact marshal).
#[test]
fn decode_over_a_paged_cache_snapshot_matches_decode_over_flat_rows() {
    let (n, n_k, d, b_c) = (1usize, 53usize, 8usize, 8usize);
    let (q, k, v) = qkv(n, n_k, d, 23);
    let blocks = Blocks::explicit(b_c, b_c);
    let cfg = AttnConfig::new();
    let (flat, _) = flash2_decode(&q, &k, &v, &cfg, blocks, 2, &Exec::new(2), &mut Hbm::new())
        .expect("flat decode");

    let mut cache = RequestCache::new(b_c, d);
    let mut side = Hbm::new();
    // Ragged appends: prefill-sized chunk, then token-by-token, then a
    // page-straddling burst.
    let mut at = 0usize;
    for take in [19usize, 1, 1, 11, 1, 20] {
        let take = take.min(n_k - at);
        cache.append_kv(
            &k.data[at * d..(at + take) * d],
            &v.data[at * d..(at + take) * d],
            take,
            &mut side,
        );
        at += take;
    }
    assert_eq!(at, n_k);
    assert_eq!(cache.len(), n_k);
    let kc = Tensor::from_vec(&[n_k, d], cache.snapshot_k());
    let vc = Tensor::from_vec(&[n_k, d], cache.snapshot_v());
    let (cached, _) = flash2_decode(&q, &kc, &vc, &cfg, blocks, 2, &Exec::new(5), &mut Hbm::new())
        .expect("cached decode");
    assert_bitwise(&cached, &flat, "paged-cache snapshot");
}
