//! Race-auditor wall (`cargo test -q -p flashattn --features audit`):
//! machine-checks the execution plane's signature rule — *workers race
//! for work items, never for output slots* — for every pooled schedule.
//!
//! For each schedule the same workload is replayed across worker counts
//! (and, for the ring forward, shard counts) with fingerprint recording
//! on; the recorded [`PoolRun`]s must be **equal**, proving the
//! item→slot mapping is pure partition geometry. Slot disjointness and
//! exactly-once commits are asserted inside the pool on every one of
//! these runs (a violation panics), so a green wall certifies all three
//! audit properties for the batched, block-sparse, ring and tree pools.

#![cfg(feature = "audit")]

use std::sync::Mutex;

use flashattn::attn::audit::{
    self, adversarial_orders, explore_schedules, permutations, ItemClaims, PoolRun, SlotClaim,
};
use flashattn::attn::batched::{
    block_sparse2_backward_batched, block_sparse2_forward_batched, flash2_backward_batched,
    flash2_forward_batched,
};
use flashattn::attn::block_sparse::{block_sparse2_backward, block_sparse2_forward};
use flashattn::attn::distributed::{
    flash_backward_sharded, flash_forward_sharded, flash_forward_sharded_tree,
};
use flashattn::attn::faults::{FaultKind, FaultPlan, FaultSite};
use flashattn::attn::flash::Blocks;
use flashattn::attn::flash2::flash2_decode;
use flashattn::attn::masks::BlockMask;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

/// Recording drains one global registry; tests that record must not
/// interleave with each other.
static GATE: Mutex<()> = Mutex::new(());

fn rand(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = SplitMix64::new(seed);
    Tensor::randn(shape, &mut rng, 1.0)
}

/// Run `f` with fingerprint recording on and drain what it recorded.
fn record(f: impl FnOnce()) -> Vec<PoolRun> {
    audit::start_recording();
    f();
    audit::stop_recording()
}

#[test]
fn batched_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xA0D_1);
    let k = rand(&[b, h, n, d], 0xA0D_2);
    let v = rand(&[b, h, n, d], 0xA0D_3);
    let dout = rand(&[b, h, n, d], 0xA0D_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
        .expect("fault-free")
        .0;

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut hbm);
            let _ = flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &exec, &mut hbm,
            );
        });
        // One forward pool plus the two backward phases.
        assert_eq!(runs.len(), 3, "w={workers}");
        match &baseline {
            None => {
                // The fingerprint has the expected partition geometry:
                // one forward item per (slice, row block), each claiming
                // its O window and its lse window.
                let t_r = n / blocks.b_r;
                assert_eq!(runs[0].items.len(), b * h * t_r);
                for (i, (idx, id, claims)) in runs[0].items.iter().enumerate() {
                    assert_eq!(*idx, i);
                    assert_eq!(*id, (i / t_r, i % t_r));
                    assert_eq!(claims, &vec![("o", blocks.b_r * d), ("lse", blocks.b_r)]);
                }
                baseline = Some(runs);
            }
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn sparse_batched_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (b, h, n, d) = (2usize, 1usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[b, h, n, d], 0x5A_1);
    let k = rand(&[b, h, n, d], 0x5A_2);
    let v = rand(&[b, h, n, d], 0x5A_3);
    let dout = rand(&[b, h, n, d], 0x5A_4);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(0, 2, false);
    mask.set(3, 1, false);
    let masks = [mask];
    let cfg = AttnConfig::default();
    let fwd = block_sparse2_forward_batched(
        &q, &k, &v, &masks, &cfg, blocks, &Exec::new(1), &mut Hbm::new(),
    )
    .expect("fault-free")
    .0;

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ =
                block_sparse2_forward_batched(&q, &k, &v, &masks, &cfg, blocks, &exec, &mut hbm);
            let _ = block_sparse2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &masks, &cfg, blocks, &exec, &mut hbm,
            );
        });
        assert_eq!(runs.len(), 3, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn single_slice_sparse_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d) = (32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[n, d], 0x1B_1);
    let k = rand(&[n, d], 0x1B_2);
    let v = rand(&[n, d], 0x1B_3);
    let dout = rand(&[n, d], 0x1B_4);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(1, 3, false);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd =
        block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &Exec::new(1), &mut Hbm::new());

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ = block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &exec, &mut hbm);
            let _ = block_sparse2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &mask, &cfg, blocks, &exec, &mut hbm,
            );
        });
        // SparseFwd, then the SparseDq and SparseDkv backward phases —
        // the row/column-block pools that replaced the raw scopes.
        assert_eq!(runs.len(), 3, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn ring_forward_mapping_is_worker_and_shard_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d) = (64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x21_1);
    let k = rand(&[n, d], 0x21_2);
    let v = rand(&[n, d], 0x21_3);
    let cfg = AttnConfig { causal: true, ..Default::default() };

    // Ring forward items are Q row blocks streaming every live shard:
    // the fingerprint must be invariant across worker counts *and*
    // shard counts.
    let mut baseline: Option<Vec<PoolRun>> = None;
    for shards in [1usize, 2, 4] {
        for workers in [1usize, 2, 5] {
            let exec = Exec::new(workers);
            let runs = record(|| {
                let _ = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &exec);
            });
            assert_eq!(runs.len(), 1, "shards={shards} w={workers}");
            match &baseline {
                None => baseline = Some(runs),
                Some(base) => {
                    assert_eq!(&runs, base, "mapping drifted at shards={shards} w={workers}")
                }
            }
        }
    }
}

#[test]
fn ring_backward_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x3D_1);
    let k = rand(&[n, d], 0x3D_2);
    let v = rand(&[n, d], 0x3D_3);
    let dout = rand(&[n, d], 0x3D_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
        .expect("fault-free")
        .0;

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let _ = flash_backward_sharded(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards, &exec,
            );
        });
        // RingDq, then RingDkv (one item per live (shard, column block)).
        assert_eq!(runs.len(), 2, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn tree_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x7E_1);
    let k = rand(&[n, d], 0x7E_2);
    let v = rand(&[n, d], 0x7E_3);
    let cfg = AttnConfig::default();

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let _ = flash_forward_sharded_tree(&q, &k, &v, &cfg, blocks, shards, &exec);
        });
        // One TreePartial pool computes every (shard, row block) partial;
        // the merge tree itself is serial arithmetic, not a pool.
        assert_eq!(runs.len(), 1, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn fingerprints_survive_pool_reuse_and_match_scoped_oracle() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // The persistent-runtime leg of the audit wall: ONE long-lived
    // handle driving batched, ring and tree schedules back to back must
    // record exactly the fingerprints that fresh per-call handles (and
    // the scoped oracle) record — parked workers carry no state between
    // calls that could perturb the item→slot mapping.
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q4 = rand(&[b, h, n, d], 0xF1_1);
    let k4 = rand(&[b, h, n, d], 0xF1_2);
    let v4 = rand(&[b, h, n, d], 0xF1_3);
    let q = rand(&[n, d], 0xF1_4);
    let k = rand(&[n, d], 0xF1_5);
    let v = rand(&[n, d], 0xF1_6);
    let cfg = AttnConfig::new().causal();
    let run_all = |exec: &Exec| {
        record(|| {
            let mut hbm = Hbm::new();
            let _ = flash2_forward_batched(&q4, &k4, &v4, &cfg, blocks, exec, &mut hbm);
            let _ = flash_forward_sharded(&q, &k, &v, &cfg, blocks, 2, exec);
            let _ = flash_forward_sharded_tree(&q, &k, &v, &AttnConfig::new(), blocks, 2, exec);
        })
    };
    let reused = Exec::new(3);
    let first = run_all(&reused);
    assert_eq!(first.len(), 3, "batched + ring + tree pools");
    let again = run_all(&reused);
    assert_eq!(again, first, "fingerprints drifted across pool reuse");
    assert_eq!(run_all(&Exec::new(3)), first, "fresh pool handle disagrees with reused one");
    assert_eq!(run_all(&Exec::scoped(3)), first, "scoped oracle disagrees with persistent pool");
}

#[test]
fn overlapping_claims_are_rejected_with_provenance() {
    // The must-flag side of check (a), through the public pure checker:
    // two items claiming intersecting windows is exactly the class of
    // bug — a worker writing another item's slots — the auditor exists
    // to catch.
    let buf = vec![0.0f32; 8];
    let a = ItemClaims { idx: 0, id: (0, 0), claims: vec![SlotClaim::of("o", &buf[0..6])] };
    let b = ItemClaims { idx: 1, id: (0, 1), claims: vec![SlotClaim::of("o", &buf[4..8])] };
    let err = audit::check_disjoint(&[a, b]).unwrap_err();
    assert!(err.contains("items 0"), "{err}");
    assert!(err.contains("overlapping"), "{err}");

    // And the must-pass side: splitting the same buffer disjointly.
    let (lo, hi) = buf.split_at(4);
    let a = ItemClaims { idx: 0, id: (0, 0), claims: vec![SlotClaim::of("o", lo)] };
    let b = ItemClaims { idx: 1, id: (0, 1), claims: vec![SlotClaim::of("o", hi)] };
    assert!(audit::check_disjoint(&[a, b]).is_ok());
}

// ---------------------------------------------------------------------
// Schedule-space explorer: the fixed LIFO drain can no longer hide
// order-dependent nondeterminism. Each wall below replays one pooled
// workload across >= 24 distinct claim orders x workers {1, 2, 5},
// fault-free and under FaultPlan injection, asserting bitwise-identical
// outputs and identical fingerprints every time (audit::explore_schedules
// panics on the first divergence).
// ---------------------------------------------------------------------

#[test]
fn explorer_batched_schedules_are_claim_order_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // One slice, four row blocks and four column blocks: every batched
    // pool (BatchedFwd, BatchedDq, BatchedDkv) has exactly 4 items, so
    // permutations(4) explores each site's claim space exhaustively.
    let (b, h, n, d) = (1usize, 1usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xE0_1);
    let k = rand(&[b, h, n, d], 0xE0_2);
    let v = rand(&[b, h, n, d], 0xE0_3);
    let dout = rand(&[b, h, n, d], 0xE0_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
        .expect("fault-free")
        .0;
    let work = |exec: &Exec| {
        let mut hbm = Hbm::new();
        let f = flash2_forward_batched(&q, &k, &v, &cfg, blocks, exec, &mut hbm)
            .expect("recovers")
            .0;
        let g = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, exec, &mut hbm,
        )
        .expect("recovers")
        .0;
        (f.o.data, f.stats.lse, g.dq.data, g.dk.data, g.dv.data, hbm.accesses())
    };
    let orders = permutations(4);
    assert!(orders.len() >= 24);
    let workers = [1usize, 2, 5];

    explore_schedules("batched/fault-free", &Exec::new(1), &orders, &workers, work);
    // Same orders through the per-call scope mode: spawn/join boundaries
    // instead of park/wake boundaries.
    explore_schedules("batched/scoped", &Exec::scoped(1), &orders, &workers, work);
    // Retry requeues re-enter the claim competition: panic, dropped
    // merge, and poison-then-guardrail retries at fixed (item, attempt)
    // coordinates must not open an order-dependent window.
    let plan = FaultPlan::none()
        .with(FaultSite::BatchedFwd, 1, 0, FaultKind::WorkerPanic)
        .with(FaultSite::BatchedFwd, 2, 0, FaultKind::DroppedMerge)
        .with(FaultSite::BatchedDq, 0, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::BatchedDkv, 3, 0, FaultKind::WorkerPanic)
        .with(FaultSite::BatchedDkv, 3, 1, FaultKind::PoisonedPartial);
    let faulted = Exec::new(1).with_plan(&plan).validated();
    explore_schedules("batched/faulted", &faulted, &orders, &workers, work);
}

#[test]
fn explorer_sparse_schedules_are_claim_order_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d) = (32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[n, d], 0xE1_1);
    let k = rand(&[n, d], 0xE1_2);
    let v = rand(&[n, d], 0xE1_3);
    let dout = rand(&[n, d], 0xE1_4);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(0, 2, false);
    mask.set(3, 1, false);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd =
        block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &Exec::new(1), &mut Hbm::new());
    let work = |exec: &Exec| {
        let mut hbm = Hbm::new();
        let f = block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, exec, &mut hbm);
        let g = block_sparse2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &mask, &cfg, blocks, exec, &mut hbm,
        );
        (f.o.data, f.lse, g.dq.data, g.dk.data, g.dv.data, hbm.accesses())
    };
    let orders = permutations(4);
    let workers = [1usize, 2, 5];

    explore_schedules("sparse/fault-free", &Exec::new(1), &orders, &workers, work);
    let plan = FaultPlan::none()
        .with(FaultSite::SparseFwd, 2, 0, FaultKind::WorkerPanic)
        .with(FaultSite::SparseDq, 1, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::SparseDkv, 0, 0, FaultKind::DroppedMerge);
    let faulted = Exec::new(1).with_plan(&plan).validated();
    explore_schedules("sparse/faulted", &faulted, &orders, &workers, work);
}

#[test]
fn explorer_ring_and_tree_schedules_are_claim_order_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d, shards) = (32usize, 8usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0xE2_1);
    let k = rand(&[n, d], 0xE2_2);
    let v = rand(&[n, d], 0xE2_3);
    let dout = rand(&[n, d], 0xE2_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let tree_cfg = AttnConfig::default();
    let fwd = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
        .expect("fault-free")
        .0;
    let work = |exec: &Exec| {
        let (f, _) = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, exec)
            .expect("recovers");
        let (g, _) = flash_backward_sharded(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards, exec,
        )
        .expect("recovers");
        let (t, _) = flash_forward_sharded_tree(&q, &k, &v, &tree_cfg, blocks, shards, exec)
            .expect("recovers");
        (f.o.data, g.dq.data, g.dk.data, g.dv.data, t.o.data)
    };
    let orders = permutations(4);
    let workers = [1usize, 2, 5];

    explore_schedules("ring+tree/fault-free", &Exec::new(1), &orders, &workers, work);
    let plan = FaultPlan::none()
        .with(FaultSite::RingFwd, 0, 0, FaultKind::WorkerPanic)
        .with(FaultSite::RingDq, 2, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::RingDkv, 1, 0, FaultKind::DroppedMerge)
        .with(FaultSite::TreePartial, 1, 0, FaultKind::WorkerPanic);
    let faulted = Exec::new(1).with_plan(&plan).validated();
    explore_schedules("ring+tree/faulted", &faulted, &orders, &workers, work);
}

#[test]
fn explorer_adversarial_orders_on_a_large_pool() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // 2*2 slices x 4 row blocks = 16 forward items: far past exhaustive
    // range, so sample the schedule space with seeded shuffles instead.
    // The smoke budget is bounded; the release audit-explore CI job
    // raises it through EXPLORE_ADVERSARIAL.
    let budget: usize = std::env::var("EXPLORE_ADVERSARIAL")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xE3_1);
    let k = rand(&[b, h, n, d], 0xE3_2);
    let v = rand(&[b, h, n, d], 0xE3_3);
    let dout = rand(&[b, h, n, d], 0xE3_4);
    let cfg = AttnConfig::default();
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
        .expect("fault-free")
        .0;
    let work = |exec: &Exec| {
        let mut hbm = Hbm::new();
        let f = flash2_forward_batched(&q, &k, &v, &cfg, blocks, exec, &mut hbm)
            .expect("recovers")
            .0;
        let g = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, exec, &mut hbm,
        )
        .expect("recovers")
        .0;
        (f.o.data, g.dq.data, g.dk.data, g.dv.data, hbm.accesses())
    };
    let orders = adversarial_orders(16, budget, 0x5EED_06D);
    let workers = [1usize, 2, 5];
    explore_schedules("batched/adversarial", &Exec::new(1), &orders, &workers, work);
    let plan =
        FaultPlan::seeded(0xC4A05, 0.2, &[FaultKind::WorkerPanic, FaultKind::PoisonedPartial]);
    let faulted = Exec::new(1).with_plan(&plan).validated();
    explore_schedules("batched/adversarial+seeded-faults", &faulted, &orders, &workers, work);
}

#[test]
fn growth_grid_fingerprints_are_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // The audit half of the pool-growth grid (rust/tests/pool_growth.rs
    // proves outputs): demanding ever-larger worker counts from one
    // handle grows the shared pool lazily, and the recorded item->slot
    // fingerprints must never move while it grows - or shrink back when
    // a later call asks for fewer workers.
    let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xE4_1);
    let k = rand(&[b, h, n, d], 0xE4_2);
    let v = rand(&[b, h, n, d], 0xE4_3);
    let cfg = AttnConfig::default();
    let handle = Exec::new(1);
    let mut baseline: Option<Vec<PoolRun>> = None;
    for &w in &[1usize, 2, 5, 9, 16, 5, 1] {
        let exec = handle.clone().with_workers(w);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut hbm);
        });
        assert_eq!(runs.len(), 1, "w={w}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "fingerprints drifted while growing to w={w}"),
        }
    }
}

#[test]
fn decode_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // 40 keys / b_c 8 / one tile per span = 5 spans, the last tile full;
    // a second config with span_tiles 2 exercises the ragged last span.
    let (n, n_k, d) = (2usize, 40usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0xDEC_A1);
    let k = rand(&[n_k, d], 0xDEC_A2);
    let v = rand(&[n_k, d], 0xDEC_A3);
    let cfg = AttnConfig::default();
    for span_tiles in [1usize, 2] {
        let mut baseline: Option<Vec<PoolRun>> = None;
        for workers in [1usize, 2, 5] {
            let exec = Exec::new(workers);
            let runs = record(|| {
                let mut hbm = Hbm::new();
                let _ = flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &exec, &mut hbm);
            });
            assert_eq!(runs.len(), 1, "span_tiles={span_tiles} w={workers}");
            match &baseline {
                None => {
                    // One item per span, claiming exactly its spill
                    // window of concatenated [n, b_c] score tiles.
                    let t_c = n_k.div_ceil(blocks.b_c);
                    let spans = t_c.div_ceil(span_tiles);
                    assert_eq!(runs[0].items.len(), spans);
                    for (i, (idx, id, claims)) in runs[0].items.iter().enumerate() {
                        assert_eq!(*idx, i);
                        assert_eq!(*id, (0, i));
                        let tiles = ((i + 1) * span_tiles).min(t_c) - i * span_tiles;
                        assert_eq!(claims, &vec![("s", tiles * n * blocks.b_c)]);
                    }
                    baseline = Some(runs);
                }
                Some(base) => assert_eq!(
                    &runs, base,
                    "item→slot mapping drifted at span_tiles={span_tiles} w={workers}"
                ),
            }
        }
    }
}

#[test]
fn explorer_decode_schedules_are_claim_order_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // 32 keys / b_c 8 / one tile per span = exactly 4 DecodeSpan items,
    // so permutations(4) (>= 24 drain orders) explores the decode claim
    // space exhaustively, across workers {1, 2, 5}, fault-free and
    // under fixed-coordinate faults.
    let (n, n_k, d) = (1usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0xE5_1);
    let k = rand(&[n_k, d], 0xE5_2);
    let v = rand(&[n_k, d], 0xE5_3);
    let cfg = AttnConfig::default();
    let work = |exec: &Exec| {
        let mut hbm = Hbm::new();
        let out = flash2_decode(&q, &k, &v, &cfg, blocks, 1, exec, &mut hbm)
            .expect("recovers")
            .0;
        (out.o.data, out.lse, hbm.accesses())
    };
    let orders = permutations(4);
    assert!(orders.len() >= 24);
    let workers = [1usize, 2, 5];

    explore_schedules("decode/fault-free", &Exec::new(1), &orders, &workers, work);
    explore_schedules("decode/scoped", &Exec::scoped(1), &orders, &workers, work);
    // Retry requeues re-enter the claim competition at every drain
    // order: panic, poison-then-guardrail, and dropped-merge retries at
    // fixed (item, attempt) coordinates.
    let plan = FaultPlan::none()
        .with(FaultSite::DecodeSpan, 1, 0, FaultKind::WorkerPanic)
        .with(FaultSite::DecodeSpan, 2, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::DecodeSpan, 3, 0, FaultKind::DroppedMerge)
        .with(FaultSite::DecodeSpan, 3, 1, FaultKind::WorkerPanic);
    let faulted = Exec::new(1).with_plan(&plan).validated();
    explore_schedules("decode/faulted", &faulted, &orders, &workers, work);
}
