//! Race-auditor wall (`cargo test -q -p flashattn --features audit`):
//! machine-checks the execution plane's signature rule — *workers race
//! for work items, never for output slots* — for every pooled schedule.
//!
//! For each schedule the same workload is replayed across worker counts
//! (and, for the ring forward, shard counts) with fingerprint recording
//! on; the recorded [`PoolRun`]s must be **equal**, proving the
//! item→slot mapping is pure partition geometry. Slot disjointness and
//! exactly-once commits are asserted inside the pool on every one of
//! these runs (a violation panics), so a green wall certifies all three
//! audit properties for the batched, block-sparse, ring and tree pools.

#![cfg(feature = "audit")]

use std::sync::Mutex;

use flashattn::attn::audit::{self, ItemClaims, PoolRun, SlotClaim};
use flashattn::attn::batched::{
    block_sparse2_backward_batched, block_sparse2_forward_batched, flash2_backward_batched,
    flash2_forward_batched,
};
use flashattn::attn::block_sparse::{block_sparse2_backward, block_sparse2_forward};
use flashattn::attn::distributed::{
    flash_backward_sharded, flash_forward_sharded, flash_forward_sharded_tree,
};
use flashattn::attn::flash::Blocks;
use flashattn::attn::masks::BlockMask;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

/// Recording drains one global registry; tests that record must not
/// interleave with each other.
static GATE: Mutex<()> = Mutex::new(());

fn rand(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = SplitMix64::new(seed);
    Tensor::randn(shape, &mut rng, 1.0)
}

/// Run `f` with fingerprint recording on and drain what it recorded.
fn record(f: impl FnOnce()) -> Vec<PoolRun> {
    audit::start_recording();
    f();
    audit::stop_recording()
}

#[test]
fn batched_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xA0D_1);
    let k = rand(&[b, h, n, d], 0xA0D_2);
    let v = rand(&[b, h, n, d], 0xA0D_3);
    let dout = rand(&[b, h, n, d], 0xA0D_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
        .expect("fault-free")
        .0;

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut hbm);
            let _ = flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &exec, &mut hbm,
            );
        });
        // One forward pool plus the two backward phases.
        assert_eq!(runs.len(), 3, "w={workers}");
        match &baseline {
            None => {
                // The fingerprint has the expected partition geometry:
                // one forward item per (slice, row block), each claiming
                // its O window and its lse window.
                let t_r = n / blocks.b_r;
                assert_eq!(runs[0].items.len(), b * h * t_r);
                for (i, (idx, id, claims)) in runs[0].items.iter().enumerate() {
                    assert_eq!(*idx, i);
                    assert_eq!(*id, (i / t_r, i % t_r));
                    assert_eq!(claims, &vec![("o", blocks.b_r * d), ("lse", blocks.b_r)]);
                }
                baseline = Some(runs);
            }
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn sparse_batched_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (b, h, n, d) = (2usize, 1usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[b, h, n, d], 0x5A_1);
    let k = rand(&[b, h, n, d], 0x5A_2);
    let v = rand(&[b, h, n, d], 0x5A_3);
    let dout = rand(&[b, h, n, d], 0x5A_4);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(0, 2, false);
    mask.set(3, 1, false);
    let masks = [mask];
    let cfg = AttnConfig::default();
    let fwd = block_sparse2_forward_batched(
        &q, &k, &v, &masks, &cfg, blocks, &Exec::new(1), &mut Hbm::new(),
    )
    .expect("fault-free")
    .0;

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ =
                block_sparse2_forward_batched(&q, &k, &v, &masks, &cfg, blocks, &exec, &mut hbm);
            let _ = block_sparse2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &masks, &cfg, blocks, &exec, &mut hbm,
            );
        });
        assert_eq!(runs.len(), 3, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn single_slice_sparse_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d) = (32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[n, d], 0x1B_1);
    let k = rand(&[n, d], 0x1B_2);
    let v = rand(&[n, d], 0x1B_3);
    let dout = rand(&[n, d], 0x1B_4);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(1, 3, false);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd =
        block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &Exec::new(1), &mut Hbm::new());

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let mut hbm = Hbm::new();
            let _ = block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &exec, &mut hbm);
            let _ = block_sparse2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &mask, &cfg, blocks, &exec, &mut hbm,
            );
        });
        // SparseFwd, then the SparseDq and SparseDkv backward phases —
        // the row/column-block pools that replaced the raw scopes.
        assert_eq!(runs.len(), 3, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn ring_forward_mapping_is_worker_and_shard_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d) = (64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x21_1);
    let k = rand(&[n, d], 0x21_2);
    let v = rand(&[n, d], 0x21_3);
    let cfg = AttnConfig { causal: true, ..Default::default() };

    // Ring forward items are Q row blocks streaming every live shard:
    // the fingerprint must be invariant across worker counts *and*
    // shard counts.
    let mut baseline: Option<Vec<PoolRun>> = None;
    for shards in [1usize, 2, 4] {
        for workers in [1usize, 2, 5] {
            let exec = Exec::new(workers);
            let runs = record(|| {
                let _ = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &exec);
            });
            assert_eq!(runs.len(), 1, "shards={shards} w={workers}");
            match &baseline {
                None => baseline = Some(runs),
                Some(base) => {
                    assert_eq!(&runs, base, "mapping drifted at shards={shards} w={workers}")
                }
            }
        }
    }
}

#[test]
fn ring_backward_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x3D_1);
    let k = rand(&[n, d], 0x3D_2);
    let v = rand(&[n, d], 0x3D_3);
    let dout = rand(&[n, d], 0x3D_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let fwd = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
        .expect("fault-free")
        .0;

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let _ = flash_backward_sharded(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards, &exec,
            );
        });
        // RingDq, then RingDkv (one item per live (shard, column block)).
        assert_eq!(runs.len(), 2, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn tree_mapping_is_worker_count_invariant() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x7E_1);
    let k = rand(&[n, d], 0x7E_2);
    let v = rand(&[n, d], 0x7E_3);
    let cfg = AttnConfig::default();

    let mut baseline: Option<Vec<PoolRun>> = None;
    for workers in [1usize, 2, 5] {
        let exec = Exec::new(workers);
        let runs = record(|| {
            let _ = flash_forward_sharded_tree(&q, &k, &v, &cfg, blocks, shards, &exec);
        });
        // One TreePartial pool computes every (shard, row block) partial;
        // the merge tree itself is serial arithmetic, not a pool.
        assert_eq!(runs.len(), 1, "w={workers}");
        match &baseline {
            None => baseline = Some(runs),
            Some(base) => assert_eq!(&runs, base, "item→slot mapping drifted at w={workers}"),
        }
    }
}

#[test]
fn fingerprints_survive_pool_reuse_and_match_scoped_oracle() {
    let _g = GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // The persistent-runtime leg of the audit wall: ONE long-lived
    // handle driving batched, ring and tree schedules back to back must
    // record exactly the fingerprints that fresh per-call handles (and
    // the scoped oracle) record — parked workers carry no state between
    // calls that could perturb the item→slot mapping.
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q4 = rand(&[b, h, n, d], 0xF1_1);
    let k4 = rand(&[b, h, n, d], 0xF1_2);
    let v4 = rand(&[b, h, n, d], 0xF1_3);
    let q = rand(&[n, d], 0xF1_4);
    let k = rand(&[n, d], 0xF1_5);
    let v = rand(&[n, d], 0xF1_6);
    let cfg = AttnConfig::new().causal();
    let run_all = |exec: &Exec| {
        record(|| {
            let mut hbm = Hbm::new();
            let _ = flash2_forward_batched(&q4, &k4, &v4, &cfg, blocks, exec, &mut hbm);
            let _ = flash_forward_sharded(&q, &k, &v, &cfg, blocks, 2, exec);
            let _ = flash_forward_sharded_tree(&q, &k, &v, &AttnConfig::new(), blocks, 2, exec);
        })
    };
    let reused = Exec::new(3);
    let first = run_all(&reused);
    assert_eq!(first.len(), 3, "batched + ring + tree pools");
    let again = run_all(&reused);
    assert_eq!(again, first, "fingerprints drifted across pool reuse");
    assert_eq!(run_all(&Exec::new(3)), first, "fresh pool handle disagrees with reused one");
    assert_eq!(run_all(&Exec::scoped(3)), first, "scoped oracle disagrees with persistent pool");
}

#[test]
fn overlapping_claims_are_rejected_with_provenance() {
    // The must-flag side of check (a), through the public pure checker:
    // two items claiming intersecting windows is exactly the class of
    // bug — a worker writing another item's slots — the auditor exists
    // to catch.
    let buf = vec![0.0f32; 8];
    let a = ItemClaims { idx: 0, id: (0, 0), claims: vec![SlotClaim::of("o", &buf[0..6])] };
    let b = ItemClaims { idx: 1, id: (0, 1), claims: vec![SlotClaim::of("o", &buf[4..8])] };
    let err = audit::check_disjoint(&[a, b]).unwrap_err();
    assert!(err.contains("items 0"), "{err}");
    assert!(err.contains("overlapping"), "{err}");

    // And the must-pass side: splitting the same buffer disjointly.
    let (lo, hi) = buf.split_at(4);
    let a = ItemClaims { idx: 0, id: (0, 0), claims: vec![SlotClaim::of("o", lo)] };
    let b = ItemClaims { idx: 1, id: (0, 1), claims: vec![SlotClaim::of("o", hi)] };
    assert!(audit::check_disjoint(&[a, b]).is_ok());
}
