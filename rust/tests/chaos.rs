//! Chaos wall for the fault-tolerant execution plane (`attn::faults`):
//! every injected fault class (worker panic, poisoned partial, delayed
//! shard, dropped merge) across the batched, ring-sharded and
//! tree-sharded schedules × worker counts {1, 2, 5} must
//!
//! * recover to output **bitwise identical** to the fault-free run
//!   (workers race only for items, never output slots — a re-run
//!   performs identical arithmetic into a window zeroed back to its
//!   pre-run state);
//! * account its retry HBM traffic **access-for-access** against the
//!   extended per-item closed forms in `sim::cost`;
//! * surface budget exhaustion and poisoned inputs as typed
//!   [`AttnError`]s carrying (site, slice, batch, head, block)
//!   provenance.
//!
//! Every grid runs on guarded `Exec::new(w)` handles — the persistent
//! parked-worker pool production uses — so the wall also proves the
//! pool's claim/retry machinery preserves the invariants the per-call
//! scoped runtime established.

use flashattn::attn::batched::{
    block_sparse2_backward_batched, block_sparse2_forward_batched, flash2_backward_batched,
    flash2_forward_batched, flash2_forward_many, AttnSlice,
};
use flashattn::attn::distributed::{
    block_sparse_forward_sharded_tree, classify_shards, flash_backward_sharded,
    flash_forward_sharded, flash_forward_sharded_tree, shard_ranges, Shard,
};
use flashattn::attn::faults::{AttnError, FaultKind, FaultPlan, FaultSite};
use flashattn::attn::flash::Blocks;
use flashattn::attn::flash2::flash2_decode;
use flashattn::attn::masks::BlockMask;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::cost;
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

/// A guarded handle over the persistent pool: fault plan armed and the
/// finiteness guardrail on — the replacement for the old `_checked`
/// entry points.
fn guarded(workers: usize, plan: &FaultPlan) -> Exec {
    Exec::new(workers).with_plan(plan).validated()
}

const ALL_KINDS: [FaultKind; 4] = [
    FaultKind::WorkerPanic,
    FaultKind::PoisonedPartial,
    FaultKind::DroppedMerge,
    FaultKind::DelayedShard,
];

fn rand(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = SplitMix64::new(seed);
    Tensor::randn(shape, &mut rng, 1.0)
}

/// Analytic HBM traffic of one ring/tree forward work item: Q row block
/// loaded once, the shard streams visited in order, O and the lse row
/// stored once (see `forward_sharded_core` / `forward_many_sited`).
fn ring_stream(n: u64, d: u64, blocks: Blocks, rb: u64, live: &[Shard], causal: bool) -> u64 {
    live.iter()
        .map(|sh| {
            cost::flash2_fwd_shard_item(n, d, blocks, rb, sh.lo as u64, sh.hi as u64, causal)
        })
        .sum()
}

fn ring_fwd_item(n: usize, d: usize, blocks: Blocks, rb: usize, live: &[Shard], causal: bool) -> u64 {
    let (nu, du) = (n as u64, d as u64);
    let b_r = blocks.b_r as u64;
    let r1 = ((rb as u64 + 1) * b_r).min(nu);
    let br = r1 - rb as u64 * b_r;
    let stream = ring_stream(nu, du, blocks, rb as u64, live, causal);
    br * du + stream + (br * du + br)
}

/// Analytic HBM traffic of one ring backward dQ work item: Q/dO/D/L row
/// block loaded once, the shard streams visited in order, dQ stored once.
fn ring_dq_item(n: usize, d: usize, blocks: Blocks, rb: usize, live: &[Shard], causal: bool) -> u64 {
    let (nu, du) = (n as u64, d as u64);
    let b_r = blocks.b_r as u64;
    let r1 = ((rb as u64 + 1) * b_r).min(nu);
    let br = r1 - rb as u64 * b_r;
    let stream = ring_stream(nu, du, blocks, rb as u64, live, causal);
    (2 * br * du + 2 * br) + stream + br * du
}

/// Per-kind counter bookkeeping shared by the recovery tests.
fn assert_fault_counters(report: &flashattn::attn::faults::FaultReport, kind: FaultKind, n: u64) {
    match kind {
        FaultKind::WorkerPanic => assert_eq!(report.panics, n, "panic counter"),
        FaultKind::PoisonedPartial => assert_eq!(report.poisoned, n, "poison counter"),
        FaultKind::DroppedMerge => assert_eq!(report.dropped, n, "dropped-merge counter"),
        FaultKind::DelayedShard => unreachable!("delayed shards are not faults"),
    }
}

// ---------------------------------------------------------------------
// Batched schedule: recovery is bitwise, retries are access-for-access.
// ---------------------------------------------------------------------

#[test]
fn batched_forward_recovers_bitwise_with_exact_retry_traffic() {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xC4A0_51);
    let k = rand(&[b, h, n, d], 0xC4A0_52);
    let v = rand(&[b, h, n, d], 0xC4A0_53);
    let t_r = n.div_ceil(blocks.b_r);
    // Flat pool coordinates (s * t_r + rb): (s=0, rb=3) and (s=1, rb=2).
    let faulted = [3usize, 10];
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let mut clean_hbm = Hbm::new();
        let baseline =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut clean_hbm)
                .expect("fault-free")
                .0;
        for kind in ALL_KINDS {
            let mut plan = FaultPlan::none();
            for &it in &faulted {
                plan = plan.with(FaultSite::BatchedFwd, it, 0, kind);
            }
            for workers in [1usize, 2, 5] {
                let ctx = format!("causal={causal} kind={kind:?} w={workers}");
                let mut hbm = Hbm::new();
                let gx = guarded(workers, &plan);
                let (out, report) =
                    flash2_forward_batched(&q, &k, &v, &cfg, blocks, &gx, &mut hbm)
                        .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
                assert_eq!(out.o.data, baseline.o.data, "O not bitwise [{ctx}]");
                assert_eq!(out.stats.lse, baseline.stats.lse, "lse not bitwise [{ctx}]");
                if kind == FaultKind::DelayedShard {
                    assert_eq!(report.delayed, 2, "{ctx}");
                    assert_eq!(report.retries, 0, "{ctx}");
                    assert_eq!(report.retry_hbm.accesses(), 0, "{ctx}");
                    assert_eq!(cost::measured(&hbm), cost::measured(&clean_hbm), "{ctx}");
                } else {
                    assert_eq!(report.retries, 2, "{ctx}");
                    assert_eq!(report.faults(), 2, "{ctx}");
                    assert_fault_counters(&report, kind, 2);
                    // Each faulted attempt ran to completion: its traffic
                    // is exactly one per-item closed form, re-done once.
                    let expected: u64 = faulted
                        .iter()
                        .map(|&it| {
                            let rb = (it % t_r) as u64;
                            cost::flash2_fwd_item(n as u64, d as u64, blocks, rb, causal)
                        })
                        .sum();
                    assert_eq!(report.retry_hbm.accesses(), expected, "retry traffic [{ctx}]");
                    assert_eq!(
                        cost::measured(&hbm),
                        cost::measured(&clean_hbm) + expected,
                        "total = clean + retries [{ctx}]"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_backward_recovers_bitwise_with_exact_retry_traffic() {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xBAC_1);
    let k = rand(&[b, h, n, d], 0xBAC_2);
    let v = rand(&[b, h, n, d], 0xBAC_3);
    let dout = rand(&[b, h, n, d], 0xBAC_4);
    // dQ pool item 5 = (s=0, rb=5); dK/dV pool item 12 = (s=1, cb=4).
    let (dq_it, dkv_it) = (5usize, 12usize);
    let t_c = n.div_ceil(blocks.b_c);
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
            .expect("fault-free")
            .0;
        let mut clean_hbm = Hbm::new();
        let baseline = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &Exec::new(1), &mut clean_hbm,
        )
        .expect("fault-free")
        .0;
        for kind in ALL_KINDS {
            let plan = FaultPlan::none()
                .with(FaultSite::BatchedDq, dq_it, 0, kind)
                .with(FaultSite::BatchedDkv, dkv_it, 0, kind);
            for workers in [1usize, 2, 5] {
                let ctx = format!("causal={causal} kind={kind:?} w={workers}");
                let mut hbm = Hbm::new();
                let (grads, report) = flash2_backward_batched(
                    &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks,
                    &guarded(workers, &plan), &mut hbm,
                )
                .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
                assert_eq!(grads.dq.data, baseline.dq.data, "dQ not bitwise [{ctx}]");
                assert_eq!(grads.dk.data, baseline.dk.data, "dK not bitwise [{ctx}]");
                assert_eq!(grads.dv.data, baseline.dv.data, "dV not bitwise [{ctx}]");
                if kind == FaultKind::DelayedShard {
                    assert_eq!(report.delayed, 2, "{ctx}");
                    assert_eq!(report.retry_hbm.accesses(), 0, "{ctx}");
                    assert_eq!(cost::measured(&hbm), cost::measured(&clean_hbm), "{ctx}");
                } else {
                    assert_eq!(report.retries, 2, "{ctx}");
                    assert_fault_counters(&report, kind, 2);
                    let expected = cost::flash2_bwd_dq_item(n as u64, d as u64, blocks, 5, causal)
                        + cost::flash2_bwd_dkv_item(
                            n as u64,
                            d as u64,
                            blocks,
                            ((dkv_it % t_c) * blocks.b_c) as u64,
                            causal,
                        );
                    assert_eq!(report.retry_hbm.accesses(), expected, "retry traffic [{ctx}]");
                    assert_eq!(
                        cost::measured(&hbm),
                        cost::measured(&clean_hbm) + expected,
                        "total = clean + retries [{ctx}]"
                    );
                }
            }
        }
    }
}

#[test]
fn sparse_batched_forward_recovers_bitwise() {
    let (b, h, n, d) = (2usize, 1usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[b, h, n, d], 0x5BA_1);
    let k = rand(&[b, h, n, d], 0x5BA_2);
    let v = rand(&[b, h, n, d], 0x5BA_3);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(0, 2, false);
    mask.set(3, 1, false);
    let masks = [mask];
    let cfg = AttnConfig::default();
    let mut clean_hbm = Hbm::new();
    let ex1 = Exec::new(1);
    let baseline =
        block_sparse2_forward_batched(&q, &k, &v, &masks, &cfg, blocks, &ex1, &mut clean_hbm)
            .expect("fault-free")
            .0;
    for kind in ALL_KINDS {
        // Pool item 5 = (s=1, rb=1).
        let plan = FaultPlan::none().with(FaultSite::SparseFwd, 5, 0, kind);
        for workers in [1usize, 2, 5] {
            let ctx = format!("kind={kind:?} w={workers}");
            let mut hbm = Hbm::new();
            let (out, report) = block_sparse2_forward_batched(
                &q, &k, &v, &masks, &cfg, blocks, &guarded(workers, &plan), &mut hbm,
            )
            .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
            assert_eq!(out.o.data, baseline.o.data, "O not bitwise [{ctx}]");
            assert_eq!(out.stats.lse, baseline.stats.lse, "lse not bitwise [{ctx}]");
            if kind == FaultKind::DelayedShard {
                assert_eq!(report.delayed, 1, "{ctx}");
                assert_eq!(cost::measured(&hbm), cost::measured(&clean_hbm), "{ctx}");
            } else {
                assert_eq!(report.retries, 1, "{ctx}");
                assert_fault_counters(&report, kind, 1);
                // No dense closed form for a masked item: the retry pool
                // traffic must still reconcile exactly with the total.
                assert_eq!(
                    cost::measured(&hbm),
                    cost::measured(&clean_hbm) + report.retry_hbm.accesses(),
                    "total = clean + retries [{ctx}]"
                );
                assert!(report.retry_hbm.accesses() > 0, "{ctx}");
            }
        }
    }
}

#[test]
fn sparse_batched_backward_recovers_bitwise() {
    let (b, h, n, d) = (2usize, 1usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let q = rand(&[b, h, n, d], 0x5BB_1);
    let k = rand(&[b, h, n, d], 0x5BB_2);
    let v = rand(&[b, h, n, d], 0x5BB_3);
    let dout = rand(&[b, h, n, d], 0x5BB_4);
    let mut mask = BlockMask::dense(t_r, t_c);
    mask.set(0, 2, false);
    mask.set(3, 1, false);
    let masks = [mask];
    let cfg = AttnConfig::default();
    let ex1 = Exec::new(1);
    let fwd =
        block_sparse2_forward_batched(&q, &k, &v, &masks, &cfg, blocks, &ex1, &mut Hbm::new())
            .expect("fault-free")
            .0;
    let mut clean_hbm = Hbm::new();
    let baseline = block_sparse2_backward_batched(
        &q, &k, &v, &fwd.o, &dout, &fwd.stats, &masks, &cfg, blocks, &ex1, &mut clean_hbm,
    )
    .expect("fault-free")
    .0;
    for kind in ALL_KINDS {
        // dQ pool item 5 = (s=1, rb=1); dK/dV pool item 2 = (s=0, cb=2).
        let plan = FaultPlan::none()
            .with(FaultSite::SparseDq, 5, 0, kind)
            .with(FaultSite::SparseDkv, 2, 0, kind);
        for workers in [1usize, 2, 5] {
            let ctx = format!("kind={kind:?} w={workers}");
            let mut hbm = Hbm::new();
            let (grads, report) = block_sparse2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &masks, &cfg, blocks,
                &guarded(workers, &plan), &mut hbm,
            )
            .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
            assert_eq!(grads.dq.data, baseline.dq.data, "dQ not bitwise [{ctx}]");
            assert_eq!(grads.dk.data, baseline.dk.data, "dK not bitwise [{ctx}]");
            assert_eq!(grads.dv.data, baseline.dv.data, "dV not bitwise [{ctx}]");
            if kind == FaultKind::DelayedShard {
                assert_eq!(report.delayed, 2, "{ctx}");
                assert_eq!(cost::measured(&hbm), cost::measured(&clean_hbm), "{ctx}");
            } else {
                assert_eq!(report.retries, 2, "{ctx}");
                assert_fault_counters(&report, kind, 2);
                // Masked items have no dense closed form; the retry pool
                // traffic must still reconcile exactly with the total.
                assert_eq!(
                    cost::measured(&hbm),
                    cost::measured(&clean_hbm) + report.retry_hbm.accesses(),
                    "total = clean + retries [{ctx}]"
                );
                assert!(report.retry_hbm.accesses() > 0, "{ctx}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Sharded schedules: ring (fwd + bwd) and tree.
// ---------------------------------------------------------------------

#[test]
fn ring_forward_recovers_bitwise_with_exact_retry_traffic() {
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x1111);
    let k = rand(&[n, d], 0x2222);
    let v = rand(&[n, d], 0x3333);
    let faulted = [2usize, 7];
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let live = shard_ranges(n, blocks.b_c, shards);
        let baseline = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
            .expect("fault-free")
            .0;
        for kind in ALL_KINDS {
            let mut plan = FaultPlan::none();
            for &rb in &faulted {
                plan = plan.with(FaultSite::RingFwd, rb, 0, kind);
            }
            for workers in [1usize, 2, 5] {
                let ctx = format!("causal={causal} kind={kind:?} w={workers}");
                let (out, report) = flash_forward_sharded(
                    &q, &k, &v, &cfg, blocks, shards, &guarded(workers, &plan),
                )
                .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
                assert_eq!(out.o.data, baseline.o.data, "O not bitwise [{ctx}]");
                assert_eq!(out.m, baseline.m, "m not bitwise [{ctx}]");
                assert_eq!(out.l, baseline.l, "l not bitwise [{ctx}]");
                if kind == FaultKind::DelayedShard {
                    assert_eq!(report.delayed, 2, "{ctx}");
                    assert_eq!(report.retry_hbm.accesses(), 0, "{ctx}");
                } else {
                    assert_eq!(report.retries, 2, "{ctx}");
                    assert_fault_counters(&report, kind, 2);
                    let expected: u64 = faulted
                        .iter()
                        .map(|&rb| ring_fwd_item(n, d, blocks, rb, &live, causal))
                        .sum();
                    assert_eq!(report.retry_hbm.accesses(), expected, "retry traffic [{ctx}]");
                }
            }
        }
    }
}

#[test]
fn ring_backward_recovers_bitwise_with_exact_retry_traffic() {
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0xD_1);
    let k = rand(&[n, d], 0xD_2);
    let v = rand(&[n, d], 0xD_3);
    let dout = rand(&[n, d], 0xD_4);
    // dQ item 1 = row block 1; dK/dV item 6 = (shard 1, local cb 2),
    // i.e. global column 32 + 2·8 = 48.
    let (dq_rb, dkv_col0) = (1usize, 48u64);
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let live = shard_ranges(n, blocks.b_c, shards);
        let fwd = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
            .expect("fault-free")
            .0;
        let baseline = flash_backward_sharded(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards, &Exec::new(1),
        )
        .expect("fault-free")
        .0;
        for kind in ALL_KINDS {
            let plan = FaultPlan::none()
                .with(FaultSite::RingDq, dq_rb, 0, kind)
                .with(FaultSite::RingDkv, 6, 0, kind);
            for workers in [1usize, 2, 5] {
                let ctx = format!("causal={causal} kind={kind:?} w={workers}");
                let (grads, report) = flash_backward_sharded(
                    &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, shards,
                    &guarded(workers, &plan),
                )
                .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
                assert_eq!(grads.dq.data, baseline.dq.data, "dQ not bitwise [{ctx}]");
                assert_eq!(grads.dk.data, baseline.dk.data, "dK not bitwise [{ctx}]");
                assert_eq!(grads.dv.data, baseline.dv.data, "dV not bitwise [{ctx}]");
                if kind == FaultKind::DelayedShard {
                    assert_eq!(report.delayed, 2, "{ctx}");
                    assert_eq!(report.retry_hbm.accesses(), 0, "{ctx}");
                } else {
                    assert_eq!(report.retries, 2, "{ctx}");
                    assert_fault_counters(&report, kind, 2);
                    let expected = ring_dq_item(n, d, blocks, dq_rb, &live, causal)
                        + cost::flash2_bwd_dkv_item(n as u64, d as u64, blocks, dkv_col0, causal);
                    assert_eq!(report.retry_hbm.accesses(), expected, "retry traffic [{ctx}]");
                }
            }
        }
    }
}

#[test]
fn tree_forward_recovers_bitwise_with_exact_retry_traffic() {
    let (n, d, shards) = (64usize, 16usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let t_r = n / blocks.b_r;
    let q = rand(&[n, d], 0x7EE_1);
    let k = rand(&[n, d], 0x7EE_2);
    let v = rand(&[n, d], 0x7EE_3);
    // Flat (live shard slice, row block) coordinates: item 2 = (shard 0,
    // rb 2), item 11 = (shard 1, rb 3).
    let faulted = [2usize, 11];
    for causal in [false, true] {
        let cfg = AttnConfig { causal, ..Default::default() };
        let live = shard_ranges(n, blocks.b_c, shards);
        let baseline = flash_forward_sharded_tree(&q, &k, &v, &cfg, blocks, shards, &Exec::new(1))
            .expect("fault-free")
            .0;
        for kind in ALL_KINDS {
            let mut plan = FaultPlan::none();
            for &it in &faulted {
                plan = plan.with(FaultSite::TreePartial, it, 0, kind);
            }
            for workers in [1usize, 2, 5] {
                let ctx = format!("causal={causal} kind={kind:?} w={workers}");
                let (out, report) = flash_forward_sharded_tree(
                    &q, &k, &v, &cfg, blocks, shards, &guarded(workers, &plan),
                )
                .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
                assert_eq!(out.o.data, baseline.o.data, "O not bitwise [{ctx}]");
                assert_eq!(out.l, baseline.l, "l not bitwise [{ctx}]");
                assert_eq!(out.m, baseline.m, "m not bitwise [{ctx}]");
                if kind == FaultKind::DelayedShard {
                    assert_eq!(report.delayed, 2, "{ctx}");
                    assert_eq!(report.retry_hbm.accesses(), 0, "{ctx}");
                } else {
                    assert_eq!(report.retries, 2, "{ctx}");
                    assert_fault_counters(&report, kind, 2);
                    // A tree partial item streams exactly its own shard.
                    let expected: u64 = faulted
                        .iter()
                        .map(|&it| {
                            let sh = live[it / t_r];
                            ring_fwd_item(n, d, blocks, it % t_r, &[sh], causal)
                        })
                        .sum();
                    assert_eq!(report.retry_hbm.accesses(), expected, "retry traffic [{ctx}]");
                }
            }
        }
    }
}

#[test]
fn sparse_tree_partial_poison_is_recomputed_and_remerged() {
    let (n, d, shards) = (32usize, 8usize, 2usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0x57E_1);
    let k = rand(&[n, d], 0x57E_2);
    let v = rand(&[n, d], 0x57E_3);
    let mask = BlockMask::dense(n / blocks.b_r, n / blocks.b_c);
    let cfg = AttnConfig::default();
    let baseline =
        block_sparse_forward_sharded_tree(&q, &k, &v, &mask, &cfg, blocks, shards, &Exec::new(1))
            .expect("fault-free")
            .0;
    // One poisoned partial on shard 1: recomputed, re-merged, bitwise.
    let plan = FaultPlan::none().with(FaultSite::TreePartial, 1, 0, FaultKind::PoisonedPartial);
    let (out, report) = block_sparse_forward_sharded_tree(
        &q, &k, &v, &mask, &cfg, blocks, shards, &guarded(2, &plan),
    )
    .expect("must recover");
    assert_eq!(out.o.data, baseline.o.data, "O not bitwise after re-merge");
    assert_eq!(out.l, baseline.l);
    assert_eq!(out.m, baseline.m);
    assert_eq!(report.poisoned, 1);
    assert_eq!(report.retries, 1);
    // Poisoned on every attempt: typed budget-exhaustion error.
    let plan = FaultPlan::none()
        .with(FaultSite::TreePartial, 1, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::TreePartial, 1, 1, FaultKind::PoisonedPartial)
        .with(FaultSite::TreePartial, 1, 2, FaultKind::PoisonedPartial);
    let err = block_sparse_forward_sharded_tree(
        &q, &k, &v, &mask, &cfg, blocks, shards, &guarded(2, &plan),
    )
    .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::TreePartial,
            slice: 1,
            batch: 0,
            head: 0,
            block: 0,
            attempts: 3,
        }
    );
}

// ---------------------------------------------------------------------
// Budget exhaustion: a fault on every attempt is a typed error.
// ---------------------------------------------------------------------

#[test]
fn exhausted_retry_budget_is_a_typed_error_with_provenance() {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0xE_1);
    let k = rand(&[b, h, n, d], 0xE_2);
    let v = rand(&[b, h, n, d], 0xE_3);
    let cfg = AttnConfig::default();

    // Panic on every attempt of item 7 = (batch 0, head 0, rb 7).
    let plan = FaultPlan::none()
        .with(FaultSite::BatchedFwd, 7, 0, FaultKind::WorkerPanic)
        .with(FaultSite::BatchedFwd, 7, 1, FaultKind::WorkerPanic)
        .with(FaultSite::BatchedFwd, 7, 2, FaultKind::WorkerPanic);
    let err =
        flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded(2, &plan), &mut Hbm::new())
            .unwrap_err();
    match err {
        AttnError::ItemFailed { site, slice, block, attempts, .. } => {
            assert_eq!(site, FaultSite::BatchedFwd);
            assert_eq!((slice, block, attempts), (0, 7, 3));
        }
        e => panic!("expected ItemFailed, got {e:?}"),
    }

    // Poison on every attempt of item 13 = (slice 1 → batch 0 head 1,
    // rb 5): NonFinite with full (batch, head, block) provenance.
    let plan = FaultPlan::none()
        .with(FaultSite::BatchedFwd, 13, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::BatchedFwd, 13, 1, FaultKind::PoisonedPartial)
        .with(FaultSite::BatchedFwd, 13, 2, FaultKind::PoisonedPartial);
    let err =
        flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded(2, &plan), &mut Hbm::new())
            .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::BatchedFwd,
            slice: 1,
            batch: 0,
            head: 1,
            block: 5,
            attempts: 3,
        }
    );
    let msg = err.to_string();
    assert!(msg.contains("batched forward"), "{msg}");
    assert!(msg.contains("batch 0, head 1"), "{msg}");

    // Dropped merge on every attempt: ItemFailed naming the cause.
    let plan = FaultPlan::none()
        .with(FaultSite::BatchedFwd, 0, 0, FaultKind::DroppedMerge)
        .with(FaultSite::BatchedFwd, 0, 1, FaultKind::DroppedMerge)
        .with(FaultSite::BatchedFwd, 0, 2, FaultKind::DroppedMerge);
    let err =
        flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded(2, &plan), &mut Hbm::new())
            .unwrap_err();
    match err {
        AttnError::ItemFailed { message, attempts, .. } => {
            assert!(message.contains("dropped"), "{message}");
            assert_eq!(attempts, 3);
        }
        e => panic!("expected ItemFailed, got {e:?}"),
    }
}

// ---------------------------------------------------------------------
// Seeded chaos: the same plan fires the same faults at every worker
// count, and recovery stays bitwise.
// ---------------------------------------------------------------------

#[test]
fn seeded_fault_schedule_is_deterministic_across_worker_counts() {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0x5EE_1);
    let k = rand(&[b, h, n, d], 0x5EE_2);
    let v = rand(&[b, h, n, d], 0x5EE_3);
    let dout = rand(&[b, h, n, d], 0x5EE_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let plan = FaultPlan::seeded(0x5EED_CA05, 0.75, &ALL_KINDS);

    let fwd_base = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
        .expect("fault-free")
        .0;
    let bwd_base = flash2_backward_batched(
        &q, &k, &v, &fwd_base.o, &dout, &fwd_base.stats, &cfg, blocks, &Exec::new(1),
        &mut Hbm::new(),
    )
    .expect("fault-free")
    .0;
    let mut fingerprints = Vec::new();
    for workers in [1usize, 2, 5] {
        let gx = guarded(workers, &plan);
        let (fwd, frep) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &gx, &mut Hbm::new())
                .expect("seeded faults fire on attempt 0 only — recovery must succeed");
        let (bwd, brep) = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &gx, &mut Hbm::new(),
        )
        .expect("seeded faults fire on attempt 0 only — recovery must succeed");
        assert_eq!(fwd.o.data, fwd_base.o.data, "w={workers}");
        assert_eq!(fwd.stats.lse, fwd_base.stats.lse, "w={workers}");
        assert_eq!(bwd.dq.data, bwd_base.dq.data, "w={workers}");
        assert_eq!(bwd.dk.data, bwd_base.dk.data, "w={workers}");
        assert_eq!(bwd.dv.data, bwd_base.dv.data, "w={workers}");
        fingerprints.push((
            frep.retries,
            frep.panics,
            frep.poisoned,
            frep.dropped,
            frep.delayed,
            frep.retry_hbm.loads,
            frep.retry_hbm.stores,
            brep.retries,
            brep.faults(),
            brep.retry_hbm.accesses(),
        ));
    }
    assert_eq!(fingerprints[0], fingerprints[1], "fault schedule depends on worker count");
    assert_eq!(fingerprints[0], fingerprints[2], "fault schedule depends on worker count");
    let (retries, faults) = (fingerprints[0].0, fingerprints[0].1 + fingerprints[0].2
        + fingerprints[0].3);
    assert!(faults + fingerprints[0].4 > 0, "seeded plan at rate 0.75 over 32 items fired nothing");
    assert_eq!(retries, faults, "every seeded fault retries exactly once");

    // The same seeded plan on the ring schedule: still bitwise.
    let (q2, k2, v2) = (rand(&[n, d], 0xA_1), rand(&[n, d], 0xA_2), rand(&[n, d], 0xA_3));
    let ring_base = flash_forward_sharded(&q2, &k2, &v2, &cfg, blocks, 2, &Exec::new(1))
        .expect("fault-free")
        .0;
    for workers in [1usize, 2, 5] {
        let (out, _) =
            flash_forward_sharded(&q2, &k2, &v2, &cfg, blocks, 2, &guarded(workers, &plan))
                .expect("must recover");
        assert_eq!(out.o.data, ring_base.o.data, "ring w={workers}");
        assert_eq!(out.m, ring_base.m, "ring w={workers}");
    }
}

// ---------------------------------------------------------------------
// Satellite 3: NaN/Inf INPUTS propagate to typed NonFinite errors with
// pinned provenance on every checked schedule; plain entry points keep
// their unvalidated (garbage-in, garbage-out) semantics.
// ---------------------------------------------------------------------

#[test]
fn nan_input_propagates_to_typed_error_in_forward_many() {
    let (n, d) = (32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q0 = rand(&[n, d], 0xF_1);
    let mut q1 = rand(&[n, d], 0xF_2);
    let k = rand(&[n, d], 0xF_3);
    let v = rand(&[n, d], 0xF_4);
    q1.data[20 * d] = f32::NAN; // slice 1, row 20 → row block 2
    let cfg = AttnConfig::default();
    let slices = [
        AttnSlice { q: &q0.data, k: &k.data, v: &v.data, n, n_k: n, d, cfg: cfg.clone() },
        AttnSlice { q: &q1.data, k: &k.data, v: &v.data, n, n_k: n, d, cfg: cfg.clone() },
    ];
    for workers in [1usize, 2, 5] {
        let err = flash2_forward_many(&slices, blocks, &guarded(workers, &FaultPlan::none()),
            &mut Hbm::new())
        .unwrap_err();
        assert_eq!(
            err,
            AttnError::NonFinite {
                site: FaultSite::BatchedFwd,
                slice: 1,
                batch: 0,
                head: 0,
                block: 2,
                attempts: 3,
            },
            "w={workers}"
        );
    }
}

#[test]
fn nan_and_inf_inputs_propagate_through_the_batched_schedules() {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let k = rand(&[b, h, n, d], 0x1F_2);
    let v = rand(&[b, h, n, d], 0x1F_3);
    let cfg = AttnConfig::default();

    // NaN in Q of (batch 1, head 0), row 5 → slice 2, row block 0.
    let mut q = rand(&[b, h, n, d], 0x1F_1);
    q.data[2 * n * d + 5 * d + 3] = f32::NAN;
    let err = flash2_forward_batched(&q, &k, &v, &cfg, blocks,
        &guarded(2, &FaultPlan::none()), &mut Hbm::new())
    .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::BatchedFwd,
            slice: 2,
            batch: 1,
            head: 0,
            block: 0,
            attempts: 3,
        }
    );

    // An unguarded handle keeps the defined garbage-in, garbage-out
    // semantics: no panic, the poison lands in the output.
    let out = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(2), &mut Hbm::new())
        .expect("no guardrail, no error")
        .0;
    assert!(out.o.data.iter().any(|x| x.is_nan()), "plain path must pass the NaN through");

    // Inf in Q of (batch 0, head 0), row 9 → slice 0, row block 1.
    let mut q = rand(&[b, h, n, d], 0x1F_4);
    q.data[9 * d] = f32::INFINITY;
    let err = flash2_forward_batched(&q, &k, &v, &cfg, blocks,
        &guarded(2, &FaultPlan::none()), &mut Hbm::new())
    .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::BatchedFwd,
            slice: 0,
            batch: 0,
            head: 0,
            block: 1,
            attempts: 3,
        }
    );

    // NaN in dO row 10 of (batch 0, head 1) → backward dQ pool, slice 1,
    // row block 1 (phase 0's D row is NaN, phase 1 trips the guardrail).
    let q = rand(&[b, h, n, d], 0x1F_5);
    let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
        .expect("fault-free")
        .0;
    let mut dout = rand(&[b, h, n, d], 0x1F_6);
    dout.data[n * d + 10 * d + 2] = f32::NAN;
    let err = flash2_backward_batched(
        &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &guarded(2, &FaultPlan::none()),
        &mut Hbm::new(),
    )
    .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::BatchedDq,
            slice: 1,
            batch: 0,
            head: 1,
            block: 1,
            attempts: 3,
        }
    );
}

#[test]
fn nan_inputs_propagate_through_sparse_and_sharded_schedules() {
    let blocks = Blocks::explicit(8, 8);

    // Sparse batched: NaN in Q row 5 of (batch 1, head 0) → slice 2.
    let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
    let mut q = rand(&[b, h, n, d], 0x2F_1);
    let k = rand(&[b, h, n, d], 0x2F_2);
    let v = rand(&[b, h, n, d], 0x2F_3);
    q.data[2 * n * d + 5 * d] = f32::NAN;
    let masks = [BlockMask::dense(n / blocks.b_r, n / blocks.b_c)];
    let cfg = AttnConfig::default();
    let err = block_sparse2_forward_batched(
        &q, &k, &v, &masks, &cfg, blocks, &guarded(2, &FaultPlan::none()), &mut Hbm::new(),
    )
    .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::SparseFwd,
            slice: 2,
            batch: 1,
            head: 0,
            block: 0,
            attempts: 3,
        }
    );

    // A NaN the mask excludes never reaches the output: checked run
    // succeeds and matches the plain run on the same poisoned input.
    let mut masked = BlockMask::dense(n / blocks.b_r, n / blocks.b_c);
    for i in 0..n / blocks.b_r {
        masked.set(i, 3, false);
    }
    let masks = [masked];
    let q_ok = rand(&[b, h, n, d], 0x2F_4);
    let mut k_bad = rand(&[b, h, n, d], 0x2F_5);
    k_bad.data[25 * d] = f32::NAN; // row 25 lives in masked-out tile 3
    let baseline = block_sparse2_forward_batched(
        &q_ok, &k_bad, &v, &masks, &cfg, blocks, &Exec::new(1), &mut Hbm::new(),
    )
    .expect("no guardrail, no error")
    .0;
    let (out, report) = block_sparse2_forward_batched(
        &q_ok, &k_bad, &v, &masks, &cfg, blocks, &guarded(2, &FaultPlan::none()), &mut Hbm::new(),
    )
    .expect("masked-out NaN must not trip the guardrail");
    assert_eq!(out.o.data, baseline.o.data);
    assert_eq!(report.faults(), 0);

    // Ring: NaN in Q row 12 → row block 1 (single logical slice).
    let (n2, d2) = (64usize, 16usize);
    let mut q2 = rand(&[n2, d2], 0x3F_1);
    let k2 = rand(&[n2, d2], 0x3F_2);
    let v2 = rand(&[n2, d2], 0x3F_3);
    q2.data[12 * d2] = f32::NAN;
    let err = flash_forward_sharded(
        &q2, &k2, &v2, &cfg, blocks, 2, &guarded(2, &FaultPlan::none()),
    )
    .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::RingFwd,
            slice: 0,
            batch: 0,
            head: 0,
            block: 1,
            attempts: 3,
        }
    );

    // Tree: NaN in K row 40 poisons only shard 1's partial.
    let q3 = rand(&[n2, d2], 0x4F_1);
    let mut k3 = rand(&[n2, d2], 0x4F_2);
    let v3 = rand(&[n2, d2], 0x4F_3);
    k3.data[40 * d2] = f32::NAN;
    let err = flash_forward_sharded_tree(
        &q3, &k3, &v3, &cfg, blocks, 2, &guarded(1, &FaultPlan::none()),
    )
    .unwrap_err();
    match err {
        AttnError::NonFinite { site, slice, attempts, .. } => {
            assert_eq!(site, FaultSite::TreePartial);
            assert_eq!(slice, 1, "only the shard owning the NaN key may fail");
            assert_eq!(attempts, 3);
        }
        e => panic!("expected NonFinite, got {e:?}"),
    }
}

// ---------------------------------------------------------------------
// Shard classification: malformed layouts are typed errors, dead shards
// are classified with a reason instead of silently dropped.
// ---------------------------------------------------------------------

#[test]
fn malformed_shard_layouts_are_typed_config_errors() {
    let cfg = AttnConfig::default();
    let err = classify_shards(&[Shard { lo: 8, hi: 8 }], 16, &cfg, 8).unwrap_err();
    match err {
        AttnError::ShardConfig { shard, lo, hi, reason } => {
            assert_eq!((shard, lo, hi), (0, 8, 8));
            assert!(reason.contains("empty"), "{reason}");
        }
        e => panic!("expected ShardConfig, got {e:?}"),
    }
    let ok = Shard { lo: 0, hi: 8 };
    let err = classify_shards(&[ok, Shard { lo: 3, hi: 16 }], 16, &cfg, 8).unwrap_err();
    match err {
        AttnError::ShardConfig { shard, reason, .. } => {
            assert_eq!(shard, 1);
            assert!(reason.contains("aligned"), "{reason}");
        }
        e => panic!("expected ShardConfig, got {e:?}"),
    }
}

#[test]
fn dead_shards_are_classified_with_reasons() {
    let (n, d) = (64usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0xDE_1);
    let k = rand(&[n, d], 0xDE_2);
    let v = rand(&[n, d], 0xDE_3);

    // kv_len = 10 kills shards [16,32), [32,48), [48,64).
    let cfg = AttnConfig { kv_len: Some(10), ..Default::default() };
    let baseline = flash_forward_sharded(&q, &k, &v, &cfg, blocks, 4, &Exec::new(1))
        .expect("fault-free")
        .0;
    let (out, report) =
        flash_forward_sharded(&q, &k, &v, &cfg, blocks, 4, &guarded(2, &FaultPlan::none()))
            .expect("dead shards are not errors");
    assert_eq!(out.o.data, baseline.o.data);
    let idx: Vec<usize> = report.dead_shards.iter().map(|&(i, _)| i).collect();
    assert_eq!(idx, vec![1, 2, 3]);
    for (_, reason) in &report.dead_shards {
        assert!(reason.contains("kv_len"), "{reason}");
    }

    // Causal with 16 query rows kills every shard past the diagonal.
    let q_short = rand(&[16, d], 0xDE_4);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let (_, report) =
        flash_forward_sharded(&q_short, &k, &v, &cfg, blocks, 4, &guarded(2, &FaultPlan::none()))
            .expect("dead shards are not errors");
    let idx: Vec<usize> = report.dead_shards.iter().map(|&(i, _)| i).collect();
    assert_eq!(idx, vec![1, 2, 3]);
    for (_, reason) in &report.dead_shards {
        assert!(reason.contains("causal"), "{reason}");
    }

    // Sparse tree: a shard whose mask window is all zero is dead with
    // the sparse-specific reason.
    let (n2, d2) = (32usize, 8usize);
    let q2 = rand(&[n2, d2], 0xDE_5);
    let k2 = rand(&[n2, d2], 0xDE_6);
    let v2 = rand(&[n2, d2], 0xDE_7);
    let mut mask = BlockMask::dense(n2 / blocks.b_r, n2 / blocks.b_c);
    for i in 0..n2 / blocks.b_r {
        mask.set(i, 2, false);
        mask.set(i, 3, false);
    }
    let cfg = AttnConfig::default();
    let baseline =
        block_sparse_forward_sharded_tree(&q2, &k2, &v2, &mask, &cfg, blocks, 2, &Exec::new(1))
            .expect("fault-free")
            .0;
    let (out, report) = block_sparse_forward_sharded_tree(
        &q2, &k2, &v2, &mask, &cfg, blocks, 2, &guarded(2, &FaultPlan::none()),
    )
    .expect("sparse-dead shards are not errors");
    assert_eq!(out.o.data, baseline.o.data);
    assert_eq!(report.dead_shards.len(), 1);
    assert_eq!(report.dead_shards[0].0, 1);
    assert!(report.dead_shards[0].1.contains("mask window"), "{}", report.dead_shards[0].1);
}

// ---------------------------------------------------------------------
// The checked entry points with no plan are free: bitwise-identical
// output, zeroed report, identical traffic.
// ---------------------------------------------------------------------

#[test]
fn checked_paths_without_faults_are_bitwise_and_traffic_identical() {
    let (b, h, n, d) = (2usize, 2usize, 48usize, 16usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 0x0FF_1);
    let k = rand(&[b, h, n, d], 0x0FF_2);
    let v = rand(&[b, h, n, d], 0x0FF_3);
    let cfg = AttnConfig { causal: true, ..Default::default() };
    let mut plain_hbm = Hbm::new();
    let plain = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::new(3), &mut plain_hbm)
        .expect("fault-free")
        .0;
    let mut checked_hbm = Hbm::new();
    let (out, report) =
        flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded(3, &FaultPlan::none()),
            &mut checked_hbm)
        .expect("no faults, no error");
    assert_eq!(out.o.data, plain.o.data);
    assert_eq!(out.stats.lse, plain.stats.lse);
    assert_eq!(report.retries, 0);
    assert_eq!(report.faults(), 0);
    assert_eq!(report.delayed, 0);
    assert_eq!(report.retry_hbm.accesses(), 0);
    assert!(report.dead_shards.is_empty());
    assert_eq!(plain_hbm.loads, checked_hbm.loads, "validation must not add modeled traffic");
    assert_eq!(plain_hbm.stores, checked_hbm.stores, "validation must not add modeled traffic");

    // flash2_forward_many round-trips the same way.
    let (n1, d1) = (32usize, 8usize);
    let q1 = rand(&[n1, d1], 0x0FF_4);
    let k1 = rand(&[n1, d1], 0x0FF_5);
    let v1 = rand(&[n1, d1], 0x0FF_6);
    let cfg1 = AttnConfig::default();
    let slices = [AttnSlice {
        q: &q1.data,
        k: &k1.data,
        v: &v1.data,
        n: n1,
        n_k: n1,
        d: d1,
        cfg: cfg1,
    }];
    let plain = flash2_forward_many(&slices, blocks, &Exec::new(2), &mut Hbm::new())
        .expect("fault-free")
        .0;
    let (outs, report) =
        flash2_forward_many(&slices, blocks, &guarded(2, &FaultPlan::none()), &mut Hbm::new())
            .expect("no faults, no error");
    assert_eq!(outs.len(), plain.len());
    assert_eq!(outs[0].o.data, plain[0].o.data);
    assert_eq!(outs[0].lse, plain[0].lse);
    assert_eq!(report.faults(), 0);
}

// ---------------------------------------------------------------------
// Split-KV decode: span items recover bitwise, retries are exact, and
// the serving loop evicts per request — never the batch.
// ---------------------------------------------------------------------

#[test]
fn decode_span_recovers_bitwise_with_exact_retry_traffic() {
    let (n, n_k, d, b_c, span_tiles) = (2usize, 100usize, 8usize, 8usize, 2usize);
    let blocks = Blocks::explicit(b_c, b_c);
    let q = rand(&[n, d], 0xDEC_1);
    let k = rand(&[n_k, d], 0xDEC_2);
    let v = rand(&[n_k, d], 0xDEC_3);
    let spans = n_k.div_ceil(b_c).div_ceil(span_tiles);
    // Non-causal: fault the first span and the ragged last span. Causal
    // with n = 2 local rows leaves only span 0 causally live — a
    // poisoned *empty* spill window is (correctly) undetectable, so the
    // causal grid faults the one live span.
    for (causal, faulted) in
        [(false, vec![0usize, spans - 1]), (true, vec![0usize])]
    {
        let nf = faulted.len() as u64;
        let cfg = AttnConfig { causal, ..Default::default() };
        let mut clean_hbm = Hbm::new();
        let baseline = flash2_decode(
            &q, &k, &v, &cfg, blocks, span_tiles, &Exec::new(1), &mut clean_hbm,
        )
        .expect("fault-free")
        .0;
        for kind in ALL_KINDS {
            let mut plan = FaultPlan::none();
            for &it in &faulted {
                plan = plan.with(FaultSite::DecodeSpan, it, 0, kind);
            }
            for workers in [1usize, 2, 5] {
                let ctx = format!("causal={causal} kind={kind:?} w={workers}");
                let mut hbm = Hbm::new();
                let gx = guarded(workers, &plan);
                let (out, report) =
                    flash2_decode(&q, &k, &v, &cfg, blocks, span_tiles, &gx, &mut hbm)
                        .unwrap_or_else(|e| panic!("must recover: {e} [{ctx}]"));
                assert_eq!(out.o.data, baseline.o.data, "O not bitwise [{ctx}]");
                assert_eq!(out.lse, baseline.lse, "lse not bitwise [{ctx}]");
                if kind == FaultKind::DelayedShard {
                    assert_eq!(report.delayed, nf, "{ctx}");
                    assert_eq!(report.retries, 0, "{ctx}");
                    assert_eq!(report.retry_hbm.accesses(), 0, "{ctx}");
                    assert_eq!(cost::measured(&hbm), cost::measured(&clean_hbm), "{ctx}");
                } else {
                    assert_eq!(report.retries, nf, "{ctx}");
                    assert_eq!(report.faults(), nf, "{ctx}");
                    assert_fault_counters(&report, kind, nf);
                    // Each faulted attempt ran its span to completion:
                    // exactly one per-span closed form, re-done once.
                    let expected: u64 = faulted
                        .iter()
                        .map(|&sp| {
                            cost::flash2_decode_item(
                                n as u64,
                                n_k as u64,
                                d as u64,
                                blocks,
                                span_tiles as u64,
                                sp as u64,
                                causal,
                            )
                        })
                        .sum();
                    assert_eq!(report.retry_hbm.accesses(), expected, "retry traffic [{ctx}]");
                    assert_eq!(
                        cost::measured(&hbm),
                        cost::measured(&clean_hbm) + expected,
                        "total = clean + retries [{ctx}]"
                    );
                }
            }
        }
    }
}

#[test]
fn decode_exhausted_retry_budget_is_a_typed_error_with_span_provenance() {
    let (n, n_k, d) = (1usize, 64usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[n, d], 0xDEC_4);
    let k = rand(&[n_k, d], 0xDEC_5);
    let v = rand(&[n_k, d], 0xDEC_6);
    let cfg = AttnConfig::default();

    // Panic on every attempt of span 3: ItemFailed names the span.
    let plan = FaultPlan::none()
        .with(FaultSite::DecodeSpan, 3, 0, FaultKind::WorkerPanic)
        .with(FaultSite::DecodeSpan, 3, 1, FaultKind::WorkerPanic)
        .with(FaultSite::DecodeSpan, 3, 2, FaultKind::WorkerPanic);
    let err = flash2_decode(&q, &k, &v, &cfg, blocks, 2, &guarded(2, &plan), &mut Hbm::new())
        .unwrap_err();
    match err {
        AttnError::ItemFailed { site, slice, block, attempts, .. } => {
            assert_eq!(site, FaultSite::DecodeSpan);
            assert_eq!((slice, block, attempts), (0, 3, 3));
        }
        e => panic!("expected ItemFailed, got {e:?}"),
    }

    // Poison on every attempt: the guardrail catches the NaN window
    // (masked entries are the *finite* NEG_INF sentinel, so a NaN can
    // only mean a poisoned partial) and surfaces NonFinite provenance.
    let plan = FaultPlan::none()
        .with(FaultSite::DecodeSpan, 1, 0, FaultKind::PoisonedPartial)
        .with(FaultSite::DecodeSpan, 1, 1, FaultKind::PoisonedPartial)
        .with(FaultSite::DecodeSpan, 1, 2, FaultKind::PoisonedPartial);
    let err = flash2_decode(&q, &k, &v, &cfg, blocks, 2, &guarded(2, &plan), &mut Hbm::new())
        .unwrap_err();
    assert_eq!(
        err,
        AttnError::NonFinite {
            site: FaultSite::DecodeSpan,
            slice: 0,
            batch: 0,
            head: 0,
            block: 1,
            attempts: 3,
        }
    );
    assert!(err.to_string().contains("split-KV decode span"), "{err}");
}

/// The serving-loop containment property: a request whose decode span
/// faults past the retry budget is evicted **alone** — every other
/// request's rows are bitwise those of the fault-free serve trace. The
/// faulted span index is one only the long request's KV history ever
/// reaches, so the plan provably cannot touch the short requests.
#[test]
fn serving_loop_evicts_only_the_faulted_request_and_keeps_the_rest_bitwise() {
    use flashattn::coordinator::server::{BatcherConfig, ContinuousBatcher, DecodeRequest};

    let cfg = BatcherConfig { d: 8, b_c: 4, span_tiles: 1, token_budget: 256 };
    let requests = [
        DecodeRequest { id: 1, prompt_len: 6, max_new_tokens: 3, seed: 0xA1 },
        // The long request: first decode step sees n_k = 22 → 6 column
        // tiles → span item 5 exists. The short requests peak at
        // n_k ≤ 12 → never more than 3 spans.
        DecodeRequest { id: 2, prompt_len: 21, max_new_tokens: 4, seed: 0xA2 },
        DecodeRequest { id: 3, prompt_len: 4, max_new_tokens: 8, seed: 0xA3 },
    ];

    let serve = |exec: &Exec| {
        let mut b = ContinuousBatcher::new(cfg.clone());
        for r in &requests {
            b.submit(r.clone());
        }
        b.run(exec, &mut Hbm::new())
    };

    let baseline = serve(&Exec::new(2));
    assert_eq!(baseline.completed.len(), 3);
    assert!(baseline.evicted.is_empty());

    // Exhaust span 5's budget: only request 2 ever builds one.
    let plan = FaultPlan::none()
        .with(FaultSite::DecodeSpan, 5, 0, FaultKind::WorkerPanic)
        .with(FaultSite::DecodeSpan, 5, 1, FaultKind::PoisonedPartial)
        .with(FaultSite::DecodeSpan, 5, 2, FaultKind::WorkerPanic);
    for workers in [1usize, 2, 5] {
        let report = serve(&guarded(workers, &plan));
        assert_eq!(report.evicted.len(), 1, "w={workers}");
        assert_eq!(report.evicted[0].id, 2, "w={workers}");
        let reason = report.evicted[0].evicted.as_deref().unwrap();
        assert!(reason.contains("split-KV decode span"), "w={workers}: {reason}");
        // The victim kept its pre-fault rows (prefill row only: the
        // fault fires on its first decode step).
        assert_eq!(report.evicted[0].steps.len(), 1, "w={workers}");
        // Survivors: completed, and bitwise the fault-free trace.
        let mut ids: Vec<u64> = report.completed.iter().map(|o| o.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 3], "w={workers}");
        for out in &report.completed {
            let clean = baseline.completed.iter().find(|o| o.id == out.id).unwrap();
            assert_eq!(out.steps, clean.steps, "request {} perturbed (w={workers})", out.id);
        }
    }
}

/// A transient decode fault (first attempt only) is retried inside the
/// pool: nothing is evicted, every request completes bitwise, and the
/// serve report carries the retry accounting.
#[test]
fn serving_loop_retries_transient_decode_faults_without_evicting() {
    use flashattn::coordinator::server::{BatcherConfig, ContinuousBatcher, DecodeRequest};

    let cfg = BatcherConfig { d: 8, b_c: 4, span_tiles: 1, token_budget: 256 };
    let requests = [
        DecodeRequest { id: 7, prompt_len: 21, max_new_tokens: 3, seed: 0xB1 },
        DecodeRequest { id: 8, prompt_len: 5, max_new_tokens: 5, seed: 0xB2 },
    ];
    let serve = |exec: &Exec| {
        let mut b = ContinuousBatcher::new(cfg.clone());
        for r in &requests {
            b.submit(r.clone());
        }
        b.run(exec, &mut Hbm::new())
    };
    let baseline = serve(&Exec::new(1));
    assert!(baseline.evicted.is_empty());
    assert_eq!(baseline.faults.retries, 0);

    // First attempt of span 5 poisons — again only request 7 has it.
    let plan =
        FaultPlan::none().with(FaultSite::DecodeSpan, 5, 0, FaultKind::PoisonedPartial);
    for workers in [1usize, 2, 5] {
        let report = serve(&guarded(workers, &plan));
        assert!(report.evicted.is_empty(), "w={workers}");
        assert_eq!(report.completed.len(), 2, "w={workers}");
        assert!(report.faults.retries >= 1, "w={workers}");
        assert!(report.faults.poisoned >= 1, "w={workers}");
        for out in &report.completed {
            let clean = baseline.completed.iter().find(|o| o.id == out.id).unwrap();
            assert_eq!(out.steps, clean.steps, "request {} perturbed (w={workers})", out.id);
        }
    }
}
