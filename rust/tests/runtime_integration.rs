//! Integration tests over the PJRT runtime + real artifacts. These require
//! `make artifacts` to have run; they are skipped (with a notice) if the
//! artifacts directory is missing so `cargo test` works on a fresh clone.

use std::path::Path;

use flashattn::attn::flash::{flash_forward, Blocks};
use flashattn::attn::flash2::flash2_forward;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::coordinator::{LmTrainer, TrainConfig};
use flashattn::coordinator::trainer::ClsTrainer;
use flashattn::data::corpus::Corpus;
use flashattn::data::listops::ListOps;
use flashattn::data::ClsDataset;
use flashattn::runtime::{Runtime, Value};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

fn runtime() -> Option<Runtime> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::cpu(Path::new("artifacts")).expect("runtime"))
}

fn rand_qkv(rt: &Runtime, name: &str, seed: u64) -> Vec<Value> {
    let spec = rt.manifest.artifact(name).unwrap();
    let mut rng = SplitMix64::new(seed);
    spec.inputs
        .iter()
        .map(|ts| Value::F32 { shape: ts.shape.clone(), data: rng.normal_vec(ts.numel(), 1.0) })
        .collect()
}

#[test]
fn flash_artifact_matches_reference_artifact() {
    let Some(mut rt) = runtime() else { return };
    let inputs = rand_qkv(&rt, "attn_flash_fwd", 0);
    let flash = rt.run("attn_flash_fwd", &inputs).unwrap().remove(0);
    let reference = rt.run("attn_ref_fwd", &inputs).unwrap().remove(0);
    let diff = flash
        .as_f32()
        .unwrap()
        .iter()
        .zip(reference.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff < 1e-4, "kernel vs oracle diff {diff}");
}

#[test]
fn flash_artifact_matches_rust_mirror() {
    let Some(mut rt) = runtime() else { return };
    let inputs = rand_qkv(&rt, "attn_flash_fwd_causal", 1);
    let flash = rt.run("attn_flash_fwd_causal", &inputs).unwrap().remove(0);
    let spec = rt.manifest.artifact("attn_flash_fwd_causal").unwrap();
    let (bh, n, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1], spec.inputs[0].shape[2]);
    for b in [0usize, bh - 1] {
        let slice = |val: &Value| {
            Tensor::from_vec(&[n, d], val.as_f32().unwrap()[b * n * d..(b + 1) * n * d].to_vec())
        };
        let out = flash_forward(
            &slice(&inputs[0]), &slice(&inputs[1]), &slice(&inputs[2]),
            &AttnConfig::new().causal(), Blocks::explicit(16, 16), &mut Hbm::new());
        assert!(out.o.max_abs_diff(&slice(&flash)) < 1e-4, "bh slice {b}");
        // The fast production kernel must agree with the artifact too.
        let fast = flash2_forward(
            &slice(&inputs[0]), &slice(&inputs[1]), &slice(&inputs[2]),
            &AttnConfig::new().causal(), Blocks::explicit(16, 16), &Exec::scoped(2),
            &mut Hbm::new());
        assert!(fast.o.max_abs_diff(&slice(&flash)) < 1e-4, "flash2 bh slice {b}");
    }
}

#[test]
fn fwd_bwd_artifacts_agree() {
    let Some(mut rt) = runtime() else { return };
    let inputs = rand_qkv(&rt, "attn_flash_fwd_bwd", 2);
    let flash = rt.run("attn_flash_fwd_bwd", &inputs).unwrap();
    let reference = rt.run("attn_ref_fwd_bwd", &inputs).unwrap();
    for (i, (f, r)) in flash.iter().zip(&reference).enumerate() {
        let diff = f
            .as_f32()
            .unwrap()
            .iter()
            .zip(r.as_f32().unwrap())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 2e-4, "output {i} (o/dq/dk/dv) diff {diff}");
    }
}

#[test]
fn dropout_artifact_is_deterministic_and_differs_from_plain() {
    let Some(mut rt) = runtime() else { return };
    let inputs = rand_qkv(&rt, "attn_flash_fwd_dropout", 3);
    let a = rt.run("attn_flash_fwd_dropout", &inputs).unwrap().remove(0);
    let b = rt.run("attn_flash_fwd_dropout", &inputs).unwrap().remove(0);
    assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "counter RNG must be deterministic");
    let plain = rt.run("attn_flash_fwd_causal", &inputs).unwrap().remove(0);
    let diff = a
        .as_f32()
        .unwrap()
        .iter()
        .zip(plain.as_f32().unwrap())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(diff > 1e-3, "dropout had no effect");
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(mut rt) = runtime() else { return };
    let a = rt.run("gpt_flash_init", &[Value::scalar_i32(5)]).unwrap();
    let b = rt.run("gpt_flash_init", &[Value::scalar_i32(5)]).unwrap();
    let c = rt.run("gpt_flash_init", &[Value::scalar_i32(6)]).unwrap();
    // Compare the largest tensor (a randomly-initialised weight matrix —
    // the first pytree leaf is a zero bias, identical across seeds).
    let big = (0..a.len()).max_by_key(|&i| a[i].numel()).unwrap();
    assert_eq!(a[big].as_f32().unwrap(), b[big].as_f32().unwrap());
    assert_ne!(a[big].as_f32().unwrap(), c[big].as_f32().unwrap());
}

#[test]
fn lm_training_reduces_loss() {
    let Some(mut rt) = runtime() else { return };
    let corpus = Corpus::builtin(50_000, 3);
    let cfg =
        TrainConfig { model: "gpt_flash".into(), steps: 8, eval_every: 0, ..Default::default() };
    let mut tr = LmTrainer::new(&mut rt, cfg, &Exec::new(2)).unwrap();
    let (first, last) = tr.train(&mut rt, &corpus).unwrap();
    assert!(last < first, "loss did not fall: {first} -> {last}");
    assert!(
        first > 4.0 && first < 7.0,
        "initial loss should be near ln(256)={:.2}: {first}",
        (256f64).ln()
    );
}

#[test]
fn flash_and_reference_models_train_identically() {
    let Some(mut rt) = runtime() else { return };
    let corpus = Corpus::builtin(50_000, 4);
    let mut curves = Vec::new();
    for model in ["gpt_flash", "gpt_ref"] {
        let cfg = TrainConfig {
            model: model.into(),
            steps: 5,
            eval_every: 0,
            seed: 11,
            ..Default::default()
        };
        let mut tr = LmTrainer::new(&mut rt, cfg, &Exec::new(2)).unwrap();
        tr.train(&mut rt, &corpus).unwrap();
        curves.push(tr.metrics.points.iter().map(|p| p.loss).collect::<Vec<_>>());
    }
    for (s, (a, b)) in curves[0].iter().zip(&curves[1]).enumerate() {
        assert!((a - b).abs() < 2e-2, "step {s}: {a} vs {b}");
    }
}

#[test]
fn cls_training_step_runs_and_is_finite() {
    let Some(mut rt) = runtime() else { return };
    let ds = ListOps::default();
    let cfg =
        TrainConfig { model: "cls_flash".into(), steps: 2, eval_every: 0, ..Default::default() };
    let mut tr = ClsTrainer::new(&mut rt, cfg, &Exec::new(2)).unwrap();
    let mut rng = SplitMix64::new(5);
    let batch = ds.batch(tr.batch, tr.n_ctx, &mut rng);
    let (loss, acc) = tr.step(&mut rt, &batch).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn checkpoint_roundtrip() {
    let Some(mut rt) = runtime() else { return };
    let corpus = Corpus::builtin(50_000, 6);
    let cfg =
        TrainConfig { model: "gpt_flash".into(), steps: 3, eval_every: 0, ..Default::default() };
    let mut tr = LmTrainer::new(&mut rt, cfg, &Exec::new(2)).unwrap();
    tr.train(&mut rt, &corpus).unwrap();
    let eval_batch = corpus.eval_batch(tr.batch, tr.n_ctx);
    let loss_before = tr.eval_loss(&mut rt, &eval_batch).unwrap();
    let path = std::env::temp_dir().join("flashattn_ckpt_test.bin");
    tr.save(&path).unwrap();

    let cfg2 = TrainConfig {
        model: "gpt_flash".into(),
        steps: 0,
        eval_every: 0,
        seed: 99,
        ..Default::default()
    };
    let mut tr2 = LmTrainer::new(&mut rt, cfg2, &Exec::new(2)).unwrap();
    tr2.load(&path).unwrap();
    let loss_after = tr2.eval_loss(&mut rt, &eval_batch).unwrap();
    assert!((loss_before - loss_after).abs() < 1e-5, "{loss_before} vs {loss_after}");
}

#[test]
fn input_shape_mismatch_is_rejected() {
    let Some(mut rt) = runtime() else { return };
    let bad = vec![Value::scalar_f32(0.0); 3];
    assert!(rt.run("attn_flash_fwd", &bad).is_err());
}

#[test]
fn manifest_models_cover_experiment_grid() {
    let Some(rt) = runtime() else { return };
    for tag in ["gpt_flash", "gpt_ref", "gpt_flash_ctx64", "gpt_flash_ctx256",
                "cls_flash", "cls_reference", "cls_block_sparse", "cls_local",
                "cls_linformer", "cls_linear",
                "longdoc_ctx64", "longdoc_ctx128", "longdoc_ctx256", "longdoc_ctx512"] {
        assert!(rt.manifest.models.contains_key(tag), "missing model {tag}");
        for suffix in ["init", "train_step"] {
            assert!(
                rt.manifest.artifacts.contains_key(&format!("{tag}_{suffix}")),
                "missing artifact {tag}_{suffix}"
            );
        }
    }
}
