//! Pool-reuse wall: the persistent runtime's core guarantee, asserted
//! on real kernels. A single long-lived [`Exec`] handle reused across
//! an interleaved stream of batched, sparse, ring and tree calls must
//! produce bitwise-identical outputs — and identical modeled traffic —
//! to a fresh pool handle and to the per-call scoped oracle, at every
//! worker count; and it must keep doing so after a guarded call on the
//! same pool recovered from an injected worker panic.

use flashattn::attn::batched::{
    block_sparse2_forward_batched, flash2_backward_batched, flash2_forward_batched,
    flash2_forward_many, AttnSlice,
};
use flashattn::attn::distributed::{
    block_sparse_forward_sharded_tree, flash_backward_sharded, flash_forward_sharded,
    flash_forward_sharded_tree,
};
use flashattn::attn::faults::{FaultKind, FaultPlan, FaultSite};
use flashattn::attn::flash::Blocks;
use flashattn::attn::masks::BlockMask;
use flashattn::attn::{AttnConfig, Exec};
use flashattn::sim::hbm::Hbm;
use flashattn::tensor::Tensor;
use flashattn::util::rng::SplitMix64;

fn rand(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = SplitMix64::new(seed);
    Tensor::randn(shape, &mut rng, 1.0)
}

/// Everything one interleaved pass produces, plus its aggregate modeled
/// traffic: equality of two traces is the bitwise-reuse guarantee.
#[derive(Debug, PartialEq)]
struct Trace {
    outputs: Vec<Vec<f32>>,
    accesses: u64,
}

/// One interleaved workload — batched fwd/bwd, per-head sparse batched,
/// ring fwd/bwd, tree, sparse tree, ragged `_many` — all through the
/// same handle, deliberately mixing schedules between calls so parked
/// workers see heterogeneous work back to back.
fn interleaved_pass(exec: &Exec) -> Trace {
    let (b, h, n, d) = (2usize, 2usize, 64usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let (t_r, t_c) = (n / blocks.b_r, n / blocks.b_c);
    let mut outputs = Vec::new();
    let mut hbm = Hbm::new();

    // Batched forward.
    let q4 = rand(&[b, h, n, d], 1);
    let k4 = rand(&[b, h, n, d], 2);
    let v4 = rand(&[b, h, n, d], 3);
    let cfg = AttnConfig::new().causal();
    let fwd = flash2_forward_batched(&q4, &k4, &v4, &cfg, blocks, exec, &mut hbm)
        .expect("fault-free")
        .0;
    outputs.push(fwd.o.data.clone());
    outputs.push(fwd.stats.lse.clone());

    // Ring forward, interleaved before the batched backward.
    let q = rand(&[n, d], 4);
    let k = rand(&[n, d], 5);
    let v = rand(&[n, d], 6);
    let ring = flash_forward_sharded(&q, &k, &v, &cfg, blocks, 2, exec).expect("fault-free").0;
    outputs.push(ring.o.data.clone());

    // Batched backward on the forward from two calls ago.
    let dout4 = rand(&[b, h, n, d], 7);
    let grads = flash2_backward_batched(
        &q4, &k4, &v4, &fwd.o, &dout4, &fwd.stats, &cfg, blocks, exec, &mut hbm,
    )
    .expect("fault-free")
    .0;
    outputs.push(grads.dq.data.clone());
    outputs.push(grads.dk.data.clone());
    outputs.push(grads.dv.data.clone());

    // Per-head sparse batched forward.
    let masks = [BlockMask::butterfly(t_r, t_c), BlockMask::local_global(t_r, t_c, 1, 1)];
    let sparse = block_sparse2_forward_batched(
        &q4, &k4, &v4, &masks, &AttnConfig::new(), blocks, exec, &mut hbm,
    )
    .expect("fault-free")
    .0;
    outputs.push(sparse.o.data.clone());

    // Ring backward.
    let dout = rand(&[n, d], 8);
    let rg = flash_backward_sharded(
        &q, &k, &v, &ring.o, &dout, ring.stats(), &cfg, blocks, 2, exec,
    )
    .expect("fault-free")
    .0;
    outputs.push(rg.dq.data.clone());
    outputs.push(rg.dk.data.clone());
    outputs.push(rg.dv.data.clone());

    // Tree merge and its sparse sibling.
    let tree = flash_forward_sharded_tree(&q, &k, &v, &AttnConfig::new(), blocks, 2, exec)
        .expect("fault-free")
        .0;
    outputs.push(tree.o.data.clone());
    outputs.push(tree.m.clone());
    outputs.push(tree.l.clone());
    let mask = BlockMask::local_global(t_r, t_c, 1, 1);
    let st = block_sparse_forward_sharded_tree(
        &q, &k, &v, &mask, &AttnConfig::new(), blocks, 2, exec,
    )
    .expect("fault-free")
    .0;
    outputs.push(st.o.data.clone());

    // Ragged heterogeneous slices through the same pool.
    let (qa, ka, va) = (rand(&[48, d], 9), rand(&[48, d], 10), rand(&[48, d], 11));
    let (qb, kb, vb) = (rand(&[32, d], 12), rand(&[32, d], 13), rand(&[32, d], 14));
    let slices = [
        AttnSlice {
            q: &qa.data,
            k: &ka.data,
            v: &va.data,
            n: 48,
            n_k: 48,
            d,
            cfg: AttnConfig::new(),
        },
        AttnSlice {
            q: &qb.data,
            k: &kb.data,
            v: &vb.data,
            n: 32,
            n_k: 32,
            d,
            cfg: AttnConfig::new().causal(),
        },
    ];
    let (many, _) = flash2_forward_many(&slices, blocks, exec, &mut hbm).expect("fault-free");
    for out in &many {
        outputs.push(out.o.data.clone());
        outputs.push(out.lse.clone());
    }

    Trace { outputs, accesses: hbm.accesses() }
}

#[test]
fn reused_pool_is_bitwise_identical_to_fresh_and_scoped_runs() {
    for workers in [1usize, 2, 5] {
        let reused = Exec::new(workers);
        let first = interleaved_pass(&reused);
        let second = interleaved_pass(&reused);
        assert_eq!(second, first, "reuse drifted (w={workers})");
        let fresh = interleaved_pass(&Exec::new(workers));
        assert_eq!(fresh, first, "fresh handle disagrees with reused pool (w={workers})");
        let scoped = interleaved_pass(&Exec::scoped(workers));
        assert_eq!(scoped, first, "scoped oracle disagrees with persistent pool (w={workers})");
    }
}

#[test]
fn pool_stays_bitwise_after_guarded_recovery() {
    let workers = 3usize;
    let baseline = interleaved_pass(&Exec::new(workers));

    // A guarded call on the same global pool takes an injected worker
    // panic mid-run and retries its way back to the exact answer...
    let reused = Exec::new(workers);
    let (b, h, n, d) = (1usize, 2usize, 32usize, 8usize);
    let blocks = Blocks::explicit(8, 8);
    let q = rand(&[b, h, n, d], 50);
    let k = rand(&[b, h, n, d], 51);
    let v = rand(&[b, h, n, d], 52);
    let cfg = AttnConfig::new().causal();
    let plain = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &reused, &mut Hbm::new())
        .expect("fault-free")
        .0;
    let plan = FaultPlan::none().with(FaultSite::BatchedFwd, 1, 0, FaultKind::WorkerPanic);
    let guarded = reused.clone().with_plan(&plan).validated();
    let (out, report) = flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded, &mut Hbm::new())
        .expect("must recover");
    assert_eq!(report.panics, 1, "the injected panic must have fired");
    assert!(report.retries >= 1, "recovery must have retried the faulted item");
    assert_eq!(out.o.data, plain.o.data, "recovered output must be bitwise");

    // ...and the pool that contained that panic then runs the full
    // interleaved workload bitwise-clean.
    assert_eq!(interleaved_pass(&reused), baseline, "pool poisoned by contained panic");
}
