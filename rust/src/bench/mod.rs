//! Shared bench harness: wall-clock measurement (median-of-k), result
//! directories, and the paper-vs-model comparison rows every bench target
//! prints.

use std::path::PathBuf;
use std::time::Instant;

/// Median wall time in seconds of `k` runs of `f` (after one warmup).
pub fn median_time<F: FnMut()>(k: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..k.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// Mean wall time in seconds of `k` runs of `f` (after one warmup) — the
/// perf-trajectory metric BENCH_attn.json records (means compose across
/// runs; medians don't).
pub fn mean_time<F: FnMut()>(k: usize, mut f: F) -> f64 {
    f(); // warmup
    let k = k.max(1);
    let t0 = Instant::now();
    for _ in 0..k {
        f();
    }
    t0.elapsed().as_secs_f64() / k as f64
}

/// Where bench CSVs are written.
pub fn out_dir() -> PathBuf {
    let p = PathBuf::from("bench_out");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Format an optional ms cell ("-" for OOM/unsupported, like the paper).
pub fn ms_cell(v: Option<f64>) -> String {
    match v {
        Some(x) if x >= 100.0 => format!("{x:.0}"),
        Some(x) if x >= 10.0 => format!("{x:.1}"),
        Some(x) => format!("{x:.2}"),
        None => "-".to_string(),
    }
}

/// Geometric mean (used for the LRA overall speedup, App. E.3).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_time_positive() {
        let t = median_time(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn mean_time_positive() {
        let t = mean_time(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn ms_cell_formats() {
        assert_eq!(ms_cell(None), "-");
        assert_eq!(ms_cell(Some(0.43)), "0.43");
        assert_eq!(ms_cell(Some(41.7)), "41.7");
        assert_eq!(ms_cell(Some(9341.3)), "9341");
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
