//! Minimal batched-inference server demo over the logits artifact: a
//! request queue, greedy/temperature sampling, and latency/throughput
//! accounting. Demonstrates the "Python never on the request path"
//! property of the stack: serving is a loop of PJRT executions.

use std::time::Instant;

use anyhow::Result;

use super::trainer::LmTrainer;
use crate::attn::flash::Blocks;
use crate::attn::Exec;
use crate::runtime::Runtime;
use crate::sim::cost;
use crate::util::rng::SplitMix64;

#[derive(Clone, Debug)]
pub struct Completion {
    pub prompt: String,
    pub text: String,
    pub tokens_generated: usize,
    pub latency_ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: usize,
    pub tokens: usize,
    pub total_ms: f64,
}

impl ServeStats {
    pub fn tokens_per_second(&self) -> f64 {
        if self.total_ms == 0.0 {
            0.0
        } else {
            self.tokens as f64 / (self.total_ms / 1e3)
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_ms / self.requests as f64
        }
    }
}

pub struct Server {
    pub trainer: LmTrainer,
    pub temperature: f32,
    pub stats: ServeStats,
    rng: SplitMix64,
}

impl Server {
    pub fn new(trainer: LmTrainer) -> Server {
        Server {
            trainer,
            temperature: 0.8,
            stats: ServeStats::default(),
            rng: SplitMix64::new(0x5EED),
        }
    }

    /// The execution handle the serve path's mirror-side attention work
    /// runs on — the trainer's. Serving shares the trainer's persistent
    /// pool rather than carrying a separate worker-count knob.
    pub fn exec(&self) -> &Exec {
        &self.trainer.exec
    }

    /// Modeled attention accumulator *write* traffic for one full serving
    /// forward — all `n_head` slices of the layer at the serving context
    /// length, in f32 elements: (faithful Algorithm-1 kernel × heads, fast
    /// batched kernel). The serve path routes through the batched entry
    /// point (`attn::batched`), which schedules every head·row-block work
    /// item in one pool but still writes each slice's O/stats exactly once
    /// — heads × (N·d + N) — instead of once per inner iteration; d = 64
    /// is the paper's GPT-2 head dim.
    pub fn modeled_attn_io(&self) -> (u64, u64) {
        let n = self.trainer.n_ctx as u64;
        let heads = self.trainer.n_head as u64;
        let d = 64u64;
        let blocks = Blocks::from_sram(48 * 1024, d as usize, n as usize);
        (
            heads * cost::flash_fwd_stores(n, d, blocks, true),
            cost::flash2_fwd_batched_stores(heads, n, d),
        )
    }

    /// Sample the next byte from logits at `position` with temperature.
    ///
    /// The caller ([`Server::complete`]) validates logits finiteness
    /// before sampling, so the fallthrough return below is only the
    /// benign end-of-rounding case — it can no longer silently convert a
    /// NaN weight vector into "always emit byte vocab-1".
    fn sample(&mut self, logits: &[f32], vocab: usize) -> i32 {
        if self.temperature <= 0.0 {
            return logits
                .iter()
                .take(vocab)
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
        }
        let inv_t = 1.0 / self.temperature;
        let mx = logits.iter().take(vocab).cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> = logits
            .iter()
            .take(vocab)
            .map(|&l| ((l - mx) * inv_t).exp())
            .collect();
        let total: f32 = weights.iter().sum();
        let mut r = self.rng.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i as i32;
            }
        }
        (vocab - 1) as i32
    }

    /// Numeric guardrail on the serving path: a non-finite logit row is
    /// a typed error naming the offending position and value, instead of
    /// silently degenerating into constant output (the old behavior:
    /// NaN weights fell through `sample`'s roulette loop to byte
    /// `vocab-1` every step).
    fn validate_logits(row: &[f32], pos: usize) -> Result<()> {
        if let Some((i, bad)) = row.iter().enumerate().find(|(_, x)| !x.is_finite()) {
            anyhow::bail!(
                "non-finite logit {bad} at vocab index {i}, position {pos}: \
                 refusing to sample from a poisoned distribution"
            );
        }
        Ok(())
    }

    /// Generate `max_new` bytes continuing `prompt` (sliding-window ctx).
    pub fn complete(
        &mut self,
        rt: &mut Runtime,
        prompt: &str,
        max_new: usize,
    ) -> Result<Completion> {
        let n_ctx = self.trainer.n_ctx;
        let t0 = Instant::now();
        let mut tokens: Vec<i32> = prompt.bytes().map(|b| b as i32).collect();
        for _ in 0..max_new {
            // Left-pad/truncate to the fixed artifact window.
            let start = tokens.len().saturating_sub(n_ctx);
            let mut window: Vec<i32> = vec![32; n_ctx.saturating_sub(tokens.len())];
            window.extend(&tokens[start..]);
            let pos = (tokens.len() - start) + n_ctx.saturating_sub(tokens.len()) - 1;
            let logits = self.trainer.logits(rt, &window)?;
            let data = logits.as_f32()?;
            let vocab = logits.shape()[2];
            let row = &data[pos * vocab..(pos + 1) * vocab];
            Self::validate_logits(row, pos)?;
            let next = self.sample(row, vocab);
            tokens.push(next);
        }
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.requests += 1;
        self.stats.tokens += max_new;
        self.stats.total_ms += latency_ms;
        let text: String = tokens
            .iter()
            .skip(prompt.len())
            .map(|&t| {
                let b = t.clamp(0, 255) as u8;
                if (32..127).contains(&b) { b as char } else { '.' }
            })
            .collect();
        Ok(Completion { prompt: prompt.to_string(), text, tokens_generated: max_new, latency_ms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServeStats { requests: 4, tokens: 400, total_ms: 2000.0 };
        assert_eq!(s.tokens_per_second(), 200.0);
        assert_eq!(s.mean_latency_ms(), 500.0);
    }

    #[test]
    fn poisoned_logits_are_a_typed_error_with_provenance() {
        let mut row = vec![0.5f32; 8];
        Server::validate_logits(&row, 3).expect("finite logits pass");
        row[5] = f32::NAN;
        let msg = Server::validate_logits(&row, 3).unwrap_err().to_string();
        assert!(msg.contains("vocab index 5"), "{msg}");
        assert!(msg.contains("position 3"), "{msg}");
        row[5] = f32::INFINITY;
        assert!(Server::validate_logits(&row, 0).is_err());
    }
}
