//! The serving tier: a continuous-batching engine over the split-KV
//! decode kernel and the paged KV cache, plus the original
//! batched-inference demo over the logits artifact.
//!
//! [`ContinuousBatcher`] is the TGI-style admission loop the ROADMAP
//! names (`router/src/infer.rs`): a waiting queue with **token-budget
//! admission** (a request is admitted while the running batch's peak
//! token footprint — prompt + max new tokens per request — fits the
//! budget), a **prefill** step that joins newly admitted requests into
//! the batch through one pooled `flash2_forward_many` dispatch, and a
//! **decode** step that advances every running request one token via
//! `attn::flash2::flash2_decode` over its paged cache
//! (`attn::kv_cache`), filtering finished requests' pages out with the
//! zero-traffic `KvBatch::filter` — the ragged-batch lifecycle.
//!
//! Fault semantics are per-request skip-and-report, like
//! `LmTrainer::train`: everything runs on the caller's plan-carrying
//! [`Exec`] handle, injected faults are retried inside the pool, and a
//! request that exhausts its budget surfaces as a typed `AttnError` —
//! the loop **evicts that one request** (recording the reason) and the
//! rest of the batch continues bitwise as if the victim never faulted
//! (chaos-tested in `rust/tests/chaos.rs`). Request content is
//! synthesized deterministically from each request's seed
//! ([`token_row`]), so the whole serve trace is a pure function of
//! (requests, config, fault plan) — no wall clock on the request path.
//!
//! [`Server`] remains the batched-inference demo over the logits
//! artifact ("Python never on the request path": serving is a loop of
//! PJRT executions).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::trainer::LmTrainer;
use crate::attn::batched::{flash2_forward_many, AttnSlice};
use crate::attn::faults::{AttnError, FaultReport};
use crate::attn::flash::Blocks;
use crate::attn::flash2::flash2_decode;
use crate::attn::kv_cache::KvBatch;
use crate::attn::{AttnConfig, Exec};
use crate::runtime::Runtime;
use crate::sim::cost;
use crate::sim::hbm::Hbm;
use crate::tensor::Tensor;
use crate::util::rng::SplitMix64;

/// Role tags for [`token_row`]'s deterministic row streams.
pub const ROLE_Q: u64 = 1;
pub const ROLE_K: u64 = 2;
pub const ROLE_V: u64 = 3;

/// The deterministic [d] feature row of a request's token at `pos` for
/// one of the Q/K/V roles: a pure function of (seed, role, pos), so a
/// request's rows are identical no matter when it was admitted, which
/// batch it shares, or whether another request faulted — the property
/// the chaos wall asserts bitwise.
pub fn token_row(seed: u64, role: u64, pos: usize, d: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(
        seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ role.wrapping_mul(0xD1B5_4A32_D192_ED03)
            ^ (pos as u64).wrapping_mul(0xA076_1D64_78BD_642F),
    );
    rng.normal_vec(d, 0.5)
}

/// One decode request: `prompt_len` prompt tokens, then generation
/// until `max_new_tokens` output rows exist (the prefill's last row
/// counts as the first, as in TGI). Content is synthesized from `seed`
/// via [`token_row`].
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
}

/// Per-request outcome: the produced attention-output rows, in order,
/// and the eviction reason if the fault plane removed it early.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    /// One [d] row per produced token: the prefill's last output row,
    /// then one row per decode step.
    pub steps: Vec<Vec<f32>>,
    /// `Some(reason)` iff the request was evicted before finishing.
    pub evicted: Option<String>,
}

/// Engine geometry and admission policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Feature dimension of every request.
    pub d: usize,
    /// KV page rows = the kernel's column-tile height (`Blocks::b_c`).
    pub b_c: usize,
    /// Split-KV span size handed to `flash2_decode` (in column tiles).
    pub span_tiles: usize,
    /// Admission budget on the running batch's peak token footprint:
    /// Σ (prompt_len + max_new_tokens) over running requests. A request
    /// that alone exceeds the budget is still admitted into an empty
    /// batch (no livelock), mirroring TGI's single-request floor.
    pub token_budget: usize,
}

/// Aggregate serve-trace report: per-request outcomes plus the merged
/// fault-plane accounting across every pooled dispatch.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests that produced all `max_new_tokens` rows, in completion
    /// order.
    pub completed: Vec<RequestOutcome>,
    /// Requests the fault plane evicted, with their partial output.
    pub evicted: Vec<RequestOutcome>,
    /// Prompt tokens prefilled (successfully joined requests only).
    pub prefill_tokens: usize,
    /// Output rows produced (prefill-first rows + decode rows).
    pub generated_tokens: usize,
    /// Per-request decode-kernel invocations.
    pub decode_steps: usize,
    /// Merged pool reports: retries, contained faults, retry traffic.
    pub faults: FaultReport,
}

/// One running request: its definition, produced rows, and progress.
#[derive(Clone, Debug)]
struct Active {
    req: DecodeRequest,
    generated: usize,
    steps: Vec<Vec<f32>>,
}

/// The continuous-batching serving engine — see the module docs.
pub struct ContinuousBatcher {
    cfg: BatcherConfig,
    waiting: VecDeque<DecodeRequest>,
    running: Vec<Active>,
    kv: KvBatch,
}

impl ContinuousBatcher {
    pub fn new(cfg: BatcherConfig) -> ContinuousBatcher {
        assert!(cfg.d >= 1 && cfg.b_c >= 1 && cfg.span_tiles >= 1, "BatcherConfig: degenerate");
        assert!(cfg.token_budget >= 1, "BatcherConfig: zero token budget");
        let kv = KvBatch::new(cfg.b_c, cfg.d);
        ContinuousBatcher { cfg, waiting: VecDeque::new(), running: Vec::new(), kv }
    }

    /// Enqueue a request into the waiting queue.
    pub fn submit(&mut self, req: DecodeRequest) {
        assert!(req.prompt_len >= 1, "DecodeRequest: empty prompt");
        assert!(req.max_new_tokens >= 1, "DecodeRequest: zero tokens requested");
        self.waiting.push_back(req);
    }

    /// Waiting-queue depth.
    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Running-batch size.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Cached tokens across the running batch (the paged cache's view).
    pub fn cached_tokens(&self) -> usize {
        self.kv.total_tokens()
    }

    fn blocks(&self) -> Blocks {
        Blocks::explicit(self.cfg.b_c, self.cfg.b_c)
    }

    /// Peak token footprint of the running batch — the admitted quantity.
    fn budget_used(&self) -> usize {
        self.running.iter().map(|a| a.req.prompt_len + a.req.max_new_tokens).sum()
    }

    /// Token-budget admission: drain the waiting queue head while the
    /// peak footprint fits (always at least one request into an empty
    /// batch).
    fn admit(&mut self) -> Vec<DecodeRequest> {
        let mut admitted = Vec::new();
        let mut used = self.budget_used();
        while let Some(front) = self.waiting.front() {
            let cost = front.prompt_len + front.max_new_tokens;
            let batch_empty = self.running.is_empty() && admitted.is_empty();
            if !batch_empty && used + cost > self.cfg.token_budget {
                break;
            }
            used += cost;
            admitted.push(self.waiting.pop_front().expect("admit: front just peeked"));
        }
        admitted
    }

    /// Rebuild the page table to exactly `keep` — the TGI `filter` on
    /// request exit. Zero HBM traffic: page ownership moves, no element
    /// is read or written.
    fn filter_kv(&mut self, keep: &[u64]) {
        let kv = std::mem::replace(&mut self.kv, KvBatch::new(self.cfg.b_c, self.cfg.d));
        self.kv = kv.filter(keep);
    }

    /// Which admitted slice a batch-level prefill error names, if any.
    fn error_slice(e: &AttnError) -> Option<usize> {
        match e {
            AttnError::NonFinite { slice, .. } | AttnError::ItemFailed { slice, .. } => {
                Some(*slice)
            }
            _ => None,
        }
    }

    /// Prefill newly admitted requests through ONE pooled
    /// `flash2_forward_many` dispatch (causal over their own prompts) and
    /// join them into the running batch. A typed error names the faulted
    /// slice: that request is evicted (pages filtered out, reason
    /// recorded) and the prefill retries with the survivors — skip and
    /// report, never kill the batch.
    fn prefill(
        &mut self,
        mut admitted: Vec<DecodeRequest>,
        exec: &Exec,
        hbm: &mut Hbm,
        report: &mut ServeReport,
    ) {
        let d = self.cfg.d;
        for req in &admitted {
            self.kv.admit(req.id);
            let mut k_rows = Vec::with_capacity(req.prompt_len * d);
            let mut v_rows = Vec::with_capacity(req.prompt_len * d);
            for pos in 0..req.prompt_len {
                k_rows.extend(token_row(req.seed, ROLE_K, pos, d));
                v_rows.extend(token_row(req.seed, ROLE_V, pos, d));
            }
            self.kv.append_kv(req.id, &k_rows, &v_rows, req.prompt_len, hbm);
        }
        while !admitted.is_empty() {
            let snaps: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> = admitted
                .iter()
                .map(|req| {
                    let cache = self.kv.get(req.id).expect("prefill: cache admitted above");
                    let mut q_rows = Vec::with_capacity(req.prompt_len * d);
                    for pos in 0..req.prompt_len {
                        q_rows.extend(token_row(req.seed, ROLE_Q, pos, d));
                    }
                    (q_rows, cache.snapshot_k(), cache.snapshot_v(), cache.len())
                })
                .collect();
            let slices: Vec<AttnSlice<'_>> = admitted
                .iter()
                .zip(&snaps)
                .map(|(req, (q, k, v, len))| AttnSlice {
                    q,
                    k,
                    v,
                    n: req.prompt_len,
                    n_k: *len,
                    d,
                    cfg: AttnConfig::new().causal(),
                })
                .collect();
            match flash2_forward_many(&slices, self.blocks(), exec, hbm) {
                Ok((outs, rep)) => {
                    report.faults.merge(&rep);
                    for (req, out) in admitted.into_iter().zip(outs) {
                        let last = out.o.data[(req.prompt_len - 1) * d..].to_vec();
                        report.prefill_tokens += req.prompt_len;
                        report.generated_tokens += 1;
                        self.running.push(Active { req, generated: 1, steps: vec![last] });
                    }
                    return;
                }
                Err(e) => {
                    // Evict the named slice and retry with the survivors;
                    // a non-attributable error (shard/preflight) evicts
                    // the whole admitted set — it is a config fault, not
                    // a per-request one.
                    let victims: Vec<DecodeRequest> = match Self::error_slice(&e) {
                        Some(idx) => vec![admitted.remove(idx)],
                        None => admitted.drain(..).collect(),
                    };
                    for req in victims {
                        println!("[serve] request {} evicted at prefill: {e}", req.id);
                        report.evicted.push(RequestOutcome {
                            id: req.id,
                            steps: Vec::new(),
                            evicted: Some(e.to_string()),
                        });
                    }
                    let keep: Vec<u64> = self
                        .running
                        .iter()
                        .map(|a| a.req.id)
                        .chain(admitted.iter().map(|r| r.id))
                        .collect();
                    self.filter_kv(&keep);
                }
            }
        }
    }

    /// Advance every running request one token: append the step's K/V
    /// row to its paged cache (counted), then run the split-KV decode
    /// kernel over the full history. A typed error evicts exactly that
    /// request; every other request's rows are bitwise those of the
    /// fault-free trace (per-request content is a pure function of its
    /// seed, and each request is its own pooled dispatch).
    fn decode_step(&mut self, exec: &Exec, hbm: &mut Hbm, report: &mut ServeReport) {
        let d = self.cfg.d;
        let blocks = Blocks::explicit(1, self.cfg.b_c);
        let mut any_evicted = false;
        let mut idx = 0;
        while idx < self.running.len() {
            let (id, seed) = {
                let a = &self.running[idx];
                (a.req.id, a.req.seed)
            };
            let pos = self.kv.get(id).expect("decode: running request has a cache").len();
            let k_row = token_row(seed, ROLE_K, pos, d);
            let v_row = token_row(seed, ROLE_V, pos, d);
            self.kv.append_kv(id, &k_row, &v_row, 1, hbm);
            let cache = self.kv.get(id).expect("decode: cache still present");
            let n_k = cache.len();
            let q = Tensor::from_vec(&[1, d], token_row(seed, ROLE_Q, pos, d));
            let k = Tensor::from_vec(&[n_k, d], cache.snapshot_k());
            let v = Tensor::from_vec(&[n_k, d], cache.snapshot_v());
            let cfg = AttnConfig::new();
            match flash2_decode(&q, &k, &v, &cfg, blocks, self.cfg.span_tiles, exec, hbm) {
                Ok((out, rep)) => {
                    report.faults.merge(&rep);
                    let active = &mut self.running[idx];
                    active.steps.push(out.o.data);
                    active.generated += 1;
                    report.generated_tokens += 1;
                    report.decode_steps += 1;
                    idx += 1;
                }
                Err(e) => {
                    let active = self.running.remove(idx);
                    println!("[serve] request {} evicted at decode: {e}", active.req.id);
                    report.evicted.push(RequestOutcome {
                        id: active.req.id,
                        steps: active.steps,
                        evicted: Some(e.to_string()),
                    });
                    any_evicted = true;
                }
            }
        }
        if any_evicted {
            let keep: Vec<u64> = self.running.iter().map(|a| a.req.id).collect();
            self.filter_kv(&keep);
        }
    }

    /// Move finished requests out of the batch and drop their pages
    /// (the zero-traffic filter).
    fn retire_finished(&mut self, report: &mut ServeReport) {
        let mut any_finished = false;
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].generated >= self.running[i].req.max_new_tokens {
                let a = self.running.remove(i);
                report.completed.push(RequestOutcome {
                    id: a.req.id,
                    steps: a.steps,
                    evicted: None,
                });
                any_finished = true;
            } else {
                i += 1;
            }
        }
        if any_finished {
            let keep: Vec<u64> = self.running.iter().map(|a| a.req.id).collect();
            self.filter_kv(&keep);
        }
    }

    /// One scheduler tick: admit → prefill the joiners → decode every
    /// running request one token → retire the finished. Public so tests
    /// and the bench can interleave submissions with ticks.
    pub fn step(&mut self, exec: &Exec, hbm: &mut Hbm, report: &mut ServeReport) {
        let admitted = self.admit();
        if !admitted.is_empty() {
            self.prefill(admitted, exec, hbm, report);
        }
        // A max_new_tokens == 1 request is done after prefill.
        self.retire_finished(report);
        self.decode_step(exec, hbm, report);
        self.retire_finished(report);
    }

    /// Drive the engine until every submitted request completed or was
    /// evicted; returns the full serve trace.
    pub fn run(&mut self, exec: &Exec, hbm: &mut Hbm) -> ServeReport {
        let mut report = ServeReport::default();
        while !self.waiting.is_empty() || !self.running.is_empty() {
            self.step(exec, hbm, &mut report);
        }
        report
    }
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub prompt: String,
    pub text: String,
    pub tokens_generated: usize,
    pub latency_ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: usize,
    pub tokens: usize,
    pub total_ms: f64,
}

impl ServeStats {
    pub fn tokens_per_second(&self) -> f64 {
        if self.total_ms == 0.0 {
            0.0
        } else {
            self.tokens as f64 / (self.total_ms / 1e3)
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_ms / self.requests as f64
        }
    }
}

pub struct Server {
    pub trainer: LmTrainer,
    pub temperature: f32,
    pub stats: ServeStats,
    rng: SplitMix64,
}

impl Server {
    pub fn new(trainer: LmTrainer) -> Server {
        Server {
            trainer,
            temperature: 0.8,
            stats: ServeStats::default(),
            rng: SplitMix64::new(0x5EED),
        }
    }

    /// The execution handle the serve path's mirror-side attention work
    /// runs on — the trainer's. Serving shares the trainer's persistent
    /// pool rather than carrying a separate worker-count knob.
    pub fn exec(&self) -> &Exec {
        &self.trainer.exec
    }

    /// Modeled attention accumulator *write* traffic for one full serving
    /// forward — all `n_head` slices of the layer at the serving context
    /// length, in f32 elements: (faithful Algorithm-1 kernel × heads, fast
    /// batched kernel). The serve path routes through the batched entry
    /// point (`attn::batched`), which schedules every head·row-block work
    /// item in one pool but still writes each slice's O/stats exactly once
    /// — heads × (N·d + N) — instead of once per inner iteration; d = 64
    /// is the paper's GPT-2 head dim.
    pub fn modeled_attn_io(&self) -> (u64, u64) {
        let n = self.trainer.n_ctx as u64;
        let heads = self.trainer.n_head as u64;
        let d = 64u64;
        let blocks = Blocks::from_sram(48 * 1024, d as usize, n as usize);
        (
            heads * cost::flash_fwd_stores(n, d, blocks, true),
            cost::flash2_fwd_batched_stores(heads, n, d),
        )
    }

    /// Sample the next byte from logits at `position` with temperature.
    ///
    /// The caller ([`Server::complete`]) validates logits finiteness
    /// before sampling, so the fallthrough return below is only the
    /// benign end-of-rounding case — it can no longer silently convert a
    /// NaN weight vector into "always emit byte vocab-1".
    fn sample(&mut self, logits: &[f32], vocab: usize) -> i32 {
        if self.temperature <= 0.0 {
            return logits
                .iter()
                .take(vocab)
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
        }
        let inv_t = 1.0 / self.temperature;
        let mx = logits.iter().take(vocab).cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> = logits
            .iter()
            .take(vocab)
            .map(|&l| ((l - mx) * inv_t).exp())
            .collect();
        let total: f32 = weights.iter().sum();
        let mut r = self.rng.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i as i32;
            }
        }
        (vocab - 1) as i32
    }

    /// Numeric guardrail on the serving path: a non-finite logit row is
    /// a typed error naming the offending position and value, instead of
    /// silently degenerating into constant output (the old behavior:
    /// NaN weights fell through `sample`'s roulette loop to byte
    /// `vocab-1` every step).
    fn validate_logits(row: &[f32], pos: usize) -> Result<()> {
        if let Some((i, bad)) = row.iter().enumerate().find(|(_, x)| !x.is_finite()) {
            anyhow::bail!(
                "non-finite logit {bad} at vocab index {i}, position {pos}: \
                 refusing to sample from a poisoned distribution"
            );
        }
        Ok(())
    }

    /// Generate `max_new` bytes continuing `prompt` (sliding-window ctx).
    pub fn complete(
        &mut self,
        rt: &mut Runtime,
        prompt: &str,
        max_new: usize,
    ) -> Result<Completion> {
        let n_ctx = self.trainer.n_ctx;
        let t0 = Instant::now();
        let mut tokens: Vec<i32> = prompt.bytes().map(|b| b as i32).collect();
        for _ in 0..max_new {
            // Left-pad/truncate to the fixed artifact window.
            let start = tokens.len().saturating_sub(n_ctx);
            let mut window: Vec<i32> = vec![32; n_ctx.saturating_sub(tokens.len())];
            window.extend(&tokens[start..]);
            let pos = (tokens.len() - start) + n_ctx.saturating_sub(tokens.len()) - 1;
            let logits = self.trainer.logits(rt, &window)?;
            let data = logits.as_f32()?;
            let vocab = logits.shape()[2];
            let row = &data[pos * vocab..(pos + 1) * vocab];
            Self::validate_logits(row, pos)?;
            let next = self.sample(row, vocab);
            tokens.push(next);
        }
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.requests += 1;
        self.stats.tokens += max_new;
        self.stats.total_ms += latency_ms;
        let text: String = tokens
            .iter()
            .skip(prompt.len())
            .map(|&t| {
                let b = t.clamp(0, 255) as u8;
                if (32..127).contains(&b) { b as char } else { '.' }
            })
            .collect();
        Ok(Completion { prompt: prompt.to_string(), text, tokens_generated: max_new, latency_ms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServeStats { requests: 4, tokens: 400, total_ms: 2000.0 };
        assert_eq!(s.tokens_per_second(), 200.0);
        assert_eq!(s.mean_latency_ms(), 500.0);
    }

    #[test]
    fn poisoned_logits_are_a_typed_error_with_provenance() {
        let mut row = vec![0.5f32; 8];
        Server::validate_logits(&row, 3).expect("finite logits pass");
        row[5] = f32::NAN;
        let msg = Server::validate_logits(&row, 3).unwrap_err().to_string();
        assert!(msg.contains("vocab index 5"), "{msg}");
        assert!(msg.contains("position 3"), "{msg}");
        row[5] = f32::INFINITY;
        assert!(Server::validate_logits(&row, 0).is_err());
    }

    fn batcher(token_budget: usize) -> ContinuousBatcher {
        ContinuousBatcher::new(BatcherConfig { d: 8, b_c: 4, span_tiles: 2, token_budget })
    }

    #[test]
    fn token_rows_are_pure_functions_of_seed_role_pos() {
        assert_eq!(token_row(7, ROLE_Q, 3, 16), token_row(7, ROLE_Q, 3, 16));
        assert_ne!(token_row(7, ROLE_Q, 3, 16), token_row(7, ROLE_K, 3, 16));
        assert_ne!(token_row(7, ROLE_Q, 3, 16), token_row(7, ROLE_Q, 4, 16));
        assert_ne!(token_row(7, ROLE_Q, 3, 16), token_row(8, ROLE_Q, 3, 16));
    }

    #[test]
    fn admission_respects_token_budget_but_never_starves_an_empty_batch() {
        let mut b = batcher(10);
        // Footprints 6, 6, 20: first fills past half the budget, second
        // must wait, third alone exceeds the budget entirely.
        b.submit(DecodeRequest { id: 0, prompt_len: 2, max_new_tokens: 4, seed: 1 });
        b.submit(DecodeRequest { id: 1, prompt_len: 2, max_new_tokens: 4, seed: 2 });
        b.submit(DecodeRequest { id: 2, prompt_len: 10, max_new_tokens: 10, seed: 3 });
        let first = b.admit();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(b.waiting(), 2);
        // With id 0 running the batch is non-empty, so nothing else fits.
        b.running.push(Active { req: first.into_iter().next().unwrap(), generated: 1, steps: vec![] });
        assert!(b.admit().is_empty());
        // Empty batch admits the over-budget head rather than livelocking.
        b.running.clear();
        let next = b.admit();
        assert_eq!(next.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        b.waiting.clear();
        b.submit(DecodeRequest { id: 2, prompt_len: 10, max_new_tokens: 10, seed: 3 });
        assert_eq!(b.admit().len(), 1);
    }

    #[test]
    fn serve_trace_completes_every_request_with_the_promised_token_counts() {
        let mut b = batcher(64);
        b.submit(DecodeRequest { id: 10, prompt_len: 5, max_new_tokens: 3, seed: 11 });
        b.submit(DecodeRequest { id: 11, prompt_len: 2, max_new_tokens: 1, seed: 12 });
        b.submit(DecodeRequest { id: 12, prompt_len: 7, max_new_tokens: 4, seed: 13 });
        let exec = Exec::new(2);
        let mut hbm = Hbm::default();
        let report = b.run(&exec, &mut hbm);
        assert_eq!(b.waiting(), 0);
        assert_eq!(b.running(), 0);
        assert_eq!(b.cached_tokens(), 0, "finished requests' pages filtered out");
        assert!(report.evicted.is_empty());
        assert_eq!(report.prefill_tokens, 5 + 2 + 7);
        assert_eq!(report.generated_tokens, 3 + 1 + 4);
        assert_eq!(report.decode_steps, 2 + 0 + 3);
        assert_eq!(report.faults.faults(), 0);
        let mut by_id: Vec<(u64, usize)> =
            report.completed.iter().map(|o| (o.id, o.steps.len())).collect();
        by_id.sort_unstable();
        assert_eq!(by_id, vec![(10, 3), (11, 1), (12, 4)]);
        for out in &report.completed {
            assert!(out.evicted.is_none());
            assert!(out.steps.iter().all(|s| s.len() == 8 && s.iter().all(|x| x.is_finite())));
        }
    }

    #[test]
    fn request_rows_are_bitwise_independent_of_batch_composition() {
        let solo_req = DecodeRequest { id: 42, prompt_len: 6, max_new_tokens: 4, seed: 99 };
        let exec = Exec::new(3);
        let mut solo = batcher(64);
        solo.submit(solo_req.clone());
        let mut hbm = Hbm::default();
        let solo_steps = solo.run(&exec, &mut hbm).completed.remove(0).steps;

        let mut mixed = batcher(64);
        mixed.submit(DecodeRequest { id: 1, prompt_len: 3, max_new_tokens: 6, seed: 5 });
        mixed.submit(solo_req);
        mixed.submit(DecodeRequest { id: 2, prompt_len: 9, max_new_tokens: 2, seed: 6 });
        let mut hbm = Hbm::default();
        let report = mixed.run(&exec, &mut hbm);
        let mixed_steps =
            &report.completed.iter().find(|o| o.id == 42).expect("request 42 completed").steps;
        assert_eq!(&solo_steps, mixed_steps, "shared-batch rows must match the solo trace bitwise");
    }
}
