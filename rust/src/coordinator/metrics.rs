//! Training metrics: loss/accuracy history, EMA smoothing, step timing,
//! CSV export for the loss curves recorded in EXPERIMENTS.md.

use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Point {
    pub step: usize,
    pub loss: f64,
    pub acc: Option<f64>,
    pub lr: f64,
    pub step_seconds: f64,
}

#[derive(Debug)]
pub struct Metrics {
    pub name: String,
    pub points: Vec<Point>,
    pub ema_loss: f64,
    ema_beta: f64,
    started: Instant,
    last_step: Instant,
}

impl Metrics {
    pub fn new(name: &str) -> Metrics {
        Metrics {
            name: name.to_string(),
            points: Vec::new(),
            ema_loss: f64::NAN,
            ema_beta: 0.9,
            started: Instant::now(),
            last_step: Instant::now(),
        }
    }

    pub fn record(&mut self, step: usize, loss: f64, acc: Option<f64>, lr: f64) {
        let now = Instant::now();
        let dt = now.duration_since(self.last_step).as_secs_f64();
        self.last_step = now;
        self.ema_loss = if self.ema_loss.is_nan() {
            loss
        } else {
            self.ema_beta * self.ema_loss + (1.0 - self.ema_beta) * loss
        };
        self.points.push(Point { step, loss, acc, lr, step_seconds: dt });
    }

    pub fn first_loss(&self) -> Option<f64> {
        self.points.first().map(|p| p.loss)
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.loss)
    }

    pub fn total_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Mean step time over the last half of training (post-warmup).
    pub fn steady_step_seconds(&self) -> f64 {
        let half = &self.points[self.points.len() / 2..];
        if half.is_empty() {
            return 0.0;
        }
        half.iter().map(|p| p.step_seconds).sum::<f64>() / half.len() as f64
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,acc,lr,step_seconds")?;
        for p in &self.points {
            writeln!(
                f,
                "{},{:.6},{},{:.6e},{:.4}",
                p.step,
                p.loss,
                p.acc.map(|a| format!("{a:.4}")).unwrap_or_default(),
                p.lr,
                p.step_seconds
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_smooths() {
        let mut m = Metrics::new("t");
        m.record(1, 10.0, None, 1e-3);
        m.record(2, 0.0, None, 1e-3);
        assert!((m.ema_loss - 9.0).abs() < 1e-9);
    }

    #[test]
    fn history_ordered() {
        let mut m = Metrics::new("t");
        for s in 1..=5 {
            m.record(s, 5.0 - s as f64, None, 1e-3);
        }
        assert_eq!(m.first_loss(), Some(4.0));
        assert_eq!(m.last_loss(), Some(0.0));
        assert_eq!(m.points.len(), 5);
    }

    #[test]
    fn csv_writes(){
        let mut m = Metrics::new("t");
        m.record(1, 1.0, Some(0.5), 1e-3);
        let p = std::env::temp_dir().join("flashattn_metrics_test.csv");
        m.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("step,loss"));
        assert!(s.lines().count() == 2);
    }
}
