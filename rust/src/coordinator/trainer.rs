//! Training loops over the AOT artifacts. One PJRT call per step: the
//! fused train-step executable takes (params, m, v, batch, lr, t) and
//! returns (params', m', v', loss[, acc]); the coordinator owns the state
//! vectors and feeds them back — Python never runs.

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{ensure, Context, Result};

use super::config::TrainConfig;
use super::metrics::Metrics;
use crate::attn::{flash2, Exec};
use crate::data::batch::{Batch, ClsDataset};
use crate::data::corpus::Corpus;
use crate::runtime::{Runtime, Value};
use crate::util::rng::SplitMix64;

/// Wall-clock budget for the preflight self-check: the probe workload
/// finishes in milliseconds on any healthy build, so a probe still
/// running after this long is hung (e.g. a deadlocked pool) and must
/// fail fast rather than wedge the trainer at startup.
const PREFLIGHT_BUDGET: std::time::Duration = std::time::Duration::from_secs(30);

/// One-time preflight on the training/serving path: the fast attention
/// kernel *pair* (`attn::flash2` forward + backward, through the shared
/// `attn::attention_backward` entry point) must agree with the
/// paper-faithful reference mirrors, AND the batched multi-head scheduler
/// (`attn::batched` — the [batch, heads, n, d] entry points the GPT-2
/// trainer step, the serve IO model, the sharded driver and the perf
/// benches route through) must agree bitwise with the per-slice pair,
/// before any step runs. The fused train step itself executes as a PJRT
/// artifact; this gate keeps the Rust mirrors honest before they are used
/// for IO claims or serving math. Costs one tiny [48, 16] fwd+bwd workload
/// plus a [2, 2, 24, 8] batched one, once per process. A failure names
/// the broken invariant (`flash2::self_check_report` probe) rather than
/// reporting one opaque scalar, and the probe runs under
/// [`PREFLIGHT_BUDGET`] so a hung check cannot wedge startup.
fn preflight_fast_kernel(exec: &Exec) -> Result<()> {
    static VERDICT: OnceLock<std::result::Result<(), String>> = OnceLock::new();
    let verdict = VERDICT.get_or_init(|| {
        let (tx, rx) = std::sync::mpsc::channel();
        let probe_exec = exec.clone();
        // lint::allow(R1, preflight watchdog: a timeout thread off the numeric path, no output slots)
        std::thread::spawn(move || {
            let _ = tx.send(flash2::self_check_report_on(&probe_exec));
        });
        match rx.recv_timeout(PREFLIGHT_BUDGET) {
            Ok(report) => report.verdict(1e-4).map_err(|e| e.to_string()),
            Err(_) => Err(format!(
                "self-check probe did not finish within {PREFLIGHT_BUDGET:?} (hung preflight)"
            )),
        }
    });
    verdict
        .clone()
        .map_err(|msg| anyhow::anyhow!("fast attention kernel preflight failed: {msg}"))
}

/// A training step whose returned scalars came back non-finite: the
/// parameter/optimizer state was NOT committed. `LmTrainer::train`
/// degrades gracefully on this error (skip-and-report); anything else
/// still aborts the run.
#[derive(Debug)]
pub struct PoisonedStep {
    pub step: usize,
    pub detail: String,
}

impl std::fmt::Display for PoisonedStep {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "poisoned step {}: {} (state not committed)", self.step, self.detail)
    }
}

impl std::error::Error for PoisonedStep {}

/// Shared state-holding core for both trainers.
struct ModelState {
    tag: String,
    params: Vec<Value>,
    m: Vec<Value>,
    v: Vec<Value>,
    n_param_tensors: usize,
    step: usize,
}

impl ModelState {
    fn init(rt: &mut Runtime, tag: &str, seed: i32, exec: &Exec) -> Result<ModelState> {
        preflight_fast_kernel(exec)?;
        let info = rt.manifest.model(tag)?.clone();
        let n = info.param_names.len();
        let params = rt
            .run(&format!("{tag}_init"), &[Value::scalar_i32(seed)])
            .with_context(|| format!("init {tag}"))?;
        ensure!(params.len() == n, "init returned {} tensors, expected {n}", params.len());
        let zeros: Vec<Value> = params
            .iter()
            .map(|p| Value::zeros_like_shape(p.shape()))
            .collect();
        Ok(ModelState {
            tag: tag.to_string(),
            params,
            m: zeros.clone(),
            v: zeros,
            n_param_tensors: n,
            step: 0,
        })
    }

    /// Assemble (params ++ m ++ v ++ extras) and apply the returned state.
    ///
    /// Numeric guardrail: the returned training scalars (loss, accuracy)
    /// are validated for finiteness BEFORE the new parameter/optimizer
    /// state is committed — a NaN/Inf step returns [`PoisonedStep`] with
    /// the model state (including the step counter) untouched, so the
    /// caller can skip-and-report instead of training on from a poisoned
    /// update.
    fn step_with(
        &mut self,
        rt: &mut Runtime,
        extras: Vec<Value>,
        n_scalar_outputs: usize,
    ) -> Result<Vec<f64>> {
        let mut inputs = Vec::with_capacity(3 * self.n_param_tensors + extras.len());
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.extend(extras);
        let mut out = rt.run(&format!("{}_train_step", self.tag), &inputs)?;
        let n = self.n_param_tensors;
        ensure!(out.len() == 3 * n + n_scalar_outputs, "train_step arity");
        let scalars: Vec<f64> = out[3 * n..]
            .iter()
            .map(|v| v.scalar().map(|x| x as f64))
            .collect::<Result<_>>()?;
        if let Some((i, bad)) = scalars.iter().enumerate().find(|(_, x)| !x.is_finite()) {
            return Err(PoisonedStep {
                step: self.step + 1,
                detail: format!("training scalar #{i} is {bad}"),
            }
            .into());
        }
        self.step += 1;
        out.truncate(3 * n);
        let v = out.split_off(2 * n);
        let m = out.split_off(n);
        self.params = out;
        self.m = m;
        self.v = v;
        Ok(scalars)
    }

    /// Save parameters to a simple binary checkpoint (name/shape/data).
    fn save(&self, path: &Path) -> Result<()> {
        use std::io::Write as _;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"FACK0001")?;
        f.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for p in &self.params {
            let data = p.as_f32()?;
            f.write_all(&(p.shape().len() as u32).to_le_bytes())?;
            for &d in p.shape() {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    fn load(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        ensure!(&bytes[..8] == b"FACK0001", "bad checkpoint magic");
        let mut off = 8usize;
        let rd_u32 = |b: &[u8], o: &mut usize| {
            let v = u32::from_le_bytes(b[*o..*o + 4].try_into().unwrap());
            *o += 4;
            v
        };
        let count = rd_u32(&bytes, &mut off) as usize;
        ensure!(count == self.params.len(), "checkpoint tensor count mismatch");
        let mut params = Vec::with_capacity(count);
        for i in 0..count {
            let rank = rd_u32(&bytes, &mut off) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(rd_u32(&bytes, &mut off) as usize);
            }
            ensure!(shape == self.params[i].shape(), "checkpoint shape mismatch at tensor {i}");
            let n: usize = shape.iter().product();
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
                off += 4;
            }
            params.push(Value::F32 { shape, data });
        }
        self.params = params;
        Ok(())
    }
}

/// Causal-LM trainer over a byte corpus (`gpt_*` artifacts).
pub struct LmTrainer {
    state: ModelState,
    pub cfg: TrainConfig,
    pub metrics: Metrics,
    pub batch: usize,
    pub n_ctx: usize,
    /// Attention heads per layer — the head-slice count the serve path's
    /// batched IO model multiplies over (1 if the manifest predates the
    /// n_head config key).
    pub n_head: usize,
    /// Execution handle for every mirror-side attention run this trainer
    /// owns (the preflight probes ran on it; serve-path cross-checks
    /// reuse it). One persistent pool per process: callers clone a
    /// shared handle in rather than passing loose worker counts.
    pub exec: Exec,
    rng: SplitMix64,
}

impl LmTrainer {
    pub fn new(rt: &mut Runtime, cfg: TrainConfig, exec: &Exec) -> Result<LmTrainer> {
        let info = rt.manifest.model(&cfg.model)?;
        let batch = info.cfg_usize("batch").context("model batch")?;
        let n_ctx = info.cfg_usize("n_ctx").context("model n_ctx")?;
        let n_head = info.cfg_usize("n_head").unwrap_or(1);
        let state = ModelState::init(rt, &cfg.model.clone(), cfg.seed as i32, exec)?;
        Ok(LmTrainer {
            state,
            metrics: Metrics::new(&cfg.model),
            batch,
            n_ctx,
            n_head,
            exec: exec.clone(),
            rng: SplitMix64::new(cfg.seed ^ 0xBEEF),
            cfg,
        })
    }

    pub fn n_params(&self) -> usize {
        self.state.params.iter().map(Value::numel).sum()
    }

    /// One fused training step on a batch of [batch, n_ctx+1] tokens.
    pub fn step(&mut self, rt: &mut Runtime, batch: &Batch) -> Result<f64> {
        ensure!(batch.seq == self.n_ctx + 1, "LM batch must be n_ctx+1 tokens");
        let lr = self.cfg.lr_at(self.state.step + 1) as f32;
        let t = (self.state.step + 1) as f32;
        let extras = vec![
            Value::I32 { shape: vec![batch.batch, batch.seq], data: batch.tokens.clone() },
            Value::F32 { shape: vec![], data: vec![lr] },
            Value::F32 { shape: vec![], data: vec![t] },
        ];
        let scalars = self.state.step_with(rt, extras, 1)?;
        let loss = scalars[0];
        self.metrics.record(self.state.step, loss, None, lr as f64);
        Ok(loss)
    }

    /// Full training run over the corpus; returns (first, last) loss.
    ///
    /// Graceful degradation: a [`PoisonedStep`] (non-finite loss — the
    /// state was not committed) is skipped and reported rather than
    /// aborting the run; any other error still propagates.
    pub fn train(&mut self, rt: &mut Runtime, corpus: &Corpus) -> Result<(f64, f64)> {
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        let mut skipped = 0usize;
        for s in 0..self.cfg.steps {
            let batch = corpus.lm_batch(self.batch, self.n_ctx, &mut self.rng);
            let loss = match self.step(rt, &batch) {
                Ok(loss) => loss,
                Err(e) if e.downcast_ref::<PoisonedStep>().is_some() => {
                    skipped += 1;
                    println!("[{}] step {:>4} SKIPPED: {e}", self.cfg.model, s + 1);
                    continue;
                }
                Err(e) => return Err(e),
            };
            if first.is_nan() {
                first = loss;
            }
            last = loss;
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                println!(
                    "[{}] step {:>4}  loss {:.4}  ema {:.4}  ({:.0} ms/step)",
                    self.cfg.model,
                    s + 1,
                    loss,
                    self.metrics.ema_loss,
                    self.metrics.steady_step_seconds() * 1e3
                );
            }
        }
        if skipped > 0 {
            println!(
                "[{}] {skipped} poisoned step(s) skipped (state never committed for them)",
                self.cfg.model
            );
        }
        Ok((first, last))
    }

    /// Held-out loss via the eval artifact.
    pub fn eval_loss(&mut self, rt: &mut Runtime, batch: &Batch) -> Result<f64> {
        let mut inputs = self.state.params.clone();
        inputs.push(Value::I32 { shape: vec![batch.batch, batch.seq], data: batch.tokens.clone() });
        let out = rt.run(&format!("{}_eval_loss", self.cfg.model), &inputs)?;
        Ok(out[0].scalar()? as f64)
    }

    /// Next-token logits for a single [1, n_ctx] prompt (serving path).
    pub fn logits(&mut self, rt: &mut Runtime, tokens: &[i32]) -> Result<Value> {
        ensure!(tokens.len() == self.n_ctx, "prompt must be exactly n_ctx tokens");
        let mut inputs = self.state.params.clone();
        inputs.push(Value::I32 { shape: vec![1, self.n_ctx], data: tokens.to_vec() });
        let mut out = rt.run(&format!("{}_logits", self.cfg.model), &inputs)?;
        Ok(out.remove(0))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.state.save(path)
    }

    pub fn load(&mut self, path: &Path) -> Result<()> {
        self.state.load(path)
    }
}

/// Classifier trainer for the LRA-style tasks (`cls_*`, `longdoc_*`).
pub struct ClsTrainer {
    state: ModelState,
    pub cfg: TrainConfig,
    pub metrics: Metrics,
    pub batch: usize,
    pub n_ctx: usize,
    /// Same role as [`LmTrainer::exec`].
    pub exec: Exec,
    rng: SplitMix64,
}

impl ClsTrainer {
    pub fn new(rt: &mut Runtime, cfg: TrainConfig, exec: &Exec) -> Result<ClsTrainer> {
        let info = rt.manifest.model(&cfg.model)?;
        let batch = info.cfg_usize("batch").context("model batch")?;
        let n_ctx = info.cfg_usize("n_ctx").context("model n_ctx")?;
        let state = ModelState::init(rt, &cfg.model.clone(), cfg.seed as i32, exec)?;
        Ok(ClsTrainer {
            state,
            metrics: Metrics::new(&cfg.model),
            batch,
            n_ctx,
            exec: exec.clone(),
            rng: SplitMix64::new(cfg.seed ^ 0xC1A55),
            cfg,
        })
    }

    pub fn step(&mut self, rt: &mut Runtime, batch: &Batch) -> Result<(f64, f64)> {
        ensure!(batch.seq == self.n_ctx, "cls batch must be n_ctx tokens");
        let lr = self.cfg.lr_at(self.state.step + 1) as f32;
        let t = (self.state.step + 1) as f32;
        let extras = vec![
            Value::I32 { shape: vec![batch.batch, batch.seq], data: batch.tokens.clone() },
            Value::I32 { shape: vec![batch.batch], data: batch.labels.clone() },
            Value::F32 { shape: vec![], data: vec![lr] },
            Value::F32 { shape: vec![], data: vec![t] },
        ];
        let scalars = self.state.step_with(rt, extras, 2)?;
        self.metrics.record(self.state.step, scalars[0], Some(scalars[1]), lr as f64);
        Ok((scalars[0], scalars[1]))
    }

    /// Train on a synthetic dataset; returns mean training accuracy over
    /// the last quarter of steps (a stable proxy for held-out accuracy
    /// since every batch is freshly generated — nothing is memorised).
    pub fn train(&mut self, rt: &mut Runtime, ds: &dyn ClsDataset) -> Result<f64> {
        for s in 0..self.cfg.steps {
            let batch = ds.batch(self.batch, self.n_ctx, &mut self.rng);
            let (loss, acc) = self.step(rt, &batch)?;
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                println!(
                    "[{} on {}] step {:>4}  loss {:.4}  acc {:.3}",
                    self.cfg.model,
                    ds.name(),
                    s + 1,
                    loss,
                    acc
                );
            }
        }
        Ok(self.tail_accuracy())
    }

    /// Mean accuracy over the last 25% of recorded steps.
    pub fn tail_accuracy(&self) -> f64 {
        let pts = &self.metrics.points;
        if pts.is_empty() {
            return 0.0;
        }
        let tail = &pts[pts.len() - pts.len() / 4 - 1..];
        tail.iter().filter_map(|p| p.acc).sum::<f64>() / tail.len() as f64
    }

    /// Held-out evaluation on fresh batches.
    pub fn eval(
        &mut self,
        rt: &mut Runtime,
        ds: &dyn ClsDataset,
        batches: usize,
    ) -> Result<(f64, f64)> {
        let mut tot_loss = 0.0;
        let mut tot_acc = 0.0;
        for _ in 0..batches {
            let batch = ds.batch(self.batch, self.n_ctx, &mut self.rng);
            let mut inputs = self.state.params.clone();
            inputs.push(Value::I32 { shape: vec![batch.batch, batch.seq], data: batch.tokens });
            inputs.push(Value::I32 { shape: vec![batch.batch], data: batch.labels });
            let out = rt.run(&format!("{}_eval", self.cfg.model), &inputs)?;
            tot_loss += out[0].scalar()? as f64;
            tot_acc += out[1].scalar()? as f64;
        }
        Ok((tot_loss / batches as f64, tot_acc / batches as f64))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.state.save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preflight_accepts_the_fast_kernel() {
        preflight_fast_kernel(&Exec::new(3)).unwrap();
        // Cached: second call must not re-run the workload (OnceLock),
        // including on a different handle.
        preflight_fast_kernel(&Exec::scoped(2)).unwrap();
    }

    #[test]
    fn poisoned_step_error_carries_provenance() {
        let e = PoisonedStep { step: 7, detail: "training scalar #0 is NaN".into() };
        let msg = e.to_string();
        assert!(msg.contains("step 7"), "{msg}");
        assert!(msg.contains("not committed"), "{msg}");
        // And it must round-trip through anyhow for the train loop's
        // skip-and-report downcast.
        let any: anyhow::Error = e.into();
        assert!(any.downcast_ref::<PoisonedStep>().is_some());
    }
}
