//! Training configuration + LR schedule (linear warmup, cosine decay —
//! the schedule the paper's GPT-2 recipe uses, scaled down).

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Manifest model tag, e.g. "gpt_flash" or "cls_linformer".
    pub model: String,
    pub steps: usize,
    pub warmup_steps: usize,
    pub lr_max: f64,
    pub lr_min: f64,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "gpt_flash".to_string(),
            steps: 200,
            warmup_steps: 20,
            lr_max: 3e-3,
            lr_min: 3e-4,
            eval_every: 25,
            seed: 0,
        }
    }
}

impl TrainConfig {
    /// LR at step t (1-based): linear warmup then cosine decay to lr_min.
    pub fn lr_at(&self, t: usize) -> f64 {
        if t <= self.warmup_steps {
            return self.lr_max * t as f64 / self.warmup_steps.max(1) as f64;
        }
        let span = (self.steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let frac = ((t - self.warmup_steps) as f64 / span).min(1.0);
        self.lr_min
            + 0.5 * (self.lr_max - self.lr_min) * (1.0 + (std::f64::consts::PI * frac).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let c = TrainConfig {
            warmup_steps: 10,
            lr_max: 1.0,
            lr_min: 0.0,
            steps: 100,
            ..Default::default()
        };
        assert!((c.lr_at(5) - 0.5).abs() < 1e-9);
        assert!((c.lr_at(10) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cosine_decays_to_min() {
        let c = TrainConfig {
            warmup_steps: 10,
            lr_max: 1.0,
            lr_min: 0.1,
            steps: 100,
            ..Default::default()
        };
        assert!((c.lr_at(100) - 0.1).abs() < 1e-6);
        assert!(c.lr_at(50) < 1.0 && c.lr_at(50) > 0.1);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let c = TrainConfig { warmup_steps: 5, steps: 50, ..Default::default() };
        let mut prev = f64::INFINITY;
        for t in 6..=50 {
            let lr = c.lr_at(t);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }
}
