//! L3 coordinator: the training/serving driver that owns the event loop.
//!
//! FlashAttention's contribution lives at L1/L2 (the kernel), so per the
//! architecture this layer is a driver: it loads the AOT train-step
//! executables, owns parameters/optimizer state as host values fed back
//! each step, runs the data pipeline and LR schedule, logs metrics, and
//! serves batched inference from the logits artifact.

pub mod config;
pub mod metrics;
pub mod server;
pub mod tasks;
pub mod trainer;

pub use config::TrainConfig;
pub use metrics::Metrics;
pub use trainer::{ClsTrainer, LmTrainer};
