//! Experiment drivers wiring datasets to trainers — the building blocks the
//! bench targets (Tables 3/5/6) call.

use anyhow::Result;

use super::config::TrainConfig;
use super::trainer::ClsTrainer;
use crate::attn::Exec;
use crate::data::batch::ClsDataset;
use crate::data::image::ImageCls;
use crate::data::listops::ListOps;
use crate::data::pathfinder::Pathfinder;
use crate::data::retrieval::Retrieval;
use crate::data::textcls::TextCls;
use crate::runtime::Runtime;

/// Result of one (model, task) fine-tune.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub model: String,
    pub task: &'static str,
    pub accuracy: f64,
    pub eval_loss: f64,
    pub seconds: f64,
    pub ms_per_step: f64,
}

/// Train `model` on `ds` for `steps` steps and evaluate.
pub fn run_task(
    rt: &mut Runtime,
    model: &str,
    ds: &dyn ClsDataset,
    steps: usize,
    seed: u64,
    exec: &Exec,
) -> Result<TaskResult> {
    let cfg = TrainConfig {
        model: model.to_string(),
        steps,
        warmup_steps: (steps / 10).max(1),
        lr_max: 2e-3,
        lr_min: 2e-4,
        eval_every: (steps / 4).max(1),
        seed,
    };
    let mut tr = ClsTrainer::new(rt, cfg, exec)?;
    let t0 = std::time::Instant::now();
    tr.train(rt, ds)?;
    let seconds = t0.elapsed().as_secs_f64();
    let (eval_loss, accuracy) = tr.eval(rt, ds, 8)?;
    Ok(TaskResult {
        model: model.to_string(),
        task: ds.name(),
        accuracy,
        eval_loss,
        seconds,
        ms_per_step: tr.metrics.steady_step_seconds() * 1e3,
    })
}

/// The LRA-style task suite at the classifier context length.
pub fn lra_tasks(n_ctx: usize) -> Vec<Box<dyn ClsDataset>> {
    vec![
        Box::new(ListOps::default()),
        Box::new(TextCls::default()),
        Box::new(Retrieval::default()),
        Box::new(ImageCls::for_seq(n_ctx)),
        Box::new(Pathfinder::for_seq(n_ctx)),
    ]
}

/// Chance accuracy for a dataset (the Table 6 "random performance" bar).
pub fn chance_accuracy(ds: &dyn ClsDataset) -> f64 {
    1.0 / ds.n_classes() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_all_five_lra_tasks() {
        let tasks = lra_tasks(128);
        assert_eq!(tasks.len(), 5);
        let names: Vec<_> = tasks.iter().map(|t| t.name()).collect();
        assert_eq!(names, vec!["ListOps", "Text", "Retrieval", "Image", "Pathfinder"]);
    }

    #[test]
    fn chance_levels() {
        assert_eq!(chance_accuracy(&ListOps::default()), 0.1);
        assert_eq!(chance_accuracy(&TextCls::default()), 0.5);
    }
}
