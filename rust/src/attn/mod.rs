//! Pure-Rust mirrors of the paper's algorithms, in two roles:
//!
//! 1. **Numeric**: independent implementations of Algorithm 0 (standard),
//!    Algorithms 1/2/4 (FlashAttention fwd/bwd) and Algorithm 5
//!    (block-sparse) used to cross-check the PJRT artifacts and each other
//!    (three-way agreement: Rust mirror == Pallas kernel == jnp oracle).
//! 2. **Instrumented**: every function takes a `sim::hbm::Hbm` counter and
//!    records loads/stores at exactly the points the paper's pseudo-code
//!    touches HBM, turning the IO-complexity theorems into measurements.
//!
//! # Two-kernel policy
//!
//! The crate deliberately carries **two** exact forward kernels:
//!
//! * [`flash::flash_forward`] — the *faithful instrumented reference*.
//!   Loop order, accumulator round-trips and HBM accounting match
//!   Algorithm 1 line for line (K/V-outer, O/l/m read-modified-written to
//!   HBM every inner iteration). Its measured traffic realises the
//!   Θ(N²d²/M) count of Theorem 2, which several tests and figures assert
//!   exactly — so this kernel must stay slow-but-faithful.
//! * [`flash2::flash2_forward`] — the *fast production kernel*
//!   (FlashAttention-2-style): outer loop over Q row blocks so the O/ℓ
//!   accumulators stay on chip for the whole K/V sweep, a single
//!   normalisation epilogue per row, one logsumexp statistic instead of
//!   the (l, m) pair, register-blocked micro-kernels
//!   (`tensor::dot4`/`tensor::pv_accum`) and `std::thread::scope`
//!   parallelism across row blocks. Everything on a hot path (the
//!   sequence-parallel sharded driver, the coordinator preflight, the
//!   serve-path IO model, the perf benches) routes through it; the
//!   reference kernel remains the oracle it is tested against.
//!
//! Both kernels produce softmax statistics; [`AttnStats`] abstracts over
//! the two representations so the backward pass accepts either.
//!
//! All functions operate on one batch*head slice `[n, d]`; callers fold the
//! leading dims.

pub mod block_sparse;
pub mod distributed;
pub mod flash;
pub mod flash2;
pub mod masks;
pub mod standard;

use crate::tensor::Tensor;

/// Shared configuration for the attention mirrors.
#[derive(Clone, Debug)]
pub struct AttnConfig {
    /// Softmax scaling tau; None => 1/sqrt(d).
    pub tau: Option<f32>,
    pub causal: bool,
    /// Valid key length (padding mask); None => n.
    pub kv_len: Option<usize>,
    pub dropout_p: f32,
    pub dropout_seed: u32,
    /// batch*head index — seeds the dropout counter stream.
    pub bh_index: u32,
}

impl Default for AttnConfig {
    fn default() -> Self {
        AttnConfig {
            tau: None,
            causal: false,
            kv_len: None,
            dropout_p: 0.0,
            dropout_seed: 0,
            bh_index: 0,
        }
    }
}

impl AttnConfig {
    pub fn causal() -> Self {
        AttnConfig { causal: true, ..Default::default() }
    }

    pub fn tau_for(&self, d: usize) -> f32 {
        self.tau.unwrap_or(1.0 / (d as f32).sqrt())
    }
}

/// Row-wise softmax statistics saved by a forward pass, in either of the
/// two equivalent representations:
///
/// * `Pair` — the paper's (l, m) pair (Algorithm 1/2): row max `m_i` and
///   the sum of exponentials `l_i` relative to it.
/// * `Lse` — the single logsumexp `L_i = m_i + ln(l_i)` (Rabe & Staats
///   2021; FlashAttention-2), which is all the backward pass needs:
///   `P_ij = exp(s_ij - L_i)`.
///
/// [`flash::flash_backward`] consumes either, so outputs from the faithful
/// kernel and the fast kernel are interchangeable.
#[derive(Clone, Copy, Debug)]
pub enum AttnStats<'a> {
    Pair { l: &'a [f32], m: &'a [f32] },
    Lse(&'a [f32]),
}

impl AttnStats<'_> {
    pub fn len(&self) -> usize {
        match self {
            AttnStats::Pair { l, .. } => l.len(),
            AttnStats::Lse(lse) => lse.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logsumexp of row `r` under either representation.
    #[inline]
    pub fn lse(&self, r: usize) -> f32 {
        match self {
            AttnStats::Pair { l, m } => m[r] + l[r].max(1e-37).ln(),
            AttnStats::Lse(lse) => lse[r],
        }
    }

    /// Materialise the logsumexp vector (diagnostics / serialisation).
    pub fn to_lse_vec(&self) -> Vec<f32> {
        (0..self.len()).map(|r| self.lse(r)).collect()
    }
}

/// Forward outputs: O plus the softmax statistics the paper saves (l, m).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub o: Tensor,
    pub l: Vec<f32>,
    pub m: Vec<f32>,
}

impl AttnOutput {
    /// Borrow the saved statistics in (l, m) form for the backward pass.
    pub fn stats(&self) -> AttnStats<'_> {
        AttnStats::Pair { l: &self.l, m: &self.m }
    }
}

/// Gradients returned by the backward passes.
#[derive(Clone, Debug)]
pub struct AttnGrads {
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_pair_and_lse_agree() {
        let l = vec![2.0f32, 0.5, 1.0];
        let m = vec![0.0f32, 1.5, -2.0];
        let pair = AttnStats::Pair { l: &l, m: &m };
        let lse_vec = pair.to_lse_vec();
        let lse = AttnStats::Lse(&lse_vec);
        assert_eq!(pair.len(), 3);
        assert!(!pair.is_empty());
        for r in 0..3 {
            let expect = m[r] + l[r].ln();
            assert!((pair.lse(r) - expect).abs() < 1e-6);
            assert!((lse.lse(r) - expect).abs() < 1e-6);
        }
    }
}
