//! Pure-Rust mirrors of the paper's algorithms, in two roles:
//!
//! 1. **Numeric**: independent implementations of Algorithm 0 (standard),
//!    Algorithms 1/2/4 (FlashAttention fwd/bwd) and Algorithm 5
//!    (block-sparse) used to cross-check the PJRT artifacts and each other
//!    (three-way agreement: Rust mirror == Pallas kernel == jnp oracle).
//! 2. **Instrumented**: every function takes a `sim::hbm::Hbm` counter and
//!    records loads/stores at exactly the points the paper's pseudo-code
//!    touches HBM, turning the IO-complexity theorems into measurements.
//!
//! # Two-kernel policy
//!
//! The crate deliberately carries **two** exact forward kernels:
//!
//! * [`flash::flash_forward`] — the *faithful instrumented reference*.
//!   Loop order, accumulator round-trips and HBM accounting match
//!   Algorithm 1 line for line (K/V-outer, O/l/m read-modified-written to
//!   HBM every inner iteration). Its measured traffic realises the
//!   Θ(N²d²/M) count of Theorem 2, which several tests and figures assert
//!   exactly — so this kernel must stay slow-but-faithful.
//! * [`flash2::flash2_forward`] — the *fast production kernel*
//!   (FlashAttention-2-style): outer loop over Q row blocks so the O/ℓ
//!   accumulators stay on chip for the whole K/V sweep, a single
//!   normalisation epilogue per row, one logsumexp statistic instead of
//!   the (l, m) pair, register-blocked micro-kernels
//!   (`tensor::dot4`/`tensor::pv_accum`) and `std::thread::scope`
//!   parallelism across row blocks. Everything on a hot path (the
//!   sequence-parallel sharded driver, the coordinator preflight, the
//!   serve-path IO model, the perf benches) routes through it; the
//!   reference kernel remains the oracle it is tested against.
//!
//! The policy extends to the **backward pair**:
//!
//! * [`flash::flash_backward`] — faithful Algorithm 4 (K/V-outer, dQ_i
//!   read-modify-written to HBM every inner tile, per its line 21). Its
//!   instrumented traffic matches `sim::cost::flash_bwd` exactly: this is
//!   the IO-theorem oracle for gradient claims and must stay
//!   slow-but-faithful.
//! * [`flash2::flash2_backward`] — the fast production gradient kernel:
//!   `D = rowsum(dO ∘ O)` precomputed in one epilogue pass, a Q-outer dQ
//!   phase with the accumulator on chip for the whole K/V stream (written
//!   once), and a column-block-parallel dK/dV phase — both recomputing
//!   `P = exp(s − L)` from the logsumexp through the same register-blocked
//!   micro-kernels, bitwise worker-count independent. The mirror-side
//!   gradient hot paths — the trainer's preflight gate and the perf
//!   benches — route through it (`sim::cost::flash2_bwd` mirrors its
//!   traffic); the fused train step itself still executes as a PJRT
//!   artifact.
//!
//! And to the **sparse pair** (§3.3 block-sparse FlashAttention, the
//! Θ(Nd + N²d²s/M) claim of Proposition 4):
//!
//! * [`block_sparse::block_sparse_forward`] — faithful Algorithm 5: the
//!   dense tiled loop with zero blocks skipped, K/V-outer with
//!   accumulator round-trips, local key coordinates. This is the
//!   instrumented reference for Proposition-4 IO claims and the oracle
//!   the fast sparse kernel is tested against; it must stay
//!   slow-but-faithful.
//! * [`block_sparse::block_sparse2_forward`] /
//!   [`block_sparse::block_sparse2_backward`] — the fast production
//!   sparse pair: exactly the flash2 sweeps (Q-outer forward, two-phase
//!   backward, pool workers via [`Exec`], bitwise
//!   worker-count-independent) with the `BlockMask` zero-block filter
//!   fused into each stream — the filter is the only difference, so a
//!   dense mask reproduces the dense pair bit for bit. Mask columns are
//!   **global key tiles**: a key shard at a tile-aligned
//!   [`AttnConfig::kv_offset`] reads the same global mask window the
//!   unsharded kernel reads, so the sequence-parallel driver slices
//!   sparse workloads with no mask surgery
//!   ([`distributed::block_sparse_shard_partials`]). Hot sparse paths —
//!   the batched scheduler (`batched::block_sparse2_forward_batched` /
//!   `_backward_batched`, per-head masks allowed), the
//!   [`BackwardKernel::BlockSparse2`] role and the perf benches — route
//!   through this pair; `sim::cost::block_sparse2_fwd`/`_bwd` mirror
//!   its traffic access-for-access, strictly decreasing in the number
//!   of live blocks.
//!
//! Every `AttnGrads` producer is reachable through the shared
//! [`attention_backward`] entry point, selected by [`BackwardKernel`] —
//! call sites pick a policy role, not a concrete function.
//!
//! **One execution handle, batched entry points.** Every parallel
//! attention schedule runs on an [`Exec`] handle ([`exec`]), which
//! bundles the worker count, the fault-injection plan and the validation
//! flag, and selects between two execution modes: [`Exec::new`] — a
//! **persistent work-stealing pool**, spawned once per process and
//! parked between calls, so repeated small calls stop paying a
//! thread-spawn tax — and [`Exec::scoped`] — per-call
//! `std::thread::scope` workers, the fresh-pool oracle the persistent
//! mode is bitwise-tested against. Real workloads are
//! [batch, heads, n, d]; scheduling them one slice at a time idles
//! workers on short sequences — the occupancy gap FlashAttention-2
//! attributes most of its speedup to closing. [`batched`] therefore
//! flattens every batch·head·row-block (and column-block) work item
//! into a single `Exec` run: `flash2_forward_batched` /
//! `flash2_backward_batched` (and, batched across shards, the
//! sequence-parallel driver in [`distributed`]) are what the trainer
//! preflight, the serve IO model and the perf benches call. Per-slice
//! kernel calls remain for tests and reference use only: they are the
//! oracle the batched scheduler is bitwise-tested against. Batching
//! never changes per-slice HBM traffic
//! (`sim::cost::flash2_fwd_batched` = slices × per-slice, asserted
//! exactly), and the merged totals are identical on either execution
//! mode, so every IO claim carries over unchanged.
//!
//! **The sharded sequence-parallel path covers causal + dropout.** The
//! multi-device driver ([`distributed`]) shards the key sequence, and
//! every shard kernel runs in *global key coordinates* via
//! [`AttnConfig::kv_offset`]: the causal test, the key-padding test and
//! the counter-based dropout stream all see `kv_offset + local_col`, so
//! mask and dropout decisions are identical to the single-device kernel
//! no matter how K/V was sliced. Two schedules exist. The **ring
//! schedule** ([`distributed::flash_forward_sharded`] /
//! [`distributed::flash_backward_sharded`]) keeps each row block's
//! on-chip state resident while the key shards visit in global order —
//! the per-row arithmetic is the single-device kernel's op sequence, so
//! output is **bitwise identical** to `flash2` for any shard count and
//! worker count. The **tree schedule**
//! ([`distributed::shard_partials`] + [`distributed::merge_partials`])
//! computes one softmax partial per shard and merges associatively in
//! any order — exact to fp rounding, the paper's §5 decomposition.
//! Shards wholly above the causal diagonal or wholly beyond `kv_len`
//! never become work items on either schedule.
//!
//! # Failure semantics
//!
//! The execution plane ([`exec::Exec`], which the [`batched`] scheduler
//! and both sharded schedules in [`distributed`] run on) is
//! fault-tolerant by construction: workers race only for *work items*,
//! never for output slots, so any item can be recomputed into its
//! disjoint window without touching the rest — the paper's §5
//! associative-merge decomposition used as a recovery primitive.
//! Concretely ([`faults`] holds the types):
//!
//! * **What is retried.** A work item whose worker panics
//!   (`catch_unwind`-contained), whose output fails the finiteness
//!   guardrail, or whose completion record is lost is requeued with its
//!   output windows zeroed, up to [`faults::MAX_ATTEMPTS`] total
//!   attempts. Because the re-run performs the identical arithmetic
//!   into a fresh window, recovered output is **bitwise identical** to
//!   the fault-free run for every schedule and worker count. The tree
//!   schedule recomputes a failed shard's partial and re-merges through
//!   the associative `merge_partials`; the ring schedule recomputes the
//!   failed row-block item (which re-streams every shard). Retries are
//!   accounted access-for-access: each faulted attempt that ran to
//!   completion adds exactly its per-item traffic
//!   (`sim::cost::flash2_fwd_item` and friends) to the
//!   [`faults::FaultReport`].
//! * **What is reported.** The batched and sharded entry points take an
//!   [`Exec`] handle (carrying the fault plan and validation flag) and
//!   return `Result<(output, FaultReport), AttnError>`: a typed
//!   [`faults::AttnError`] names the site, slice (batch, head), and
//!   block of an item that exhausted its attempt budget or stayed
//!   non-finite, and a malformed shard layout names the shard and the
//!   reason ([`faults::AttnError::ShardConfig`]) instead of silently
//!   substituting an all-masked output. Dead shards (wholly beyond
//!   `kv_len`, wholly above the causal diagonal, or all-zero in the
//!   sparse mask) are classified in `FaultReport::dead_shards`. The
//!   pre-`Exec` `_checked` twins are gone; per-call guarded execution
//!   is spelled `Exec::scoped(w).with_plan(plan).validated()`. The
//!   per-slice fast
//!   sparse pair keeps its infallible signature: its pool still
//!   contains panics and retries, and only after the budget is
//!   exhausted does it panic — with the typed error's message.
//! * **What degrades.** The coordinator treats a poisoned training step
//!   (non-finite loss/grad-norm) as skip-and-report: parameters are not
//!   committed, the step is counted, training continues. The server
//!   validates logits before sampling and returns a typed error rather
//!   than serving garbage. The trainer preflight runs under a
//!   wall-clock budget and reports *which* invariant broke
//!   (`flash2::self_check_report`).
//!
//! All kernels produce softmax statistics; [`AttnStats`] abstracts over
//! the two representations so either backward accepts either forward's
//! output. Fully-masked rows (e.g. `kv_len` = 0 shards) have defined
//! semantics on the fast/production paths — flash2 forward, the sharded
//! driver and `merge_partials`, and both tiled backwards: zero output
//! row, logsumexp −∞ (`AttnStats::lse` maps zero-mass `(l, m)` pairs to
//! −∞ too), zero gradient — never NaN/Inf. The faithful `flash_forward`
//! keeps Algorithm 1's literal arithmetic and is not given special
//! masked-row handling.
//!
//! # Invariant catalog (machine-checked)
//!
//! The determinism and IO guarantees above are enforced by `cargo run -p
//! lint` (a token-level scanner plus a semantic call-graph pass over
//! `rust/src`, `rust/tests` and `examples/`, blocking in CI) as seven
//! named rules, plus a runtime auditor. A violation is an error listing
//! file:line and a fix hint; the only escape hatch is an explicit
//! `// lint::allow(Rn, reason)` comment pragma on (or directly above)
//! the offending line.
//!
//! * **R1 — pool routing.** No raw `std::thread::spawn` /
//!   `std::thread::scope` outside [`exec`]'s `spawn_worker` /
//!   `run_scoped` — the persistent pool's sole spawn site and the scoped
//!   oracle. Every parallel schedule goes through [`Exec`], so fault
//!   containment, retry accounting and the audit hooks cover it by
//!   construction. (The per-slice `flash2` reference kernels keep their
//!   historical scopes under pragmas — they are the oracle the pool is
//!   bitwise-tested against.)
//! * **R2 — determinism hazards.** Inside `attn/`, `sim/`, `runtime/`,
//!   and everywhere in `rust/tests/` and `examples/` (a nondeterministic
//!   harness can mask a determinism regression): no `HashMap`/`HashSet`
//!   (iteration order), no `Instant::now`/`SystemTime` (wall clock), no
//!   `std::thread::current`/`ThreadId` (thread-identity-dependent
//!   branching). Built-in allowlist: `runtime/exec.rs`'s compile cache
//!   and compile-time metric, which never touch kernel numerics.
//! * **R3 — no unsafe.** `unsafe` is banned tree-wide, backing the
//!   crate-level `#![forbid(unsafe_code)]`.
//! * **R4 — coverage cross-reference.** Every `pub fn *_forward*` /
//!   `*_backward*` / `*_decode*` in [`flash2`], [`batched`],
//!   [`block_sparse`] and
//!   [`distributed`] must be exercised by name in the IO-exactness wall
//!   (`rust/tests/io_complexity.rs`, against a `sim::cost` form), and
//!   every [`faults::FaultSite`] variant must be injected somewhere in
//!   `rust/tests/chaos.rs`. New hot paths cannot silently skip the test
//!   walls.
//! * **R5 — counted-access discipline.** Inside the kernel files
//!   ([`flash`], [`flash2`], [`standard`], [`block_sparse`],
//!   [`kv_cache`]), any
//!   function that handles the `sim::Hbm` meter may touch the role-named
//!   HBM buffers (q/k/v/o/dout/lse/dq/dk/dv and their `*_win`-style
//!   windows) only through the sanctioned counted accessors (the
//!   `stream_kv*` loaders, the `*_sweep` drivers, `write_epilogue` and
//!   the top-level entries). Raw `buf[i]` indexing and
//!   `chunks`/`chunks_mut` carves of a role buffer are findings —
//!   untouched bytes the cost model never saw. Post-run stitches that
//!   immediately `copy_from_slice`/`extend_from_slice` are exempt (the
//!   traffic was counted when the window was produced).
//! * **R6 — reachability routing.** A call-graph check (replacing R4's
//!   old parameter-list heuristic): batched/sharded `pub` fwd/bwd
//!   entries must take an [`Exec`] handle; every Exec-carrying `pub`
//!   fwd/bwd/decode entry in the hot modules must reach the pool sink
//!   (`Exec::run`) through a chain of Exec-carrying calls; and any
//!   fwd/bwd/decode entry reachable from the serving/training roots
//!   (`Server`/`LmTrainer`/`ClsTrainer`/`run_task`) without an `Exec`
//!   is a finding. (The per-slice `flash2` oracles carry R6 pragmas:
//!   they take the handle for its worker count but run their own
//!   scoped threads by design.)
//! * **R7 — exactly-once-commit shape.** For every
//!   `faults::PoolItem` impl, `reset`, `poison` and `check_finite`
//!   must touch exactly the window fields its `claims()` manifests —
//!   a forgotten window survives retries stale and dodges the
//!   guardrail scan. And at every pool run site whose closure names an
//!   item type, each claimed window must be stitched back into its
//!   output exactly once (`copy_from_slice` cross-reference): zero
//!   commits lose the work, two clobber it.
//!
//! R1–R4 are token-level (`lint/src/lib.rs`); R5–R7 ride the
//! per-function models and call graph in `lint/src/semantic.rs`. Every
//! rule ships must-flag and must-pass fixtures (`lint/fixtures/`) so
//! the rules themselves cannot silently rot.
//!
//! **Adding a new kernel or pool site** (the recipe the rules encode):
//! take `exec: &Exec` on the public entry and hand it down to
//! `Exec::run` (R6); touch HBM role buffers only through a counted
//! accessor — if the kernel needs a new access pattern, write a
//! counting helper next to the `*_sweep`s and add it to the sanctioned
//! list in `lint/src/semantic.rs` with a test (R5); give the work item
//! a `claims()` manifest agreeing with `reset`/`poison`/`check_finite`
//! and stitch each claimed window exactly once after the run (R7);
//! name the entry in `rust/tests/io_complexity.rs` and inject its
//! `FaultSite` in `rust/tests/chaos.rs` (R4). A pragma is the escape
//! hatch of last resort: it must name the rule and carry a reason, an
//! unused pragma is itself a finding, and the reviewer bar is "the
//! rule is wrong here", not "the rule is inconvenient here".
//!
//! **Worked example — the split-KV decode kernel** (PR 10, the serving
//! tier's pool site, built exactly by the recipe above):
//! [`flash2::flash2_decode`] takes `exec: &Exec` and dispatches one
//! `DecodeItem` per KV span straight into `Exec::run`
//! (`FaultSite::DecodeSpan`) (R6). Spans only *score* their tiles —
//! order-free work — through the sanctioned counted accessor
//! `score_span_tiles`; the order-sensitive online-softmax absorb
//! replays the spilled score tiles at the merge site, in global tile
//! order, through `absorb_scored_tiles` — the literal loop body of the
//! fused sweep — so the output is bitwise identical to
//! [`flash2::flash2_forward`] for any worker count and span size *by
//! construction*, and the paged-cache accessors in [`kv_cache`]
//! (`append_kv`/`k_tile`/`v_tile`) joined the sanctioned list the same
//! way (R5). `DecodeItem` claims its `s_win` spill window, which
//! `reset`/`poison`/`check_finite` agree on and the merge stitches
//! exactly once (R7). The kernel is named in the IO wall against
//! `sim::cost::flash2_decode` (access-for-access, ragged spans
//! included) and `DecodeSpan` faults are injected in the chaos wall —
//! both per-kernel and through the continuous-batching serving loop
//! (`coordinator::server`), where an exhausted retry budget surfaces as
//! a typed error and the loop evicts that one request (R4).
//!
//! **Audit contract** (`--features audit`, see `attn::audit`): every
//! pool run checks that work items claim pairwise-disjoint output
//! windows before any worker spawns, that the address-free item→slot
//! fingerprint is identical across worker and shard counts, and that
//! every item commits exactly once on success — "workers race for
//! items, never for output" as a checked property, compiled out of the
//! plain build entirely. On top of that sits the schedule-space race
//! explorer (`audit::explore_schedules`): it re-runs a pool site under
//! many distinct drain orders — exhaustively over all permutations for
//! small item counts, seeded-adversarial (reversals, interleavings,
//! worst-case rank shuffles) for large — across worker counts and
//! under fault injection, asserting bitwise-identical outputs and
//! identical commit fingerprints for every schedule. R5–R7 prove the
//! shape statically; the explorer runs the schedules the shape admits.
//!
//! All functions operate on one batch*head slice `[n, d]`; callers fold the
//! leading dims.

#[cfg(feature = "audit")]
pub mod audit;
pub mod batched;
pub mod block_sparse;
pub mod distributed;
pub mod exec;
pub mod faults;
pub mod flash;
pub mod flash2;
pub mod kv_cache;
pub mod masks;
pub mod standard;

pub use exec::Exec;

use crate::tensor::Tensor;

/// Shared configuration for the attention mirrors.
///
/// **Global key coordinates.** A kernel invocation may see only a slice
/// of the key sequence (the sequence-parallel sharded path hands each
/// shard a contiguous K/V range). `kv_offset` is the global column index
/// of the slice's local column 0, and every masked/dropout decision is
/// made in global coordinates `kv_offset + local_col`:
///
/// * the causal test is `kv_offset + col > row`,
/// * the padding test compares `kv_offset + col` against `kv_len`
///   (which is itself a *global* key count),
/// * the dropout counter stream hashes the global column, so a shard
///   reproduces exactly the keep/drop pattern the unsharded kernel
///   draws for the same entries.
///
/// With `kv_offset = 0` (every non-sharded caller) all of this reduces
/// to the local-coordinate behaviour.
#[derive(Clone, Debug, Default)]
pub struct AttnConfig {
    /// Softmax scaling tau; None => 1/sqrt(d).
    pub tau: Option<f32>,
    pub causal: bool,
    /// Valid key length (padding mask) in GLOBAL key coordinates;
    /// None => every key.
    pub kv_len: Option<usize>,
    pub dropout_p: f32,
    pub dropout_seed: u32,
    /// batch*head index — seeds the dropout counter stream.
    pub bh_index: u32,
    /// Global key-column index of this slice's local key column 0.
    /// Non-zero only on the sharded sequence-parallel path.
    pub kv_offset: usize,
}

impl AttnConfig {
    /// Start a builder chain from the defaults:
    /// `AttnConfig::new().causal().dropout(0.1, 7).kv_window(4, 33)`.
    pub fn new() -> Self {
        AttnConfig::default()
    }

    /// Enable the causal mask (judged in global key coordinates).
    pub fn causal(mut self) -> Self {
        self.causal = true;
        self
    }

    /// Enable dropout with keep-probability `1 - p` and the given
    /// counter-stream seed.
    pub fn dropout(mut self, p: f32, seed: u32) -> Self {
        self.dropout_p = p;
        self.dropout_seed = seed;
        self
    }

    /// Restrict the valid key range: global key column 0 of this slice
    /// sits at `lo` ([`AttnConfig::kv_offset`]) and padding ends at the
    /// global key count `hi` ([`AttnConfig::kv_len`]).
    pub fn kv_window(mut self, lo: usize, hi: usize) -> Self {
        self.kv_offset = lo;
        self.kv_len = Some(hi);
        self
    }

    /// Set the padding limit alone (global key count; `kv_offset` 0).
    pub fn kv_len(mut self, n: usize) -> Self {
        self.kv_len = Some(n);
        self
    }

    /// Override the softmax scale (default: 1/sqrt(d)).
    pub fn tau(mut self, t: f32) -> Self {
        self.tau = Some(t);
        self
    }

    pub fn tau_for(&self, d: usize) -> f32 {
        self.tau.unwrap_or(1.0 / (d as f32).sqrt())
    }

    /// Global end (exclusive) of the valid key range visible to a slice
    /// holding `n_k` local keys: the padding limit clamped to the
    /// slice's global span `[kv_offset, kv_offset + n_k)`. Kernels
    /// compare global columns against this, so a key shard and the
    /// unsharded kernel make identical mask decisions. With
    /// `kv_offset = 0` this is the old local clamp `min(kv_len, n_k)`.
    pub fn kv_limit(&self, n_k: usize) -> usize {
        let end = self.kv_offset + n_k;
        self.kv_len.unwrap_or(end).min(end)
    }

    /// Config for a key shard whose local column 0 sits `lo` columns
    /// into this config's key range: same global decisions (causal,
    /// padding, dropout stream), local storage. `kv_len` stays global —
    /// the per-shard remap that used to live in the sharded driver is
    /// exactly the coordinate bug this replaces.
    pub fn for_shard(&self, lo: usize) -> AttnConfig {
        AttnConfig { kv_offset: self.kv_offset + lo, ..self.clone() }
    }
}

/// Row-wise softmax statistics saved by a forward pass, in either of the
/// two equivalent representations:
///
/// * `Pair` — the paper's (l, m) pair (Algorithm 1/2): row max `m_i` and
///   the sum of exponentials `l_i` relative to it.
/// * `Lse` — the single logsumexp `L_i = m_i + ln(l_i)` (Rabe & Staats
///   2021; FlashAttention-2), which is all the backward pass needs:
///   `P_ij = exp(s_ij - L_i)`.
///
/// [`flash::flash_backward`] consumes either, so outputs from the faithful
/// kernel and the fast kernel are interchangeable.
#[derive(Clone, Copy, Debug)]
pub enum AttnStats<'a> {
    Pair { l: &'a [f32], m: &'a [f32] },
    Lse(&'a [f32]),
}

impl AttnStats<'_> {
    pub fn len(&self) -> usize {
        match self {
            AttnStats::Pair { l, .. } => l.len(),
            AttnStats::Lse(lse) => lse.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logsumexp of row `r` under either representation. A zero-mass row
    /// (`l = 0`, the all-masked convention of `merge_partials` and the
    /// sharded path) maps to `-inf`, matching the fast kernel's encoding,
    /// so the backward passes' zero-gradient guard fires for Pair stats
    /// too instead of seeing the finite `ln(1e-37)` clamp.
    #[inline]
    pub fn lse(&self, r: usize) -> f32 {
        match self {
            AttnStats::Pair { l, m } => {
                if l[r] == 0.0 {
                    f32::NEG_INFINITY
                } else {
                    m[r] + l[r].max(1e-37).ln()
                }
            }
            AttnStats::Lse(lse) => lse[r],
        }
    }

    /// Materialise the logsumexp vector (diagnostics / serialisation).
    pub fn to_lse_vec(&self) -> Vec<f32> {
        (0..self.len()).map(|r| self.lse(r)).collect()
    }
}

/// Forward outputs: O plus the softmax statistics the paper saves (l, m).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub o: Tensor,
    pub l: Vec<f32>,
    pub m: Vec<f32>,
}

impl AttnOutput {
    /// Borrow the saved statistics in (l, m) form for the backward pass.
    pub fn stats(&self) -> AttnStats<'_> {
        AttnStats::Pair { l: &self.l, m: &self.m }
    }
}

/// Gradients returned by the backward passes.
#[derive(Clone, Debug)]
pub struct AttnGrads {
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
}

/// Which gradient kernel an `AttnGrads` producer routes through — the
/// backward half of the two-kernel policy (module docs above).
#[derive(Clone, Copy, Debug)]
pub enum BackwardKernel<'a> {
    /// Algorithm 3: the materialise-everything baseline (square shapes;
    /// ignores the saved statistics and recomputes P densely).
    Standard,
    /// Algorithm 4: the faithful instrumented K/V-outer reference — the
    /// IO-theorem oracle.
    Flash,
    /// The fast two-phase production kernel (Q-outer dQ + column-parallel
    /// dK/dV) on the given execution handle.
    Flash2 { exec: &'a Exec },
    /// The fast block-sparse two-phase kernel
    /// (`attn::block_sparse::block_sparse2_backward`): the Flash2 sweeps
    /// with `mask`'s zero blocks skipped in both phases. Mask columns
    /// are global key tiles (see the `block_sparse` module docs), so the
    /// same role works on key shards.
    BlockSparse2 { exec: &'a Exec, mask: &'a masks::BlockMask },
}

/// Shared per-slice entry point for every backward pass. Call sites
/// select a [`BackwardKernel`] role here instead of naming kernel
/// functions, so swapping the production gradient kernel is a one-line
/// policy change. Hot paths with a [batch, heads, n, d] workload go
/// through [`attention_backward_batched`] instead; this per-slice form is
/// for tests, reference comparisons and single-slice callers.
pub fn attention_backward(
    kernel: BackwardKernel<'_>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: AttnStats<'_>,
    cfg: &AttnConfig,
    blocks: flash::Blocks,
    hbm: &mut crate::sim::hbm::Hbm,
) -> AttnGrads {
    match kernel {
        BackwardKernel::Standard => standard::standard_backward(q, k, v, dout, cfg, hbm),
        BackwardKernel::Flash => {
            flash::flash_backward(q, k, v, o, dout, stats, cfg, blocks, hbm)
        }
        BackwardKernel::Flash2 { exec } => {
            flash2::flash2_backward(q, k, v, o, dout, stats, cfg, blocks, exec, hbm)
        }
        BackwardKernel::BlockSparse2 { exec, mask } => block_sparse::block_sparse2_backward(
            q, k, v, o, dout, stats, mask, cfg, blocks, exec, hbm,
        ),
    }
}

/// Batched counterpart of [`attention_backward`]: gradients for a whole
/// [batch, heads, n, d] workload through one entry point, so every
/// gradient producer gets batching for free. The fast production kernel
/// schedules all batch·head·block work items into a single worker pool
/// ([`batched::flash2_backward_batched`]); the reference kernels fall
/// back to a per-slice loop with identical slice semantics (slice `s`
/// runs with `bh_index = cfg.bh_index + s`, the same dropout streams as
/// the batched path) — callers swap policy roles without touching layout
/// code.
pub fn attention_backward_batched(
    kernel: BackwardKernel<'_>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &batched::BatchedAttnStats,
    cfg: &AttnConfig,
    blocks: flash::Blocks,
    hbm: &mut crate::sim::hbm::Hbm,
) -> AttnGrads {
    if let BackwardKernel::Flash2 { exec } = kernel {
        return batched::flash2_backward_batched(q, k, v, o, dout, stats, cfg, blocks, exec, hbm)
            .unwrap_or_else(|e| panic!("attention_backward_batched: retries exhausted: {e}"))
            .0;
    }
    if let BackwardKernel::BlockSparse2 { exec, mask } = kernel {
        return batched::block_sparse2_backward_batched(
            q,
            k,
            v,
            o,
            dout,
            stats,
            std::slice::from_ref(mask),
            cfg,
            blocks,
            exec,
            hbm,
        )
        .unwrap_or_else(|e| panic!("attention_backward_batched: retries exhausted: {e}"))
        .0;
    }
    assert_eq!(q.rank(), 4, "attention_backward_batched: Q must be [batch, heads, n, d]");
    let (b, h, n, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
    let n_k = k.shape[2];
    let mut dq = Tensor::zeros(&[b, h, n, d]);
    let mut dk = Tensor::zeros(&[b, h, n_k, d]);
    let mut dv = Tensor::zeros(&[b, h, n_k, d]);
    for s in 0..b * h {
        let cfg_s = AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() };
        let g = attention_backward(
            kernel,
            &batched::bh_slice(q, s),
            &batched::bh_slice(k, s),
            &batched::bh_slice(v, s),
            &batched::bh_slice(o, s),
            &batched::bh_slice(dout, s),
            stats.slice(s),
            &cfg_s,
            blocks,
            hbm,
        );
        dq.data[s * n * d..(s + 1) * n * d].copy_from_slice(&g.dq.data);
        dk.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dk.data);
        dv.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dv.data);
    }
    AttnGrads { dq, dk, dv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hbm::Hbm;
    use crate::util::rng::SplitMix64;

    #[test]
    fn entry_point_kernels_agree() {
        // All four BackwardKernel roles produce the same gradients for
        // the same workload (the dispatch itself is what's under test —
        // numeric parity is property-tested per kernel; BlockSparse2
        // runs with a dense mask, where it must match the dense pair).
        let mut rng = SplitMix64::new(21);
        let n = 24usize;
        let d = 8usize;
        let q = Tensor::randn(&[n, d], &mut rng, 1.0);
        let k = Tensor::randn(&[n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let dout = Tensor::randn(&[n, d], &mut rng, 1.0);
        let cfg = AttnConfig::new().causal();
        let blocks = flash::Blocks::explicit(8, 8);
        let dense = masks::BlockMask::dense(3, 3);
        let ex = Exec::new(3);
        let fwd =
            flash2::flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(2), &mut Hbm::new());
        let grads: Vec<AttnGrads> = [
            BackwardKernel::Standard,
            BackwardKernel::Flash,
            BackwardKernel::Flash2 { exec: &ex },
            BackwardKernel::BlockSparse2 { exec: &ex, mask: &dense },
        ]
        .into_iter()
        .map(|kernel| {
            attention_backward(
                kernel, &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new(),
            )
        })
        .collect();
        for g in &grads[1..] {
            assert!(grads[0].dq.max_abs_diff(&g.dq) < 1e-4);
            assert!(grads[0].dk.max_abs_diff(&g.dk) < 1e-4);
            assert!(grads[0].dv.max_abs_diff(&g.dv) < 1e-4);
        }
        // Dense-mask BlockSparse2 == Flash2 exactly (bitwise).
        assert_eq!(grads[3].dq.data, grads[2].dq.data);
        assert_eq!(grads[3].dk.data, grads[2].dk.data);
        assert_eq!(grads[3].dv.data, grads[2].dv.data);
    }

    #[test]
    fn config_builder_matches_struct_literal_forms() {
        let cfg = AttnConfig::new().causal().dropout(0.2, 7).kv_window(8, 33).tau(0.25);
        assert!(cfg.causal);
        assert_eq!(cfg.dropout_p, 0.2);
        assert_eq!(cfg.dropout_seed, 7);
        assert_eq!(cfg.kv_offset, 8);
        assert_eq!(cfg.kv_len, Some(33));
        assert_eq!(cfg.tau, Some(0.25));
        assert_eq!(AttnConfig::new().kv_len(5).kv_len, Some(5));
        // The chain composes with the shard remap exactly like literals.
        assert_eq!(cfg.for_shard(4).kv_offset, 12);
    }

    #[test]
    fn kv_limit_is_global_and_backwards_compatible() {
        // kv_offset = 0: the old local clamp min(kv_len, n_k).
        let cfg = AttnConfig { kv_len: Some(10), ..Default::default() };
        assert_eq!(cfg.kv_limit(16), 10);
        assert_eq!(cfg.kv_limit(6), 6);
        assert_eq!(AttnConfig::default().kv_limit(8), 8);
        // A shard at offset 12 holding 8 keys spans global [12, 20).
        let sh = cfg.for_shard(12);
        assert_eq!(sh.kv_offset, 12);
        assert_eq!(sh.kv_limit(8), 10); // padding ends before the shard
        assert_eq!(AttnConfig::default().for_shard(12).kv_limit(8), 20);
        // Nested sharding composes offsets.
        assert_eq!(sh.for_shard(4).kv_offset, 16);
    }

    #[test]
    fn stats_zero_mass_pair_maps_to_neg_inf() {
        // The all-masked convention: (l, m) = (0, -inf) must read as
        // lse = -inf (so backward passes skip the row), not ln(1e-37).
        let l = vec![0.0f32, 1.0];
        let m = vec![f32::NEG_INFINITY, 0.5];
        let pair = AttnStats::Pair { l: &l, m: &m };
        assert_eq!(pair.lse(0), f32::NEG_INFINITY);
        assert!((pair.lse(1) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn stats_pair_and_lse_agree() {
        let l = vec![2.0f32, 0.5, 1.0];
        let m = vec![0.0f32, 1.5, -2.0];
        let pair = AttnStats::Pair { l: &l, m: &m };
        let lse_vec = pair.to_lse_vec();
        let lse = AttnStats::Lse(&lse_vec);
        assert_eq!(pair.len(), 3);
        assert!(!pair.is_empty());
        for r in 0..3 {
            let expect = m[r] + l[r].ln();
            assert!((pair.lse(r) - expect).abs() < 1e-6);
            assert!((lse.lse(r) - expect).abs() < 1e-6);
        }
    }
}
