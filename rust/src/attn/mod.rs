//! Pure-Rust mirrors of the paper's algorithms, in two roles:
//!
//! 1. **Numeric**: independent implementations of Algorithm 0 (standard),
//!    Algorithms 1/2/4 (FlashAttention fwd/bwd) and Algorithm 5
//!    (block-sparse) used to cross-check the PJRT artifacts and each other
//!    (three-way agreement: Rust mirror == Pallas kernel == jnp oracle).
//! 2. **Instrumented**: every function takes a `sim::hbm::Hbm` counter and
//!    records loads/stores at exactly the points the paper's pseudo-code
//!    touches HBM, turning the IO-complexity theorems into measurements.
//!
//! All functions operate on one batch*head slice `[n, d]`; callers fold the
//! leading dims.

pub mod block_sparse;
pub mod distributed;
pub mod flash;
pub mod masks;
pub mod standard;

use crate::tensor::Tensor;

/// Shared configuration for the attention mirrors.
#[derive(Clone, Debug)]
pub struct AttnConfig {
    /// Softmax scaling tau; None => 1/sqrt(d).
    pub tau: Option<f32>,
    pub causal: bool,
    /// Valid key length (padding mask); None => n.
    pub kv_len: Option<usize>,
    pub dropout_p: f32,
    pub dropout_seed: u32,
    /// batch*head index — seeds the dropout counter stream.
    pub bh_index: u32,
}

impl Default for AttnConfig {
    fn default() -> Self {
        AttnConfig {
            tau: None,
            causal: false,
            kv_len: None,
            dropout_p: 0.0,
            dropout_seed: 0,
            bh_index: 0,
        }
    }
}

impl AttnConfig {
    pub fn causal() -> Self {
        AttnConfig { causal: true, ..Default::default() }
    }

    pub fn tau_for(&self, d: usize) -> f32 {
        self.tau.unwrap_or(1.0 / (d as f32).sqrt())
    }
}

/// Forward outputs: O plus the softmax statistics the paper saves (l, m).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub o: Tensor,
    pub l: Vec<f32>,
    pub m: Vec<f32>,
}

/// Gradients returned by the backward passes.
#[derive(Clone, Debug)]
pub struct AttnGrads {
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
}
