//! Multi-device FlashAttention (paper §5 "Multi-GPU IO-Aware Methods" and
//! Appendix D.1), implemented as a real parallel algorithm:
//!
//! The K/V sequence is sharded across W workers; each worker runs the
//! ordinary single-device kernel (Algorithm 1) over its shard, producing a
//! *partial* (O_w, l_w, m_w). Partials combine with exactly the softmax
//! decomposition of Section 3.1:
//!
//! ```text
//! m = max(m_a, m_b)
//! l = e^{m_a - m} l_a + e^{m_b - m} l_b
//! O = ( e^{m_a - m} l_a O_a + e^{m_b - m} l_b O_b ) / l
//! ```
//!
//! which is associative — workers can reduce in any tree order. The merge
//! moves only O(N·d) per worker across the interconnect (no N² traffic),
//! giving the extra hierarchy level the paper sketches: HBM↔SRAM within a
//! device, HBM↔HBM (NVLink) between devices.
//!
//! `flash_forward_sharded` runs the shards on OS threads (std::thread::scope)
//! as the laptop-scale stand-in for the GPUs; `multi_gpu_cost` extends the
//! IO model with the interconnect term.
//!
//! Per the two-kernel policy (attn module docs) each shard runs the *fast*
//! Q-outer kernel over its key range — and per the batched-entry-point
//! policy the shards are not spawned one thread each: they are handed to
//! the batched scheduler (`attn::batched::flash2_forward_many`), which
//! flattens every shard × row-block work item into a single worker pool.
//! Skewed shards (the dead-shard skip below, ragged tails) therefore never
//! strand threads, and per-shard outputs stay bitwise identical to a
//! per-shard kernel call. The fast kernel returns a logsumexp statistic;
//! `(l, m) = (1, L)` is an exact decomposition (l·eᵐ = e^L), so the
//! softmax merge below is unchanged.

use super::batched::{flash2_forward_many, AttnSlice};
use super::flash::Blocks;
use super::{AttnConfig, AttnOutput};
use crate::sim::hbm::Hbm;
use crate::tensor::Tensor;

/// Merge two attention partials over disjoint key sets (associative).
///
/// Fully-masked rows arrive as `m = -inf` (the fast kernel's zero-mass
/// convention, `Flash2Output::into_attn_output`): when only one side is
/// masked its weight `e^{-inf - m} · l` is exactly 0 and the live side
/// wins; when *both* sides are masked, `m_a - m_new = -inf - -inf` would
/// be NaN, so that case is handled explicitly — the merged row keeps the
/// defined all-masked semantics (zero output, zero mass, `m = -inf`),
/// which composes associatively with any later live partial.
pub fn merge_partials(a: &AttnOutput, b: &AttnOutput) -> AttnOutput {
    let n = a.l.len();
    let d = a.o.cols();
    assert_eq!(b.l.len(), n);
    let mut o = Tensor::zeros(&[n, d]);
    let mut l = vec![0.0f32; n];
    let mut m = vec![0.0f32; n];
    for r in 0..n {
        let m_new = a.m[r].max(b.m[r]);
        if m_new == f32::NEG_INFINITY {
            // Both partials fully masked: no probability mass anywhere.
            l[r] = 0.0;
            m[r] = f32::NEG_INFINITY;
            continue; // output row stays zero
        }
        let wa = (a.m[r] - m_new).exp() * a.l[r];
        let wb = (b.m[r] - m_new).exp() * b.l[r];
        let l_new = wa + wb;
        let inv = 1.0 / l_new.max(1e-37);
        let (ra, rb) = (a.o.row(r), b.o.row(r));
        let ro = o.row_mut(r);
        for c in 0..d {
            ro[c] = (wa * ra[c] + wb * rb[c]) * inv;
        }
        l[r] = l_new;
        m[r] = m_new;
    }
    AttnOutput { o, l, m }
}

/// Sequence-parallel flash forward: shard K/V rows over `workers` threads,
/// each running Algorithm 1 on its shard, then tree-merge the partials.
/// Exact for non-causal attention (each shard sees a contiguous key range;
/// causal masking needs per-shard column offsets, handled via kv offsets).
pub fn flash_forward_sharded(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
) -> AttnOutput {
    assert!(cfg.dropout_p == 0.0, "sharded path: dropout handled per-device in future work");
    assert!(!cfg.causal, "sharded path is non-causal (shards are key ranges)");
    let n = k.rows();
    let kv_len = cfg.kv_len.unwrap_or(n).min(n);
    if kv_len == 0 {
        // Every key masked (or none exist): the defined all-masked result —
        // zero output, zero mass, m = -inf — without spawning any worker.
        let nq = q.rows();
        return AttnOutput {
            o: Tensor::zeros(&[nq, q.cols()]),
            l: vec![0.0; nq],
            m: vec![f32::NEG_INFINITY; nq],
        };
    }
    let w = workers.max(1).min(n);
    let shard = n.div_ceil(w);
    let d = k.cols();

    // One descriptor per live shard; empty shards and *dead* shards — key
    // ranges entirely beyond the valid prefix, whose remapped kv_len would
    // be 0 — never become work items. (They used to spawn workers whose
    // fully-masked partials only merged away via the 1/l clamp.)
    let mut shards: Vec<AttnSlice<'_>> = Vec::new();
    for wi in 0..w {
        let lo = wi * shard;
        let hi = ((wi + 1) * shard).min(n);
        if lo >= hi || lo >= kv_len {
            continue;
        }
        shards.push(AttnSlice {
            q: &q.data[..],
            k: &k.data[lo * d..hi * d],
            v: &v.data[lo * d..hi * d],
            n: q.rows(),
            n_k: hi - lo,
            d,
            cfg: AttnConfig {
                // Padding mask applies to *global* columns; shards beyond
                // kv_len contribute nothing via their local mask.
                kv_len: cfg.kv_len.map(|kl| kl.saturating_sub(lo).min(hi - lo)),
                ..cfg.clone()
            },
        });
    }
    // All shard × row-block work items drain through one pool of `workers`
    // threads. Each simulated device counts its own HBM traffic in the
    // model (`multi_gpu_cost`); the merged counter here is discarded, as
    // the per-worker counters were before.
    let partials = flash2_forward_many(&shards, blocks, workers, &mut Hbm::new());

    // Tree reduction in shard order (any order is exact — associativity
    // test below).
    let mut acc: Option<AttnOutput> = None;
    for p in partials {
        let p = p.into_attn_output();
        acc = Some(match acc {
            None => p,
            Some(a) => merge_partials(&a, &p),
        });
    }
    acc.expect("at least one live shard")
}

/// IO model for W-way sequence-parallel flash (Appendix D.1): per-device
/// HBM traffic for an N/W key shard plus the O(N·d·W) interconnect merge.
#[derive(Clone, Copy, Debug)]
pub struct MultiGpuCost {
    /// Per-device HBM elements (the slowest device bounds the step).
    pub hbm_per_device: u64,
    /// Elements crossing the interconnect for the merge.
    pub interconnect_elems: u64,
}

pub fn multi_gpu_cost(n: u64, d: u64, blocks: Blocks, workers: u64) -> MultiGpuCost {
    let shard = n.div_ceil(workers);
    // Each device: full Q (all rows attend its shard) vs shard of K/V,
    // running the fast Q-outer kernel (matching flash_forward_sharded).
    let per_dev = crate::sim::cost::flash2_fwd_rect(n, shard, d, blocks);
    // Merge: each device ships (O, l, m) = N(d+2) elements.
    MultiGpuCost {
        hbm_per_device: per_dev.hbm_elems,
        interconnect_elems: workers * n * (d + 2),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash::flash_forward;
    use crate::attn::standard::standard_forward;
    use crate::util::prop::{for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn sharded_matches_single_device() {
        let (q, k, v) = qkv(64, 16, 0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::explicit(16, 16);
        let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        for workers in [1usize, 2, 3, 4, 8] {
            let multi = flash_forward_sharded(&q, &k, &v, &cfg, blocks, workers);
            assert!(
                single.o.max_abs_diff(&multi.o) < 1e-4,
                "workers={workers}: diff {}",
                single.o.max_abs_diff(&multi.o)
            );
        }
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let (q, k, v) = qkv(32, 8, 1);
        let cfg = AttnConfig::default();
        let blocks = Blocks::explicit(8, 8);
        // Three disjoint key shards.
        let parts: Vec<AttnOutput> = [(0, 12), (12, 20), (20, 32)]
            .iter()
            .map(|&(lo, hi)| {
                let (ks, vs) = (k.slice_rows(lo, hi), v.slice_rows(lo, hi));
                flash_forward(&q, &ks, &vs, &cfg, blocks, &mut Hbm::new())
            })
            .collect();
        let abc = merge_partials(&merge_partials(&parts[0], &parts[1]), &parts[2]);
        let a_bc = merge_partials(&parts[0], &merge_partials(&parts[1], &parts[2]));
        let cba = merge_partials(&merge_partials(&parts[2], &parts[1]), &parts[0]);
        assert!(abc.o.max_abs_diff(&a_bc.o) < 1e-5);
        assert!(abc.o.max_abs_diff(&cba.o) < 1e-5);
    }

    #[test]
    fn sharded_with_padding_mask() {
        let (q, k, v) = qkv(48, 8, 2);
        let cfg = AttnConfig { kv_len: Some(29), ..Default::default() };
        let blocks = Blocks::explicit(8, 8);
        let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        let multi = flash_forward_sharded(&q, &k, &v, &cfg, blocks, 3);
        assert!(single.o.max_abs_diff(&multi.o) < 1e-4);
    }

    #[test]
    fn dead_shards_skipped_kv_len_within_one_shard() {
        // Regression: kv_len ≤ one shard width means every shard but the
        // first is entirely beyond the valid key prefix. Those shards must
        // be skipped up front, and the result must match the dense oracle
        // with no NaN/Inf anywhere.
        let (q, k, v) = qkv(48, 8, 7);
        let blocks = Blocks::explicit(8, 8);
        for kv_len in [5usize, 8, 1] {
            let cfg = AttnConfig { kv_len: Some(kv_len), ..Default::default() };
            let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            for workers in [6usize, 8, 48] {
                let multi = flash_forward_sharded(&q, &k, &v, &cfg, blocks, workers);
                assert!(
                    multi.o.data.iter().all(|x| x.is_finite()),
                    "kv_len={kv_len} workers={workers}: non-finite output"
                );
                assert!(
                    single.o.max_abs_diff(&multi.o) < 1e-4,
                    "kv_len={kv_len} workers={workers}: diff {}",
                    single.o.max_abs_diff(&multi.o)
                );
            }
        }
    }

    #[test]
    fn kv_len_zero_gives_zero_output_no_nan() {
        let (q, k, v) = qkv(16, 4, 9);
        let cfg = AttnConfig { kv_len: Some(0), ..Default::default() };
        let out = flash_forward_sharded(&q, &k, &v, &cfg, Blocks::explicit(4, 4), 3);
        assert!(out.o.data.iter().all(|&x| x == 0.0));
        assert!(out.l.iter().all(|&x| x == 0.0));
        assert!(out.m.iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn property_merge_handles_all_masked_partials() {
        // The -inf/-inf case: merging two fully-masked partials must stay
        // NaN-free and keep zero-mass semantics; merging masked with live
        // must reproduce the live partial exactly; and the all-masked
        // identity must be associative with live merges.
        use crate::attn::flash2::flash2_forward;
        for_each_case("merge_masked", 8, |rng| {
            let n = usize_in(rng, 2, 24);
            let d = *crate::util::prop::choose(rng, &[2usize, 4, 8]);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let blocks = Blocks::explicit(4, 4);
            let dead_cfg = AttnConfig { kv_len: Some(0), ..Default::default() };
            let dead = flash2_forward(&q, &k, &v, &dead_cfg, blocks, 1, &mut Hbm::new())
                .into_attn_output();
            let live =
                flash2_forward(&q, &k, &v, &AttnConfig::default(), blocks, 1, &mut Hbm::new())
                    .into_attn_output();

            let both_dead = merge_partials(&dead, &dead);
            assert!(both_dead.o.data.iter().all(|&x| x == 0.0), "n={n} d={d}: dead+dead O");
            assert!(both_dead.l.iter().all(|&x| x == 0.0));
            assert!(both_dead.m.iter().all(|&x| x == f32::NEG_INFINITY));

            for merged in [
                merge_partials(&dead, &live),
                merge_partials(&live, &dead),
                merge_partials(&both_dead, &live),
            ] {
                assert!(merged.o.data.iter().all(|x| x.is_finite()), "n={n} d={d}");
                assert!(live.o.max_abs_diff(&merged.o) < 1e-5, "n={n} d={d}");
            }
        });
    }

    #[test]
    fn property_random_worker_counts() {
        for_each_case("sharded", 8, |rng| {
            let n = usize_in(rng, 8, 48);
            let d = *crate::util::prop::choose(rng, &[4usize, 8]);
            let w = usize_in(rng, 1, 6);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let cfg = AttnConfig::default();
            let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            let multi = flash_forward_sharded(&q, &k, &v, &cfg, Blocks::explicit(8, 8), w);
            assert!(single.o.max_abs_diff(&multi.o) < 1e-4, "n={n} d={d} w={w}");
        });
    }

    #[test]
    fn interconnect_traffic_linear_not_quadratic() {
        let blocks = Blocks::explicit(64, 256);
        let c2 = multi_gpu_cost(8192, 64, blocks, 4);
        let c1 = multi_gpu_cost(4096, 64, blocks, 4);
        let ratio = c2.interconnect_elems as f64 / c1.interconnect_elems as f64;
        assert!((1.9..2.1).contains(&ratio), "merge traffic must be O(N): {ratio}");
        // Per-device HBM shrinks as workers grow.
        let w8 = multi_gpu_cost(8192, 64, blocks, 8).hbm_per_device;
        let w2 = multi_gpu_cost(8192, 64, blocks, 2).hbm_per_device;
        assert!(w8 < w2);
    }
}
