//! Multi-device FlashAttention (paper §5 "Multi-GPU IO-Aware Methods" and
//! Appendix D.1, with FlashAttention-2's sequence-parallel work
//! partitioning): the key sequence is sharded into contiguous,
//! tile-aligned ranges, and **every shard kernel runs in global key
//! coordinates** ([`AttnConfig::kv_offset`]) — the causal mask, the key
//! padding and the counter-based dropout stream all see
//! `kv_offset + local_col`, so a shard makes exactly the decisions the
//! unsharded kernel makes for the same attention entries. That
//! coordinate plumbing is what lets this path run causal + dropout
//! configurations (the two asserts that used to reject them are gone).
//!
//! Two schedules over the same shards:
//!
//! * **Ring schedule** ([`flash_forward_sharded`] /
//!   [`flash_backward_sharded`]) — the production path. Each Q row
//!   block's on-chip softmax (or dQ) state stays resident on the device
//!   owning those rows while the K/V shards visit in global order; the
//!   per-row arithmetic is therefore the *single-device kernel's op
//!   sequence*, restarted at shard boundaries, and the output is
//!   **bitwise identical** to `attn::flash2` for any shard count and
//!   any worker count (asserted over the causal × dropout × kv_len
//!   grid below). dK/dV needs no state threading at all: a shard owns
//!   its key rows, so its column blocks dispatch independently.
//! * **Tree schedule** ([`shard_partials`] + [`merge_partials`]) — the
//!   paper's §5 softmax decomposition. Every live shard computes a full
//!   partial (O_w, l_w, m_w) through the batched scheduler
//!   (`attn::batched::flash2_forward_many`), and partials combine with
//!   the Section 3.1 identity:
//!
//!   ```text
//!   m = max(m_a, m_b)
//!   l = e^{m_a - m} l_a + e^{m_b - m} l_b
//!   O = ( e^{m_a - m} l_a O_a + e^{m_b - m} l_b O_b ) / l
//!   ```
//!
//!   which is associative — partials can reduce in any tree order,
//!   moving only O(N·d) per device across the interconnect. The merge
//!   renormalises, so this schedule is exact to fp rounding (not
//!   bitwise); use it when the interconnect favours an all-reduce over
//!   a ring.
//!
//! **Dead shards never become work items.** A shard wholly beyond the
//! valid key prefix (`lo ≥ kv_len`) or wholly above the causal diagonal
//! for every query row (`lo ≥ n_q`) contributes nothing; both schedules
//! drop it up front, and `multi_gpu_cost` models the saved traffic (the
//! causal-skip term: per-device HBM counts only tiles at or below the
//! diagonal in global coordinates, and dead shards ship no partial).
//!
//! Pool workers (the [`Exec`](super::exec::Exec) execution plane) are
//! the laptop-scale stand-in for the devices; every entry point takes
//! the `&Exec` handle, whose fault plan and guardrail govern the run.

use std::sync::Arc;

use super::batched::{block_rows, forward_many_sited, AttnSlice, DqItem, FwdItem};
use super::exec::Exec;
use super::block_sparse::{block_sparse2_forward, check_mask_geometry, mask_tile_base};
use super::faults::{AttnError, FaultPlan, FaultReport, FaultSite, PoolItem};
use super::flash::Blocks;
use super::flash2::{dkv_col_sweep, stream_kv, stream_kv_dq, write_epilogue, RowBlockState};
use super::masks::BlockMask;
use super::{AttnConfig, AttnGrads, AttnOutput, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::{dot4, Tensor};

/// One key shard: global key rows [lo, hi). Shard boundaries are
/// aligned to whole column tiles (`Blocks::b_c`), so a shard's tiles
/// are exactly the single-device kernel's tiles for those columns —
/// the alignment that makes the ring schedule bitwise-exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub lo: usize,
    pub hi: usize,
}

/// Split `n_k` keys into at most `shards` contiguous tile-aligned
/// ranges (fewer when there are fewer column tiles than shards).
pub fn shard_ranges(n_k: usize, b_c: usize, shards: usize) -> Vec<Shard> {
    let t_c = n_k.div_ceil(b_c);
    if t_c == 0 {
        return Vec::new();
    }
    let s = shards.max(1).min(t_c);
    let per = t_c.div_ceil(s);
    let mut out = Vec::new();
    let mut b = 0usize;
    while b < t_c {
        let b_hi = (b + per).min(t_c);
        out.push(Shard { lo: b * b_c, hi: (b_hi * b_c).min(n_k) });
        b = b_hi;
    }
    out
}

/// True iff the shard can contribute to no query row: wholly beyond the
/// valid key prefix, or (causal) wholly above the diagonal for every
/// row. Generalises the old beyond-`kv_len` skip — such shards never
/// become work items on either schedule.
pub fn shard_is_dead(sh: Shard, n_q: usize, cfg: &AttnConfig) -> bool {
    shard_dead_reason(sh, n_q, cfg).is_some()
}

/// Why a shard is dead, for the checked entry points' classified
/// reporting (`FaultReport::dead_shards`) — `None` means live.
pub fn shard_dead_reason(sh: Shard, n_q: usize, cfg: &AttnConfig) -> Option<&'static str> {
    let glo = cfg.kv_offset + sh.lo;
    if cfg.kv_len.is_some_and(|kl| glo >= kl) {
        Some("wholly beyond the valid key prefix (kv_len)")
    } else if cfg.causal && glo >= n_q {
        Some("wholly above the causal diagonal")
    } else {
        None
    }
}

/// Split a shard layout into live shards and classified dead shards, or
/// a typed [`AttnError::ShardConfig`] naming a structurally malformed
/// shard (empty range, or a start not aligned to whole `b_c` column
/// tiles — misalignment would silently break the ring schedule's
/// bitwise-parity guarantee). Layouts from [`shard_ranges`] always pass
/// the structural check; this guards externally-constructed layouts.
pub fn classify_shards(
    ranges: &[Shard],
    n_q: usize,
    cfg: &AttnConfig,
    b_c: usize,
) -> Result<(Vec<Shard>, Vec<(usize, &'static str)>), AttnError> {
    let mut live = Vec::new();
    let mut dead = Vec::new();
    for (i, &sh) in ranges.iter().enumerate() {
        if sh.lo >= sh.hi {
            return Err(AttnError::ShardConfig {
                shard: i,
                lo: sh.lo,
                hi: sh.hi,
                reason: "empty key range".into(),
            });
        }
        if sh.lo % b_c != 0 {
            return Err(AttnError::ShardConfig {
                shard: i,
                lo: sh.lo,
                hi: sh.hi,
                reason: format!("start not aligned to the {b_c}-column tile grid"),
            });
        }
        match shard_dead_reason(sh, n_q, cfg) {
            Some(reason) => dead.push((i, reason)),
            None => live.push(sh),
        }
    }
    Ok((live, dead))
}

/// The defined all-masked result: zero output, zero mass, m = -inf.
fn all_masked_output(n_q: usize, d: usize) -> AttnOutput {
    AttnOutput {
        o: Tensor::zeros(&[n_q, d]),
        l: vec![0.0; n_q],
        m: vec![f32::NEG_INFINITY; n_q],
    }
}

/// Merge two attention partials over disjoint key sets (associative).
///
/// Fully-masked rows arrive as `m = -inf` (the fast kernel's zero-mass
/// convention, `Flash2Output::into_attn_output`): when only one side is
/// masked its weight `e^{-inf - m} · l` is exactly 0 and the live side
/// wins; when *both* sides are masked, `m_a - m_new = -inf - -inf` would
/// be NaN, so that case is handled explicitly — the merged row keeps the
/// defined all-masked semantics (zero output, zero mass, `m = -inf`),
/// which composes associatively with any later live partial.
///
/// The same zero-mass branch catches **underflowed** mass: when both
/// sides' weights `e^{m - m_new} · l` land below the smallest normal
/// f32 (denormal or zero `l` paired with a very negative max), the old
/// `1 / l.max(1e-37)` clamp scaled junk by ~1e37; now any total below
/// `f32::MIN_POSITIVE` routes through the explicit all-masked path,
/// which stays associative with live partials (their weights dominate
/// identically in either grouping).
pub fn merge_partials(a: &AttnOutput, b: &AttnOutput) -> AttnOutput {
    let n = a.l.len();
    let d = a.o.cols();
    assert_eq!(b.l.len(), n);
    let mut o = Tensor::zeros(&[n, d]);
    let mut l = vec![0.0f32; n];
    let mut m = vec![0.0f32; n];
    for r in 0..n {
        let m_new = a.m[r].max(b.m[r]);
        if m_new == f32::NEG_INFINITY {
            // Both partials fully masked: no probability mass anywhere.
            l[r] = 0.0;
            m[r] = f32::NEG_INFINITY;
            continue; // output row stays zero
        }
        let wa = (a.m[r] - m_new).exp() * a.l[r];
        let wb = (b.m[r] - m_new).exp() * b.l[r];
        let l_new = wa + wb;
        if l_new < f32::MIN_POSITIVE {
            // Zero or subnormal total mass: the defined zero-mass row.
            l[r] = 0.0;
            m[r] = f32::NEG_INFINITY;
            continue;
        }
        let inv = 1.0 / l_new;
        let (ra, rb) = (a.o.row(r), b.o.row(r));
        let ro = o.row_mut(r);
        for c in 0..d {
            ro[c] = (wa * ra[c] + wb * rb[c]) * inv;
        }
        l[r] = l_new;
        m[r] = m_new;
    }
    AttnOutput { o, l, m }
}

/// Sequence-parallel fast forward, ring schedule: K/V is sharded into
/// `shards` tile-aligned ranges; each Q row block's on-chip state stays
/// resident while the live shards stream through it in global order
/// (`exec`'s pool workers drain the row-block work items). Every shard
/// sweep runs with that shard's global `kv_offset`, so causal, padding
/// and dropout decisions match the single-device kernel
/// entry-for-entry — the output (O and logsumexp, returned in the
/// `(l, m) = (1, L)` decomposition) is **bitwise identical** to
/// [`super::flash2::flash2_forward`] for any shard count, worker count,
/// and pool mode. Fault containment, retry, the finiteness guardrail
/// and fault injection all come from `exec`; dead shards are classified
/// in the report. A failed row-block item is recomputed (re-streaming
/// every shard), so recovered output stays bitwise identical to the
/// fault-free run.
pub fn flash_forward_sharded(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Result<(AttnOutput, FaultReport), AttnError> {
    let (n_q, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    assert_eq!(k.cols(), d, "flash_forward_sharded: K feature dim mismatch");
    assert_eq!((v.rows(), v.cols()), (n_k, d), "flash_forward_sharded: V shape mismatch");
    let kv_limit = cfg.kv_limit(n_k);
    let ranges = shard_ranges(n_k, blocks.b_c, shards);
    let (live, dead) = classify_shards(&ranges, n_q, cfg, blocks.b_c)?;
    let mut report = FaultReport { dead_shards: dead, ..Default::default() };
    if live.is_empty() {
        // Every key masked (or none exist): the defined all-masked result
        // without spawning any worker — each dropped shard is classified
        // in the report instead of silently substituted.
        return Ok((all_masked_output(n_q, d), report));
    }
    let tau = cfg.tau_for(d);
    let b_r = blocks.b_r;
    let t_r = n_q.div_ceil(b_r);
    let mut o = Tensor::zeros(&[n_q, d]);
    let mut lse = vec![0.0f32; n_q];

    let items: Vec<FwdItem> = (0..t_r)
        .map(|rb| {
            let rows = block_rows(rb, b_r, n_q);
            FwdItem { s: 0, rb, o_win: vec![0.0; rows * d], lse_win: vec![0.0; rows] }
        })
        .collect();

    let (qd, kd, vd) = (q.data.clone(), k.data.clone(), v.data.clone());
    let (cfg_o, live_o) = (cfg.clone(), live.clone());
    // Each simulated device counts its own traffic in the analytic model
    // (`multi_gpu_cost`); the merged counter here is discarded — but the
    // report's retry traffic is kept, access-for-access.
    let (done, pool_report) =
        exec.run(items, FaultSite::RingFwd, &mut Hbm::new(), move |it: &mut FwdItem| {
            let mut hbm = Hbm::new();
            let r0 = it.rb * b_r;
            let r1 = ((it.rb + 1) * b_r).min(n_q);
            let br = r1 - r0;
            hbm.load(br * d); // Q_i loaded once, before the shards visit
            let mut state = RowBlockState::new(blocks, d); // fresh = already reset
            for sh in &live_o {
                // Shards wholly above this row block's diagonal would have
                // every tile skipped — don't visit them at all.
                if cfg_o.causal && cfg_o.kv_offset + sh.lo > r1 - 1 {
                    continue;
                }
                let cfg_s = cfg_o.for_shard(sh.lo);
                stream_kv(
                    &mut state,
                    &qd[r0 * d..r1 * d],
                    &kd[sh.lo * d..sh.hi * d],
                    &vd[sh.lo * d..sh.hi * d],
                    sh.hi - sh.lo,
                    n_q,
                    d,
                    r0,
                    r1,
                    &cfg_s,
                    blocks,
                    tau,
                    kv_limit,
                    &mut hbm,
                );
            }
            write_epilogue(&state, br, d, &mut it.o_win, &mut it.lse_win, &mut hbm);
            hbm
        })?;
    for it in done {
        let r0 = it.rb * b_r;
        o.data[r0 * d..r0 * d + it.o_win.len()].copy_from_slice(&it.o_win);
        lse[r0..r0 + it.lse_win.len()].copy_from_slice(&it.lse_win);
    }
    report.merge(&pool_report);

    // (l, m) = (1, L) is an exact decomposition (l·eᵐ = e^L); zero-mass
    // rows keep the explicit (0, -inf) convention.
    let l = lse.iter().map(|&x| if x == f32::NEG_INFINITY { 0.0 } else { 1.0 }).collect();
    Ok((AttnOutput { o, l, m: lse }, report))
}

/// Sequence-parallel fast backward, ring schedule — the gradient
/// counterpart of [`flash_forward_sharded`], bitwise identical to
/// [`super::flash2::flash2_backward`] for any shard count, worker
/// count, and pool mode of `exec`:
///
/// * **dQ** threads each row block's on-chip accumulator through the
///   live shards in global order (the accumulation order per element is
///   the global column order either way);
/// * **dK/dV** needs no threading: a shard owns its key rows, so every
///   (shard, column block) pair is an independent work item writing its
///   own dK/dV window, with the full Q/dO stream and global-coordinate
///   masking.
///
/// Fault containment comes from `exec`: dQ items re-stream every live
/// shard on retry from a zeroed accumulator window; dK/dV items re-run
/// their single (shard, column-block) sweep — both bitwise identical to
/// the fault-free computation.
#[allow(clippy::too_many_arguments)]
pub fn flash_backward_sharded(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: AttnStats<'_>,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    backward_sharded_core(q, k, v, o, dout, stats, cfg, blocks, shards, exec)
}

/// One (shard, column block) dK/dV work item in the ring backward pool.
/// `si` is the shard's index in the ring — the provenance coordinate a
/// guardrail failure reports.
struct RingDkvItem {
    si: usize,
    shard: Shard,
    cb: usize,
    dk_win: Vec<f32>,
    dv_win: Vec<f32>,
}

impl PoolItem for RingDkvItem {
    fn id(&self) -> (usize, usize) {
        (self.si, self.cb)
    }
    fn reset(&mut self) {
        self.dk_win.fill(0.0);
        self.dv_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        self.dk_win.iter().all(|x| x.is_finite()) && self.dv_win.iter().all(|x| x.is_finite())
    }
    fn poison(&mut self) {
        self.dk_win.fill(f32::NAN);
        self.dv_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        use crate::attn::audit::SlotClaim;
        vec![SlotClaim::of("dk", &self.dk_win), SlotClaim::of("dv", &self.dv_win)]
    }
}

#[allow(clippy::too_many_arguments)]
fn backward_sharded_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: AttnStats<'_>,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    assert_eq!(k.cols(), d, "flash_backward_sharded: K feature dim mismatch");
    assert_eq!((v.rows(), v.cols()), (n_k, d), "flash_backward_sharded: V shape mismatch");
    assert_eq!((o.rows(), o.cols()), (n, d), "flash_backward_sharded: O shape mismatch");
    assert_eq!((dout.rows(), dout.cols()), (n, d), "flash_backward_sharded: dO shape mismatch");
    assert_eq!(stats.len(), n, "flash_backward_sharded: stats length mismatch");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let mut dq = Tensor::zeros(&[n, d]);
    let mut dk = Tensor::zeros(&[n_k, d]);
    let mut dv = Tensor::zeros(&[n_k, d]);
    if t_r == 0 || n_k == 0 {
        return Ok((AttnGrads { dq, dk, dv }, FaultReport::default()));
    }
    // D and the logsumexp are global per-row quantities, computed once —
    // identical to the single-device kernel's phase 0.
    let d_vec: Vec<f32> = (0..n).map(|r| dot4(dout.row(r), o.row(r))).collect();
    let lse = stats.to_lse_vec();
    let ranges = shard_ranges(n_k, b_c, shards);
    let (live, dead) = classify_shards(&ranges, n, cfg, b_c)?;
    let mut report = FaultReport { dead_shards: dead, ..Default::default() };

    // One owned snapshot shared by both phases' work closures.
    struct Shared {
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        dout: Vec<f32>,
        lse: Vec<f32>,
        d_vec: Vec<f32>,
        cfg: AttnConfig,
        live: Vec<Shard>,
    }
    let data = Arc::new(Shared {
        q: q.data.clone(),
        k: k.data.clone(),
        v: v.data.clone(),
        dout: dout.data.clone(),
        lse,
        d_vec,
        cfg: cfg.clone(),
        live: live.clone(),
    });

    // Phase 1: dQ — one work item per Q row block, shards visiting in
    // global order with the accumulator resident.
    let dq_items: Vec<DqItem> = (0..t_r)
        .map(|rb| DqItem { s: 0, rb, dq_win: vec![0.0; block_rows(rb, b_r, n) * d] })
        .collect();
    let dq_data = Arc::clone(&data);
    let (dq_done, dq_report) =
        exec.run(dq_items, FaultSite::RingDq, &mut Hbm::new(), move |it: &mut DqItem| {
            let sh_data = &dq_data;
            let mut hbm = Hbm::new();
            let r0 = it.rb * b_r;
            let r1 = ((it.rb + 1) * b_r).min(n);
            let br = r1 - r0;
            hbm.load(2 * br * d + 2 * br); // Q_i, dO_i, D_i, L_i once
            let mut s_buf = vec![0.0f32; b_r * b_c];
            let mut dp_buf = vec![0.0f32; b_r * b_c];
            for sh in &sh_data.live {
                if sh_data.cfg.causal && sh_data.cfg.kv_offset + sh.lo > r1 - 1 {
                    continue;
                }
                let cfg_s = sh_data.cfg.for_shard(sh.lo);
                stream_kv_dq(
                    &mut it.dq_win,
                    &sh_data.q[r0 * d..r1 * d],
                    &sh_data.dout[r0 * d..r1 * d],
                    &sh_data.k[sh.lo * d..sh.hi * d],
                    &sh_data.v[sh.lo * d..sh.hi * d],
                    sh.hi - sh.lo,
                    n,
                    d,
                    r0,
                    r1,
                    &sh_data.lse,
                    &sh_data.d_vec,
                    &cfg_s,
                    blocks,
                    tau,
                    kv_limit,
                    &mut s_buf,
                    &mut dp_buf,
                    &mut hbm,
                );
            }
            hbm.store(br * d); // dQ_i leaves the device exactly once
            hbm
        })?;
    for it in dq_done {
        let r0 = it.rb * b_r;
        dq.data[r0 * d..r0 * d + it.dq_win.len()].copy_from_slice(&it.dq_win);
    }
    report.merge(&dq_report);

    // Phase 2: dK/dV — every (live shard, column block) pair is an
    // independent work item; dead shards keep their zero windows, which
    // is exactly what the single-device kernel computes for them.
    let mut dkv_items: Vec<RingDkvItem> = Vec::new();
    for (si, &sh) in ranges.iter().enumerate() {
        if shard_is_dead(sh, n, cfg) {
            continue;
        }
        let t_c_sh = (sh.hi - sh.lo).div_ceil(b_c);
        for cb in 0..t_c_sh {
            let c0 = sh.lo + cb * b_c;
            let c1 = (sh.lo + (cb + 1) * b_c).min(sh.hi);
            dkv_items.push(RingDkvItem {
                si,
                shard: sh,
                cb,
                dk_win: vec![0.0; (c1 - c0) * d],
                dv_win: vec![0.0; (c1 - c0) * d],
            });
        }
    }
    let (dkv_done, dkv_report) =
        exec.run(dkv_items, FaultSite::RingDkv, &mut Hbm::new(), move |it: &mut RingDkvItem| {
            let sh_data = &data;
            let sh = it.shard;
            let cfg_s = sh_data.cfg.for_shard(sh.lo);
            dkv_col_sweep(
                &sh_data.q,
                &sh_data.k[sh.lo * d..sh.hi * d],
                &sh_data.v[sh.lo * d..sh.hi * d],
                &sh_data.dout,
                &sh_data.lse,
                &sh_data.d_vec,
                n,
                sh.hi - sh.lo,
                d,
                &cfg_s,
                blocks,
                tau,
                kv_limit,
                it.cb,
                it.cb + 1,
                &mut it.dk_win,
                &mut it.dv_win,
            )
        })?;
    for it in dkv_done {
        let c0 = it.shard.lo + it.cb * b_c;
        dk.data[c0 * d..c0 * d + it.dk_win.len()].copy_from_slice(&it.dk_win);
        dv.data[c0 * d..c0 * d + it.dv_win.len()].copy_from_slice(&it.dv_win);
    }
    report.merge(&dkv_report);

    Ok((AttnGrads { dq, dk, dv }, report))
}

/// Tree schedule, step 1: one softmax partial per live shard, scheduled
/// through the batched many-slice entry point (all shard × row-block
/// work items in one pool on `exec`). Each slice carries
/// `kv_offset = shard.lo` and the caller's *global* `kv_len` — the
/// per-shard `kv_len` remap that used to live here was the
/// local-coordinate bug. Dead shards are classified in the report, not
/// silently dropped; the result may hold fewer than `shards` partials
/// (possibly zero when every key is masked). Fault containment comes
/// from `exec`: a failed (shard, row-block) work item is recomputed and
/// its partial re-enters the merge unchanged — the associativity of
/// [`merge_partials`] is the recovery primitive. A malformed shard
/// range is a typed [`AttnError::ShardConfig`].
pub fn shard_partials(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Result<(Vec<AttnOutput>, FaultReport), AttnError> {
    let n_k = k.rows();
    let d = k.cols();
    let ranges = shard_ranges(n_k, blocks.b_c, shards);
    let (live, dead) = classify_shards(&ranges, q.rows(), cfg, blocks.b_c)?;
    let mut report = FaultReport { dead_shards: dead, ..Default::default() };
    let slices: Vec<AttnSlice<'_>> = live
        .iter()
        .map(|sh| AttnSlice {
            q: &q.data[..],
            k: &k.data[sh.lo * d..sh.hi * d],
            v: &v.data[sh.lo * d..sh.hi * d],
            n: q.rows(),
            n_k: sh.hi - sh.lo,
            d,
            cfg: cfg.for_shard(sh.lo),
        })
        .collect();
    let (partials, pool_report) =
        forward_many_sited(&slices, blocks, exec, &mut Hbm::new(), FaultSite::TreePartial)?;
    report.merge(&pool_report);
    Ok((partials.into_iter().map(|p| p.into_attn_output()).collect(), report))
}

/// Tree schedule, step 2: reduce the shard partials with
/// [`merge_partials`] (here in shard order; any order is exact — the
/// associativity property tests below). Exact to fp rounding against
/// the single-device kernel; the ring schedule is the bitwise path.
/// The report says exactly which shards were dead and why; only when
/// every shard is classified dead does the defined all-masked result
/// come back. Failed partials are recomputed and re-merged.
pub fn flash_forward_sharded_tree(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Result<(AttnOutput, FaultReport), AttnError> {
    // Tree partials are always finiteness-validated before the merge,
    // regardless of the handle's flag: a NaN partial poisons every
    // downstream merge, so validation here is load-bearing, not optional.
    let (partials, report) =
        shard_partials(q, k, v, cfg, blocks, shards, &exec.clone().validated())?;
    let out = partials
        .into_iter()
        .reduce(|a, b| merge_partials(&a, &b))
        .unwrap_or_else(|| all_masked_output(q.rows(), q.cols()));
    Ok((out, report))
}

/// Tree schedule over a **block-sparse** workload: one softmax partial
/// per live shard, each running the fast sparse kernel
/// (`attn::block_sparse::block_sparse2_forward`) over its tile-aligned
/// key range with the SAME global mask — `kv_offset` shifts each
/// shard's mask window, no mask surgery. On top of the dense dead-shard
/// predicate, a shard whose mask window is **all-zero** is dead too:
/// the sparsity pattern itself can kill a shard, and such shards never
/// become work items (their saved traffic is the Proposition-4 term
/// the cost model tracks).
pub fn block_sparse_shard_partials(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Vec<AttnOutput> {
    let n_k = k.rows();
    let t_r = q.rows().div_ceil(blocks.b_r);
    // Validate the FULL global tile grid up front: the dead-window scan
    // below reads mask tiles for every shard, so an undersized mask must
    // hit the loud geometry assert here — not alias `BlockMask::get`'s
    // row-major indexing into the wrong row's bits (which could silently
    // classify a live shard as dead).
    check_mask_geometry(
        mask,
        t_r,
        mask_tile_base(cfg.kv_offset, blocks.b_c),
        n_k.div_ceil(blocks.b_c),
    );
    shard_ranges(n_k, blocks.b_c, shards)
        .into_iter()
        .filter(|&sh| !shard_is_dead(sh, q.rows(), cfg))
        .filter(|&sh| !sparse_window_is_dead(sh, mask, cfg, blocks, t_r))
        .map(|sh| {
            let ks = k.slice_rows(sh.lo, sh.hi);
            let vs = v.slice_rows(sh.lo, sh.hi);
            // Injection happens at shard granularity in the tree driver's
            // own retry loop — the per-item pool inside each shard runs
            // fault-free so one planned fault is never applied twice.
            block_sparse2_forward(
                q,
                &ks,
                &vs,
                mask,
                &cfg.for_shard(sh.lo),
                blocks,
                &exec.fault_free(),
                &mut Hbm::new(),
            )
            .into_attn_output()
        })
        .collect()
}

/// Sparse dead-shard test: is there any live mask block in the shard's
/// global tile window [tb, te)? A shard whose window is all zero never
/// becomes a work item.
fn sparse_window_is_dead(
    sh: Shard,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    t_r: usize,
) -> bool {
    let tb = (cfg.kv_offset + sh.lo) / blocks.b_c;
    let te = (cfg.kv_offset + sh.hi).div_ceil(blocks.b_c);
    !(0..t_r).any(|i| (tb..te).any(|t| mask.get(i, t)))
}

/// Reduce [`block_sparse_shard_partials`] with the §5 associative merge
/// — the sparse workload's sequence-parallel entry point. Exact to fp
/// rounding against the unsharded sparse kernel (property-tested
/// below); all-dead workloads return the defined all-masked result. The
/// report classifies every dead shard (masked by `kv_len`, above the
/// causal diagonal, or killed by an all-zero mask window); each live
/// partial is finiteness-validated with shard provenance before it may
/// enter the merge. The sparse kernel runs whole per shard, so `exec`'s
/// fault plan here only poisons partials at shard granularity (the
/// per-shard pool runs fault-free) — a poisoned partial is recomputed
/// before merging, bitwise identical.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse_forward_sharded_tree(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    shards: usize,
    exec: &Exec,
) -> Result<(AttnOutput, FaultReport), AttnError> {
    let plan = exec.plan();
    let n_k = k.rows();
    let t_r = q.rows().div_ceil(blocks.b_r);
    check_mask_geometry(
        mask,
        t_r,
        mask_tile_base(cfg.kv_offset, blocks.b_c),
        n_k.div_ceil(blocks.b_c),
    );
    let ranges = shard_ranges(n_k, blocks.b_c, shards);
    let (_, mut dead) = classify_shards(&ranges, q.rows(), cfg, blocks.b_c)?;
    let dense_dead: Vec<usize> = dead.iter().map(|&(i, _)| i).collect();
    let mut live: Vec<(usize, Shard)> = Vec::new();
    for (si, &sh) in ranges.iter().enumerate() {
        if dense_dead.contains(&si) {
            continue;
        }
        if sparse_window_is_dead(sh, mask, cfg, blocks, t_r) {
            dead.push((si, "mask window all zero within the shard's key range"));
        } else {
            live.push((si, sh));
        }
    }
    let mut report = FaultReport { dead_shards: dead, ..Default::default() };
    let mut partials: Vec<AttnOutput> = Vec::new();
    for &(si, sh) in &live {
        let ks = k.slice_rows(sh.lo, sh.hi);
        let vs = v.slice_rows(sh.lo, sh.hi);
        let cfg_s = cfg.for_shard(sh.lo);
        let mut attempt: u32 = 0;
        loop {
            let mut p = block_sparse2_forward(
                q,
                &ks,
                &vs,
                mask,
                &cfg_s,
                blocks,
                &exec.fault_free(),
                &mut Hbm::new(),
            )
            .into_attn_output();
            if plan.fault_for(FaultSite::TreePartial, si, attempt)
                == Some(super::faults::FaultKind::PoisonedPartial)
            {
                p.o.data.fill(f32::NAN);
                report.poisoned += 1;
            }
            let finite = p.o.data.iter().all(|x| x.is_finite())
                && p.l.iter().all(|x| x.is_finite())
                && p.m.iter().all(|&x| x.is_finite() || x == f32::NEG_INFINITY);
            if finite {
                partials.push(p);
                break;
            }
            attempt += 1;
            if attempt >= super::faults::MAX_ATTEMPTS {
                return Err(AttnError::NonFinite {
                    site: FaultSite::TreePartial,
                    slice: si,
                    batch: 0,
                    head: 0,
                    block: 0,
                    attempts: attempt,
                });
            }
            report.retries += 1;
        }
    }
    let out = partials
        .into_iter()
        .reduce(|a, b| merge_partials(&a, &b))
        .unwrap_or_else(|| all_masked_output(q.rows(), q.cols()));
    Ok((out, report))
}

/// IO model for W-way sequence-parallel flash (Appendix D.1): per-device
/// HBM traffic for a key shard plus the O(N·d·W) interconnect merge.
#[derive(Clone, Copy, Debug)]
pub struct MultiGpuCost {
    /// Per-device HBM elements (the slowest device bounds the step).
    pub hbm_per_device: u64,
    /// Elements crossing the interconnect for the merge.
    pub interconnect_elems: u64,
}

/// W-way cost with the causal-skip and dead-shard traffic terms: each
/// live shard runs the fast Q-outer kernel over its global column range
/// (`sim::cost::flash2_fwd_shard` — tiles above the diagonal, judged in
/// global coordinates, are never loaded), the slowest device bounds
/// per-device HBM, and only live shards ship their N·(d+2) partial
/// across the interconnect. Shards wholly beyond `kv_len` contribute
/// nothing to either term, mirroring the driver's dead-shard skip.
pub fn multi_gpu_cost(
    n: u64,
    d: u64,
    blocks: Blocks,
    shards: u64,
    causal: bool,
    kv_len: Option<u64>,
) -> MultiGpuCost {
    // Model EXACTLY the partition the driver builds: same tile-aligned
    // ranges, same dead-shard predicate — the cost model cannot drift
    // from the schedule it claims to mirror.
    let cfg = AttnConfig { causal, kv_len: kv_len.map(|kl| kl as usize), ..Default::default() };
    let mut hbm_max = 0u64;
    let mut live_shards = 0u64;
    for sh in shard_ranges(n as usize, blocks.b_c, shards as usize) {
        if shard_is_dead(sh, n as usize, &cfg) {
            continue; // dead shard: no work item, no partial shipped
        }
        live_shards += 1;
        let dev =
            crate::sim::cost::flash2_fwd_shard(n, d, blocks, sh.lo as u64, sh.hi as u64, causal);
        hbm_max = hbm_max.max(dev.hbm_elems);
    }
    // Merge: each live device ships (O, l, m) = N(d+2) elements.
    MultiGpuCost { hbm_per_device: hbm_max, interconnect_elems: live_shards * n * (d + 2) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash::flash_forward;
    use crate::attn::flash2::{flash2_backward, flash2_forward};
    use crate::attn::standard::standard_forward;
    use crate::util::prop::{for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn shard_ranges_tile_aligned_and_clamped() {
        let ranges = shard_ranges(48, 8, 7); // 6 tiles, 7 shards -> 6 shards
        assert_eq!(ranges.len(), 6);
        for (i, sh) in ranges.iter().enumerate() {
            assert_eq!(sh.lo % 8, 0, "shard {i} not tile-aligned");
            assert!(sh.lo < sh.hi);
        }
        assert_eq!(ranges.first().unwrap().lo, 0);
        assert_eq!(ranges.last().unwrap().hi, 48);
        // Ragged tail stays aligned at the starts.
        let ragged = shard_ranges(20, 8, 2); // 3 tiles -> per=2 -> [0,16) [16,20)
        assert_eq!(ragged, vec![Shard { lo: 0, hi: 16 }, Shard { lo: 16, hi: 20 }]);
        assert!(shard_ranges(0, 8, 4).is_empty());
    }

    #[test]
    fn dead_shard_predicate_uses_global_coordinates() {
        let causal = AttnConfig::new().causal();
        // Shard starting at or past the last query row is wholly acausal.
        assert!(shard_is_dead(Shard { lo: 16, hi: 24 }, 16, &causal));
        assert!(!shard_is_dead(Shard { lo: 8, hi: 16 }, 16, &causal));
        // Beyond the padded prefix.
        let padded = AttnConfig { kv_len: Some(10), ..Default::default() };
        assert!(shard_is_dead(Shard { lo: 16, hi: 24 }, 64, &padded));
        assert!(!shard_is_dead(Shard { lo: 8, hi: 16 }, 64, &padded));
        // kv_offset shifts the shard's global position.
        let shifted = padded.for_shard(8);
        assert!(shard_is_dead(Shard { lo: 2, hi: 8 }, 64, &shifted));
    }

    #[test]
    fn sharded_bitwise_identical_to_single_device() {
        // The acceptance grid: causal × dropout × kv_len × shard counts
        // {1, 2, 3, 7} × worker counts — the ring schedule must
        // reproduce the single-device fast kernel bit for bit.
        let (n, d) = (48usize, 8usize);
        let (q, k, v) = qkv(n, d, 21);
        let blocks = Blocks::explicit(8, 8);
        for causal in [false, true] {
            for dropout_p in [0.0f32, 0.2] {
                for kv_len in [None, Some(33), Some(5)] {
                    let cfg = AttnConfig {
                        causal,
                        dropout_p,
                        dropout_seed: 7,
                        kv_len,
                        ..Default::default()
                    };
                    let single =
                        flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
                    for shards in [1usize, 2, 3, 7] {
                        for workers in [1usize, 3, 8] {
                            for persistent in [false, true] {
                                let ex = if persistent {
                                    Exec::new(workers)
                                } else {
                                    Exec::scoped(workers)
                                };
                                let (multi, _) =
                                    flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &ex)
                                        .unwrap();
                                let ctx = format!(
                                    "causal={causal} p={dropout_p} kv_len={kv_len:?} \
                                     shards={shards} workers={workers} persistent={persistent}"
                                );
                                assert_eq!(multi.o.data, single.o.data, "O not bitwise: {ctx}");
                                assert_eq!(multi.m, single.lse, "lse not bitwise: {ctx}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_backward_bitwise_identical_to_single_device() {
        // Same grid through the sharded backward: dQ (state threaded
        // through shards) and dK/dV (per-shard column blocks) must both
        // be bitwise equal to flash2_backward.
        let (n, d) = (40usize, 8usize);
        let (q, k, v) = qkv(n, d, 22);
        let mut rng = SplitMix64::new(23);
        let dout = Tensor::randn(&[n, d], &mut rng, 1.0);
        let blocks = Blocks::explicit(8, 8);
        for causal in [false, true] {
            for dropout_p in [0.0f32, 0.2] {
                for kv_len in [None, Some(27), Some(6)] {
                    let cfg = AttnConfig {
                        causal,
                        dropout_p,
                        dropout_seed: 9,
                        kv_len,
                        ..Default::default()
                    };
                    let fwd =
                        flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(2), &mut Hbm::new());
                    let single = flash2_backward(
                        &q,
                        &k,
                        &v,
                        &fwd.o,
                        &dout,
                        fwd.stats(),
                        &cfg,
                        blocks,
                        &Exec::scoped(1),
                        &mut Hbm::new(),
                    );
                    for shards in [1usize, 2, 3, 7] {
                        for workers in [1usize, 4] {
                            for persistent in [false, true] {
                                let ex = if persistent {
                                    Exec::new(workers)
                                } else {
                                    Exec::scoped(workers)
                                };
                                let (multi, _) = flash_backward_sharded(
                                    &q,
                                    &k,
                                    &v,
                                    &fwd.o,
                                    &dout,
                                    fwd.stats(),
                                    &cfg,
                                    blocks,
                                    shards,
                                    &ex,
                                )
                                .unwrap();
                                let ctx = format!(
                                    "causal={causal} p={dropout_p} kv_len={kv_len:?} \
                                     shards={shards} workers={workers} persistent={persistent}"
                                );
                                assert_eq!(multi.dq.data, single.dq.data, "dQ not bitwise: {ctx}");
                                assert_eq!(multi.dk.data, single.dk.data, "dK not bitwise: {ctx}");
                                assert_eq!(multi.dv.data, single.dv.data, "dV not bitwise: {ctx}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_backward_grads_match_finite_difference() {
        // FD straight through the sharded pair with causal + padding +
        // dropout all active (the dropout mask is a deterministic
        // function of indices, so the loss stays differentiable).
        let (n, d) = (6usize, 4usize);
        let (q, k, v) = qkv(n, d, 24);
        let cfg = AttnConfig {
            causal: true,
            kv_len: Some(5),
            dropout_p: 0.25,
            dropout_seed: 3,
            ..Default::default()
        };
        let blocks = Blocks::explicit(2, 2);
        let shards = 3usize;
        let ex = Exec::new(2);
        let fwd = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &ex).unwrap().0;
        let dout = Tensor::full(&[n, d], 1.0);
        let g = flash_backward_sharded(
            &q,
            &k,
            &v,
            &fwd.o,
            &dout,
            fwd.stats(),
            &cfg,
            blocks,
            shards,
            &ex,
        )
        .unwrap()
        .0;
        let f = |q_: &Tensor, k_: &Tensor, v_: &Tensor| -> f32 {
            flash_forward_sharded(q_, k_, v_, &cfg, blocks, shards, &ex)
                .unwrap()
                .0
                .o
                .data
                .iter()
                .sum()
        };
        let eps = 1e-3f32;
        for (which, (x, gx)) in [(0, (&q, &g.dq)), (1, (&k, &g.dk)), (2, (&v, &g.dv))] {
            for idx in [0usize, 7, 13, 19, 23] {
                let mut xp = x.clone();
                xp.data[idx] += eps;
                let mut xm = x.clone();
                xm.data[idx] -= eps;
                let (fp, fm) = match which {
                    0 => (f(&xp, &k, &v), f(&xm, &k, &v)),
                    1 => (f(&q, &xp, &v), f(&q, &xm, &v)),
                    _ => (f(&q, &k, &xp), f(&q, &k, &xm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                let an = gx.data[idx];
                assert!(
                    (fd - an).abs() < 3e-2 + 0.05 * an.abs(),
                    "which={which} idx={idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn tree_schedule_matches_single_device_on_the_grid() {
        // The §5 merge path now covers causal + dropout via global
        // coordinates; exact to fp rounding for any shard count.
        let (n, d) = (48usize, 8usize);
        let (q, k, v) = qkv(n, d, 25);
        let blocks = Blocks::explicit(8, 8);
        for causal in [false, true] {
            for dropout_p in [0.0f32, 0.2] {
                for kv_len in [None, Some(29)] {
                    let cfg = AttnConfig {
                        causal,
                        dropout_p,
                        dropout_seed: 5,
                        kv_len,
                        ..Default::default()
                    };
                    let single =
                        flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
                    for shards in [2usize, 3, 6] {
                        let tree = flash_forward_sharded_tree(
                            &q,
                            &k,
                            &v,
                            &cfg,
                            blocks,
                            shards,
                            &Exec::new(4),
                        )
                        .unwrap()
                        .0;
                        let diff = single.o.max_abs_diff(&tree.o);
                        assert!(
                            diff < 1e-4,
                            "causal={causal} p={dropout_p} kv_len={kv_len:?} \
                             shards={shards}: diff {diff}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn block_sparse_tree_schedule_matches_unsharded_on_the_grid() {
        // The sparse sequence-parallel path: tile-aligned shards all
        // holding the SAME global mask, merged with the §5 identity,
        // must match the unsharded fast sparse kernel — causal ×
        // dropout × padding × shard counts, butterfly and local_global.
        let (n, d) = (48usize, 8usize);
        let (q, k, v) = qkv(n, d, 31);
        let blocks = Blocks::explicit(8, 8);
        for mask in [BlockMask::butterfly(6, 6), BlockMask::local_global(6, 6, 1, 1)] {
            for causal in [false, true] {
                for dropout_p in [0.0f32, 0.2] {
                    for kv_len in [None, Some(29)] {
                        let cfg = AttnConfig {
                            causal,
                            dropout_p,
                            dropout_seed: 5,
                            kv_len,
                            ..Default::default()
                        };
                        let single = block_sparse2_forward(
                            &q,
                            &k,
                            &v,
                            &mask,
                            &cfg,
                            blocks,
                            &Exec::scoped(1),
                            &mut Hbm::new(),
                        );
                        for shards in [1usize, 2, 3, 6] {
                            let tree = block_sparse_forward_sharded_tree(
                                &q,
                                &k,
                                &v,
                                &mask,
                                &cfg,
                                blocks,
                                shards,
                                &Exec::new(3),
                            )
                            .unwrap()
                            .0;
                            let diff = single.o.max_abs_diff(&tree.o);
                            assert!(
                                diff < 1e-4,
                                "causal={causal} p={dropout_p} kv_len={kv_len:?} \
                                 shards={shards}: diff {diff}"
                            );
                            // lse agreement via the (l, m) encoding: a
                            // live row's merged stats must recover the
                            // single-device logsumexp.
                            for r in 0..n {
                                let merged = tree.stats().lse(r);
                                let want = single.lse[r];
                                assert!(
                                    (merged - want).abs() < 1e-4
                                        || (merged == f32::NEG_INFINITY
                                            && want == f32::NEG_INFINITY),
                                    "row {r}: lse {merged} vs {want}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn block_sparse_all_zero_shards_are_dead() {
        // A mask whose live blocks all land in the first shard must
        // leave exactly one partial; an all-zero mask leaves none and
        // the tree returns the defined all-masked result.
        let (q, k, v) = qkv(16, 4, 33);
        let blocks = Blocks::explicit(4, 4);
        let mut mask = BlockMask::zeros(4, 4);
        for i in 0..4 {
            mask.set(i, 0, true);
            mask.set(i, 1, true);
        }
        let cfg = AttnConfig::default();
        let ex = Exec::new(2);
        let parts = block_sparse_shard_partials(&q, &k, &v, &mask, &cfg, blocks, 2, &ex);
        assert_eq!(parts.len(), 1, "right shard's mask window is all-zero");
        let none = block_sparse_shard_partials(
            &q, &k, &v, &BlockMask::zeros(4, 4), &cfg, blocks, 2, &ex,
        );
        assert!(none.is_empty());
        let tree = block_sparse_forward_sharded_tree(
            &q, &k, &v, &BlockMask::zeros(4, 4), &cfg, blocks, 2, &ex,
        )
        .unwrap()
        .0;
        assert!(tree.o.data.iter().all(|&x| x == 0.0));
        assert!(tree.m.iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn sharded_matches_single_device() {
        let (q, k, v) = qkv(64, 16, 0);
        let cfg = AttnConfig::default();
        let blocks = Blocks::explicit(16, 16);
        let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        for shards in [1usize, 2, 3, 4, 8] {
            let multi = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(shards))
                .unwrap()
                .0;
            assert!(
                single.o.max_abs_diff(&multi.o) < 1e-4,
                "shards={shards}: diff {}",
                single.o.max_abs_diff(&multi.o)
            );
        }
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let (q, k, v) = qkv(32, 8, 1);
        let cfg = AttnConfig::default();
        let blocks = Blocks::explicit(8, 8);
        // Three disjoint key shards.
        let parts: Vec<AttnOutput> = [(0, 12), (12, 20), (20, 32)]
            .iter()
            .map(|&(lo, hi)| {
                let (ks, vs) = (k.slice_rows(lo, hi), v.slice_rows(lo, hi));
                flash_forward(&q, &ks, &vs, &cfg, blocks, &mut Hbm::new())
            })
            .collect();
        let abc = merge_partials(&merge_partials(&parts[0], &parts[1]), &parts[2]);
        let a_bc = merge_partials(&parts[0], &merge_partials(&parts[1], &parts[2]));
        let cba = merge_partials(&merge_partials(&parts[2], &parts[1]), &parts[0]);
        assert!(abc.o.max_abs_diff(&a_bc.o) < 1e-5);
        assert!(abc.o.max_abs_diff(&cba.o) < 1e-5);
    }

    #[test]
    fn sharded_with_padding_mask() {
        let (q, k, v) = qkv(48, 8, 2);
        let cfg = AttnConfig { kv_len: Some(29), ..Default::default() };
        let blocks = Blocks::explicit(8, 8);
        let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        let multi =
            flash_forward_sharded(&q, &k, &v, &cfg, blocks, 3, &Exec::new(3)).unwrap().0;
        assert!(single.o.max_abs_diff(&multi.o) < 1e-4);
    }

    #[test]
    fn dead_shards_skipped_kv_len_within_one_shard() {
        // Regression: kv_len ≤ one shard width means every shard but the
        // first is entirely beyond the valid key prefix. Those shards must
        // be skipped up front, and the result must match the dense oracle
        // with no NaN/Inf anywhere.
        let (q, k, v) = qkv(48, 8, 7);
        let blocks = Blocks::explicit(8, 8);
        for kv_len in [5usize, 8, 1] {
            let cfg = AttnConfig { kv_len: Some(kv_len), ..Default::default() };
            let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            for shards in [6usize, 8, 48] {
                let multi = flash_forward_sharded(&q, &k, &v, &cfg, blocks, shards, &Exec::new(4))
                    .unwrap()
                    .0;
                assert!(
                    multi.o.data.iter().all(|x| x.is_finite()),
                    "kv_len={kv_len} shards={shards}: non-finite output"
                );
                assert!(
                    single.o.max_abs_diff(&multi.o) < 1e-4,
                    "kv_len={kv_len} shards={shards}: diff {}",
                    single.o.max_abs_diff(&multi.o)
                );
            }
        }
    }

    #[test]
    fn kv_len_zero_gives_zero_output_no_nan() {
        let (q, k, v) = qkv(16, 4, 9);
        let cfg = AttnConfig { kv_len: Some(0), ..Default::default() };
        let ex = Exec::new(3);
        let out =
            flash_forward_sharded(&q, &k, &v, &cfg, Blocks::explicit(4, 4), 3, &ex).unwrap().0;
        assert!(out.o.data.iter().all(|&x| x == 0.0));
        assert!(out.l.iter().all(|&x| x == 0.0));
        assert!(out.m.iter().all(|&x| x == f32::NEG_INFINITY));
        // Tree schedule: every shard is dead, same defined result.
        let tree = flash_forward_sharded_tree(&q, &k, &v, &cfg, Blocks::explicit(4, 4), 3, &ex)
            .unwrap()
            .0;
        assert!(tree.o.data.iter().all(|&x| x == 0.0));
        assert!(tree.m.iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn property_merge_handles_all_masked_partials() {
        // The -inf/-inf case: merging two fully-masked partials must stay
        // NaN-free and keep zero-mass semantics; merging masked with live
        // must reproduce the live partial exactly; and the all-masked
        // identity must be associative with live merges.
        for_each_case("merge_masked", 8, |rng| {
            let n = usize_in(rng, 2, 24);
            let d = *crate::util::prop::choose(rng, &[2usize, 4, 8]);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let blocks = Blocks::explicit(4, 4);
            let dead_cfg = AttnConfig { kv_len: Some(0), ..Default::default() };
            let dead =
                flash2_forward(&q, &k, &v, &dead_cfg, blocks, &Exec::scoped(1), &mut Hbm::new())
                    .into_attn_output();
            let live = flash2_forward(
                &q,
                &k,
                &v,
                &AttnConfig::default(),
                blocks,
                &Exec::scoped(1),
                &mut Hbm::new(),
            )
            .into_attn_output();

            let both_dead = merge_partials(&dead, &dead);
            assert!(both_dead.o.data.iter().all(|&x| x == 0.0), "n={n} d={d}: dead+dead O");
            assert!(both_dead.l.iter().all(|&x| x == 0.0));
            assert!(both_dead.m.iter().all(|&x| x == f32::NEG_INFINITY));

            for merged in [
                merge_partials(&dead, &live),
                merge_partials(&live, &dead),
                merge_partials(&both_dead, &live),
            ] {
                assert!(merged.o.data.iter().all(|x| x.is_finite()), "n={n} d={d}");
                assert!(live.o.max_abs_diff(&merged.o) < 1e-5, "n={n} d={d}");
            }
        });
    }

    #[test]
    fn property_merge_zero_mass_on_denormal_weights() {
        // Satellite bugfix: when both partials' merge weights underflow
        // to subnormals, the old `1 / l.max(1e-37)` clamp scaled junk by
        // ~1e37. Such rows must take the defined zero-mass branch and
        // stay associative with live partials.
        let (n, d) = (3usize, 4usize);
        let mk = |l: f32, m: f32, val: f32| AttnOutput {
            o: Tensor::full(&[n, d], val),
            l: vec![l; n],
            m: vec![m; n],
        };
        let a = mk(1.0e-38, -200.0, 7.0); // subnormal mass, junk payload
        let b = mk(1.0e-39, -200.0, -9.0);
        let ab = merge_partials(&a, &b);
        assert!(ab.o.data.iter().all(|&x| x == 0.0), "underflowed mass must zero the row");
        assert!(ab.l.iter().all(|&x| x == 0.0));
        assert!(ab.m.iter().all(|&x| x == f32::NEG_INFINITY));
        assert!(ab.o.data.iter().all(|x| x.is_finite()));

        // Associativity with a live partial, both groupings: the
        // denormal partials' weights vanish against a live max either
        // way, so all orders agree with the live partial.
        let live = mk(2.0, 1.0, 0.5);
        for merged in [
            merge_partials(&ab, &live),
            merge_partials(&live, &ab),
            merge_partials(&a, &merge_partials(&b, &live)),
            merge_partials(&merge_partials(&live, &a), &b),
        ] {
            assert!(merged.o.data.iter().all(|x| x.is_finite()));
            assert!(live.o.max_abs_diff(&merged.o) < 1e-6);
            for r in 0..n {
                assert!((merged.l[r] - live.l[r]).abs() < 1e-6);
                assert!((merged.m[r] - live.m[r]).abs() < 1e-6);
            }
        }

        // Randomised denormal sweep: merges never produce NaN/Inf, the
        // zero-mass rows keep the (0, -inf) convention, and grouping
        // does not matter. The l pool is chosen so no subset sum lands
        // in the cutoff's rounding window (any denormal-only total
        // stays below f32::MIN_POSITIVE, any total with a live partial
        // is ≥ 1) — at the exact cutoff boundary associativity cannot
        // hold for ANY flooring rule, which is why production masses
        // are ≥ 1 per live row.
        for_each_case("merge_denormal", 8, |rng| {
            let pick = |rng: &mut SplitMix64| {
                let ls = [0.0f32, 1.0e-39, 5.0e-40, 1.0, 2.0];
                let l = ls[(rng.next_u64() % ls.len() as u64) as usize];
                let m = if l == 0.0 { f32::NEG_INFINITY } else { -200.0 };
                mk(l, m, rng.next_f32() * 4.0 - 2.0)
            };
            let (x, y, z) = (pick(rng), pick(rng), pick(rng));
            let lhs = merge_partials(&merge_partials(&x, &y), &z);
            let rhs = merge_partials(&x, &merge_partials(&y, &z));
            for t in [&lhs, &rhs] {
                for r in 0..n {
                    assert!(t.o.row(r).iter().all(|x| x.is_finite()));
                    assert!(t.l[r].is_finite());
                    if t.l[r] == 0.0 {
                        assert_eq!(t.m[r], f32::NEG_INFINITY);
                        assert!(t.o.row(r).iter().all(|&x| x == 0.0));
                    }
                }
            }
            assert!(lhs.o.max_abs_diff(&rhs.o) < 1e-5);
        });
    }

    #[test]
    fn property_random_shard_and_worker_counts() {
        for_each_case("sharded", 8, |rng| {
            let n = usize_in(rng, 8, 48);
            let d = *crate::util::prop::choose(rng, &[4usize, 8]);
            let shards = usize_in(rng, 1, 6);
            let w = usize_in(rng, 1, 6);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let cfg = AttnConfig::default();
            let single = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            let ex = Exec::new(w);
            let multi =
                flash_forward_sharded(&q, &k, &v, &cfg, Blocks::explicit(8, 8), shards, &ex)
                    .unwrap()
                    .0;
            assert!(single.o.max_abs_diff(&multi.o) < 1e-4, "n={n} d={d} shards={shards} w={w}");
        });
    }

    #[test]
    fn interconnect_traffic_linear_not_quadratic() {
        let blocks = Blocks::explicit(64, 256);
        let c2 = multi_gpu_cost(8192, 64, blocks, 4, false, None);
        let c1 = multi_gpu_cost(4096, 64, blocks, 4, false, None);
        let ratio = c2.interconnect_elems as f64 / c1.interconnect_elems as f64;
        assert!((1.9..2.1).contains(&ratio), "merge traffic must be O(N): {ratio}");
        // Per-device HBM shrinks as workers grow.
        let w8 = multi_gpu_cost(8192, 64, blocks, 8, false, None).hbm_per_device;
        let w2 = multi_gpu_cost(8192, 64, blocks, 2, false, None).hbm_per_device;
        assert!(w8 < w2);
    }

    #[test]
    fn multi_gpu_cost_causal_skip_and_dead_shards() {
        let blocks = Blocks::explicit(64, 64);
        let (n, d, w) = (4096u64, 64u64, 4u64);
        // Causal-skip term: every device loads fewer K/V tiles.
        let full = multi_gpu_cost(n, d, blocks, w, false, None);
        let caus = multi_gpu_cost(n, d, blocks, w, true, None);
        assert!(
            caus.hbm_per_device < full.hbm_per_device,
            "causal {} !< full {}",
            caus.hbm_per_device,
            full.hbm_per_device
        );
        assert_eq!(caus.interconnect_elems, full.interconnect_elems);
        // Dead shards beyond kv_len ship no partial: with the valid
        // prefix inside the first shard, interconnect is one device's.
        let padded = multi_gpu_cost(n, d, blocks, w, false, Some(100));
        assert_eq!(padded.interconnect_elems, n * (d + 2));
        assert!(padded.hbm_per_device <= full.hbm_per_device);
    }
}
