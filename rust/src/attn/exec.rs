//! The execution plane: one long-lived work-stealing pool behind a small
//! [`Exec`] handle that every attention schedule runs on.
//!
//! Until this module, every batched/sharded call paid a
//! `std::thread::scope` spin-up: `w` fresh OS threads per entry-point
//! call, torn down at the end of the call. At serve-time QPS and small
//! decode batches that spin-up dominates the actual kernel work. Here
//! the threads are spawned once, parked on a condvar between calls, and
//! re-dispatched per call — the "pool" section of the hotpath bench
//! measures the difference directly.
//!
//! ## The `Exec` handle
//!
//! [`Exec`] carries the whole execution policy: worker count, the
//! [`FaultPlan`] under which items run, the finiteness-guardrail flag,
//! and the pool mode:
//!
//! * [`Exec::new`] — **persistent** mode: work is drained by the
//!   process-wide parked worker pool (plus the calling thread, which
//!   always participates — see below).
//! * [`Exec::scoped`] — **per-call scope** mode: the exact pre-pool
//!   behaviour, one `std::thread::scope` per call. This is the fresh-pool
//!   oracle the reuse tests compare against and the baseline the bench's
//!   "pool" section measures; production callers want [`Exec::new`].
//!
//! Both modes run the *identical* drain loop over the identical work
//! items, so outputs are bitwise identical between them by construction.
//!
//! ## Determinism
//!
//! The persistent pool preserves the project's two signature guarantees
//! unchanged:
//!
//! * **Workers race for items, never for output slots.** Each work item
//!   owns its output windows outright ([`PoolItem`]); the deterministic
//!   item → window mapping is fixed before anything is scheduled, and
//!   finished items are stitched back in item-index order on the calling
//!   thread. Claim order and worker identity never touch the numerics.
//! * **Access-for-access HBM accounting.** Per-attempt counters merge
//!   into the run's counter under the run lock at disposal time; counter
//!   addition is associative and commutative, so totals are independent
//!   of worker count, claim order, and pool mode.
//!
//! ## Progress
//!
//! The calling thread always runs the drain loop itself and persistent
//! mode only *adds* `workers - 1` helper tasks to the shared pool, so a
//! call makes progress even if every pool thread is busy with other
//! runs — there is no cross-run deadlock, and `workers = 1` never
//! touches the shared pool at all. Helper tasks that wake up after their
//! run already finished observe an empty queue and exit immediately.
//!
//! ## Fault semantics
//!
//! The drain loop is the fault-tolerant pool of `attn::faults`, moved
//! here verbatim from `attn::batched` (PR 6): `catch_unwind` panic
//! containment, publish-time fault injection, zero-and-requeue retry up
//! to [`MAX_ATTEMPTS`], the finiteness guardrail, and per-attempt retry
//! traffic accounted in the [`FaultReport`]. See the failure-semantics
//! section of the `attn` module docs.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};

use super::faults::{
    panic_message, AttnError, FaultKind, FaultPlan, FaultReport, FaultSite, InjectedPanic,
    PoolItem, MAX_ATTEMPTS,
};
use crate::sim::hbm::Hbm;

// ---------------------------------------------------------------------
// The process-wide parked worker pool
// ---------------------------------------------------------------------

/// Upper bound on pool threads ever spawned. Far above any sane
/// `workers` setting; exists so a pathological caller cannot fork-bomb
/// the process. Past the cap, submitted helpers queue until a parked
/// thread frees up — the caller thread still guarantees progress.
const MAX_POOL_THREADS: usize = 256;

/// A queued helper task: the drain loop of one run, type-erased.
type Task = Box<dyn FnOnce() + Send>;

struct PoolQueue {
    tasks: VecDeque<Task>,
    /// Threads currently parked in `ready.wait` (spawn only when none
    /// are free to take the new task).
    idle: usize,
    /// Threads ever spawned (monotone; pool threads never exit).
    spawned: usize,
}

struct Pool {
    queue: Mutex<PoolQueue>,
    ready: Condvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        Pool {
            queue: Mutex::new(PoolQueue { tasks: VecDeque::new(), idle: 0, spawned: 0 }),
            ready: Condvar::new(),
        }
    })
}

/// Enqueue one helper task, growing the pool lazily: a new worker is
/// spawned only when no parked thread is available and the cap allows.
fn submit(task: Task) {
    let p = pool();
    let mut q = p.queue.lock().unwrap_or_else(PoisonError::into_inner);
    q.tasks.push_back(task);
    if q.idle == 0 && q.spawned < MAX_POOL_THREADS {
        q.spawned += 1;
        drop(q);
        spawn_worker();
    } else {
        drop(q);
    }
    p.ready.notify_one();
}

/// Spawn one detached pool worker: park on the condvar when the task
/// queue is empty, run tasks as they arrive, never exit. This is the
/// tree's sole sanctioned `std::thread::spawn` site (lint R1); every
/// other module routes its parallelism through [`Exec`].
fn spawn_worker() {
    std::thread::spawn(|| {
        let p = pool();
        loop {
            let task = {
                let mut q = p.queue.lock().unwrap_or_else(PoisonError::into_inner);
                loop {
                    if let Some(t) = q.tasks.pop_front() {
                        break t;
                    }
                    q.idle += 1;
                    q = p.ready.wait(q).unwrap_or_else(PoisonError::into_inner);
                    q.idle -= 1;
                }
            };
            // Drain tasks contain worker panics internally; a stray
            // unwind must not take the parked thread (or, via a poisoned
            // queue lock, the whole pool) down with it.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        }
    });
}

// ---------------------------------------------------------------------
// Per-run state (the guarded drain loop)
// ---------------------------------------------------------------------

/// An item in flight or queued: its original index and attempt counter.
struct Tracked<T> {
    idx: usize,
    attempt: u32,
    item: T,
}

/// Shared per-run state behind one mutex: the (re)queue, the count of
/// items being worked on (a faulted one may return to the queue, so
/// "queue empty" alone does not mean "done"), committed items parked in
/// index order, the run's HBM counter, the first fatal error, and the
/// fault bookkeeping.
struct RunCore<T> {
    queue: Vec<Tracked<T>>,
    in_flight: usize,
    error: Option<AttnError>,
    report: FaultReport,
    /// Committed items, slot `idx` filled exactly once on commit; the
    /// caller stitches their windows back in index order.
    finished: Vec<Option<T>>,
    /// The run's merged HBM counter. Per-attempt counters land here
    /// under the lock at disposal time — counter addition is associative
    /// and commutative, so the total is identical to the per-call-scope
    /// pool's join-time merge for any claim order.
    hbm: Hbm,
    /// Audit check (c): per-item commit counts — every item must commit
    /// exactly once on a successful run (retries are not commits).
    #[cfg(feature = "audit")]
    commits: Vec<u32>,
}

/// How a finished attempt is disposed of (classified outside the lock —
/// the finiteness scan is O(window) and must not serialize workers).
enum Disposal {
    Commit { delayed: bool },
    Retry { kind: RetryKind, attempt_hbm: Option<Hbm>, message: String },
}

enum RetryKind {
    Panicked,
    Poisoned,
    Dropped,
    NonFinite,
}

/// One guarded run: the work closure, the fault policy it runs under,
/// and the shared drain state. Helper tasks and the calling thread all
/// drain the same job through an `Arc`.
struct RunJob<T, F> {
    state: Mutex<RunCore<T>>,
    ready: Condvar,
    work: F,
    plan: FaultPlan,
    site: FaultSite,
    validate: bool,
    #[cfg(feature = "audit")]
    order: DrainOrder,
}

impl<T, F> RunJob<T, F>
where
    T: PoolItem,
    F: Fn(&mut T) -> Hbm + Send + Sync,
{
    /// A contained panic can poison the mutex between lock() and the
    /// guard drop; the inner state is still consistent (the lock is held
    /// only for queue bookkeeping, never across item execution), so
    /// recover it instead of cascading.
    fn lock(&self) -> MutexGuard<'_, RunCore<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Claim the next queued item under the run lock. Plain builds pop
    /// LIFO, unconditionally — this is the production claim policy and
    /// the only one that ships. The audit feature can override it with a
    /// rank table so the schedule explorer steers the claim sequence
    /// through arbitrary permutations (claims serialize under the run
    /// lock, so the rank order fully determines the claim order among
    /// the items queued at each instant; a retried item re-enters the
    /// competition under its original rank).
    #[cfg(not(feature = "audit"))]
    fn claim(&self, st: &mut RunCore<T>) -> Option<Tracked<T>> {
        st.queue.pop()
    }

    #[cfg(feature = "audit")]
    fn claim(&self, st: &mut RunCore<T>) -> Option<Tracked<T>> {
        match &self.order {
            DrainOrder::Lifo => st.queue.pop(),
            DrainOrder::Ranked(ranks) => {
                // O(queue) scan — audit-only, never on the shipping
                // path. The (rank, idx) key is unique per queued item
                // (an item is queued at most once), so the choice is
                // total and tie-free.
                let pos = st
                    .queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, t)| (ranks.get(t.idx).copied().unwrap_or(t.idx), t.idx))
                    .map(|(i, _)| i)?;
                Some(st.queue.remove(pos))
            }
        }
    }

    /// The fault-tolerant drain loop behind every batched and sharded
    /// schedule (semantics: see `attn::faults` and the module docs).
    /// Claims items LIFO, runs them under `catch_unwind`, and commits or
    /// zero-requeues under the lock. Runs identically on scope threads,
    /// parked pool threads, and the calling thread.
    fn drain(&self) {
        loop {
            let mut st = self.lock();
            let claimed = loop {
                if st.error.is_some() {
                    break None;
                }
                if let Some(t) = self.claim(&mut st) {
                    break Some(t);
                }
                if st.in_flight == 0 {
                    break None;
                }
                // Queue empty but items in flight: one may yet fail and
                // requeue, so wait instead of exiting.
                st = self.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
            };
            let Some(mut t) = claimed else {
                break;
            };
            st.in_flight += 1;
            drop(st);

            let fault = self.plan.fault_for(self.site, t.idx, t.attempt);
            if fault == Some(FaultKind::DelayedShard) {
                // A straggler, not a failure: complete late, commit
                // normally, add no traffic.
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let h = (self.work)(&mut t.item);
                if fault == Some(FaultKind::WorkerPanic) {
                    // resume_unwind skips the panic hook (no stderr spam
                    // for planned chaos); the payload carries the
                    // attempt's exact traffic so the retry accounting
                    // stays access-for-access.
                    std::panic::resume_unwind(Box::new(InjectedPanic(h)));
                }
                h
            }));
            // The attempt's real traffic (None only for a genuine
            // mid-item panic, whose partial traffic is unknowable).
            let mut traffic: Option<Hbm> = None;
            let disposal = match outcome {
                Ok(h) => {
                    traffic = Some(h.clone());
                    if fault == Some(FaultKind::PoisonedPartial) {
                        t.item.poison();
                    }
                    if fault == Some(FaultKind::DroppedMerge) {
                        Disposal::Retry {
                            kind: RetryKind::Dropped,
                            attempt_hbm: Some(h),
                            message: "completion record dropped".into(),
                        }
                    } else if (self.validate || fault == Some(FaultKind::PoisonedPartial))
                        && !t.item.check_finite()
                    {
                        let kind = if fault == Some(FaultKind::PoisonedPartial) {
                            RetryKind::Poisoned
                        } else {
                            RetryKind::NonFinite
                        };
                        Disposal::Retry {
                            kind,
                            attempt_hbm: Some(h),
                            message: "non-finite output".into(),
                        }
                    } else {
                        Disposal::Commit { delayed: fault == Some(FaultKind::DelayedShard) }
                    }
                }
                Err(payload) => {
                    let attempt_hbm = payload.downcast_ref::<InjectedPanic>().map(|inj| {
                        // Injected at publish time: the work ran to
                        // completion, its traffic is real and gets
                        // re-done by the retry.
                        traffic = Some(inj.0.clone());
                        inj.0.clone()
                    });
                    Disposal::Retry {
                        kind: RetryKind::Panicked,
                        attempt_hbm,
                        message: panic_message(&*payload),
                    }
                }
            };

            let mut st = self.lock();
            st.in_flight -= 1;
            if let Some(h) = &traffic {
                st.hbm.merge(h);
            }
            match disposal {
                Disposal::Commit { delayed } => {
                    #[cfg(feature = "audit")]
                    {
                        st.commits[t.idx] += 1;
                    }
                    if delayed {
                        st.report.delayed += 1;
                    }
                    st.finished[t.idx] = Some(t.item);
                }
                Disposal::Retry { kind, attempt_hbm, message } => {
                    match kind {
                        RetryKind::Panicked => st.report.panics += 1,
                        RetryKind::Poisoned => st.report.poisoned += 1,
                        RetryKind::Dropped => st.report.dropped += 1,
                        RetryKind::NonFinite => st.report.guardrail += 1,
                    }
                    if let Some(h) = &attempt_hbm {
                        st.report.retry_hbm.merge(h);
                    }
                    if t.attempt + 1 < MAX_ATTEMPTS {
                        st.report.retries += 1;
                        // The backward sweeps accumulate into their
                        // windows (and a poisoned forward scribbled NaN
                        // over them): zero back to the pre-run state so
                        // the re-run reproduces a fresh run bit for bit.
                        t.item.reset();
                        st.queue.push(Tracked {
                            idx: t.idx,
                            attempt: t.attempt + 1,
                            item: t.item,
                        });
                    } else if st.error.is_none() {
                        let (slice, block) = t.item.id();
                        let attempts = t.attempt + 1;
                        st.error = Some(match kind {
                            RetryKind::Poisoned | RetryKind::NonFinite => AttnError::NonFinite {
                                site: self.site,
                                slice,
                                batch: 0,
                                head: 0,
                                block,
                                attempts,
                            },
                            _ => AttnError::ItemFailed {
                                site: self.site,
                                slice,
                                block,
                                attempts,
                                message,
                            },
                        });
                    }
                }
            }
            drop(st);
            self.ready.notify_all();
        }
    }
}

// ---------------------------------------------------------------------
// The Exec handle
// ---------------------------------------------------------------------

/// Audit-only claim-order override for the schedule-space explorer.
///
/// The production pool claims items LIFO; that fixed order could hide a
/// commit path that is only correct *because* of the order. Under the
/// `audit` feature the explorer re-runs a pool site under many distinct
/// rank tables (exhaustive permutations for small item counts, seeded
/// adversarial shuffles for large ones) and asserts bitwise-identical
/// outputs and identical item→slot fingerprints for every one of them.
/// Zero cost when the feature is off: the field and the ranked claim
/// scan are compiled out.
#[cfg(feature = "audit")]
#[derive(Clone, Debug, Default)]
pub enum DrainOrder {
    /// The production policy: claim the most recently queued item.
    #[default]
    Lifo,
    /// Claim the queued item with the smallest rank (`ranks[item_idx]`);
    /// items beyond the table rank as their own index.
    Ranked(Arc<Vec<usize>>),
}

/// The execution policy every attention entry point runs under: worker
/// count, fault plan, finiteness-guardrail flag, and pool mode. Cheap to
/// clone; see the module docs for the mode semantics.
#[derive(Clone, Debug)]
pub struct Exec {
    workers: usize,
    plan: FaultPlan,
    validate: bool,
    scoped: bool,
    #[cfg(feature = "audit")]
    order: DrainOrder,
}

impl Exec {
    /// Persistent-pool execution with `workers` concurrent drains per
    /// call (the calling thread plus `workers - 1` parked pool threads),
    /// no fault injection, guardrail off. The production default.
    pub fn new(workers: usize) -> Exec {
        Exec {
            workers,
            plan: FaultPlan::none(),
            validate: false,
            scoped: false,
            #[cfg(feature = "audit")]
            order: DrainOrder::Lifo,
        }
    }

    /// Per-call `std::thread::scope` execution: `workers` threads
    /// spawned and joined per call — the pre-pool behaviour, kept as the
    /// fresh-pool oracle and the bench baseline.
    pub fn scoped(workers: usize) -> Exec {
        Exec { scoped: true, ..Exec::new(workers) }
    }

    /// Run work under `plan` (deterministic fault injection; see
    /// `attn::faults`). Injection is per [`FaultSite`], so a plan only
    /// fires at the schedules it names.
    pub fn with_plan(mut self, plan: &FaultPlan) -> Exec {
        self.plan = plan.clone();
        self
    }

    /// Enable the finiteness guardrail: every item's output windows are
    /// scanned before commit, and a non-finite window is retried like a
    /// contained panic.
    pub fn validated(mut self) -> Exec {
        self.validate = true;
        self
    }

    /// Same policy, different worker count — the pool-growth grids sweep
    /// worker counts over one configured handle with this.
    pub fn with_workers(mut self, workers: usize) -> Exec {
        self.workers = workers;
        self
    }

    /// Audit-only: steer the claim sequence through `ranks` (see
    /// [`DrainOrder`]). The schedule explorer is the sole caller.
    #[cfg(feature = "audit")]
    pub fn with_drain_order(mut self, ranks: Vec<usize>) -> Exec {
        self.order = DrainOrder::Ranked(Arc::new(ranks));
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn validate(&self) -> bool {
        self.validate
    }

    /// True for per-call-scope mode ([`Exec::scoped`]).
    pub fn is_scoped(&self) -> bool {
        self.scoped
    }

    /// Same workers and pool mode, no fault plan, guardrail off — for
    /// inner schedules whose faults are handled by an outer retry loop
    /// (the tree schedule's per-shard retries).
    pub(crate) fn fault_free(&self) -> Exec {
        Exec { plan: FaultPlan::none(), validate: false, ..self.clone() }
    }

    /// Drain `items` through this handle's pool: every item is claimed
    /// dynamically (workers race for items, never for output slots),
    /// run through `work` under the handle's fault plan, and returned —
    /// committed, in item-index order — together with the run's
    /// [`FaultReport`]. Per-attempt HBM counters merge into `hbm`;
    /// totals are identical for any worker count, claim order, or pool
    /// mode. On retry-budget exhaustion the first typed error is
    /// returned and the already-running attempts are drained first, so
    /// `hbm` still reflects all work actually performed.
    pub(crate) fn run<T, F>(
        &self,
        items: Vec<T>,
        site: FaultSite,
        hbm: &mut Hbm,
        work: F,
    ) -> Result<(Vec<T>, FaultReport), AttnError>
    where
        T: PoolItem,
        F: Fn(&mut T) -> Hbm + Send + Sync + 'static,
    {
        if items.is_empty() {
            return Ok((Vec::new(), FaultReport::default()));
        }
        // Audit check (a): every item's claimed output windows are
        // disjoint, verified (and optionally fingerprinted) before any
        // drain starts — workers race for items, never for output slots.
        #[cfg(feature = "audit")]
        {
            let manifest: Vec<super::audit::ItemClaims> = items
                .iter()
                .enumerate()
                .map(|(idx, it)| super::audit::ItemClaims { idx, id: it.id(), claims: it.claims() })
                .collect();
            super::audit::check_and_record(site, &manifest);
        }
        let n_items = items.len();
        let w = self.workers.max(1).min(n_items);
        let job = Arc::new(RunJob {
            state: Mutex::new(RunCore {
                queue: items
                    .into_iter()
                    .enumerate()
                    .map(|(idx, item)| Tracked { idx, attempt: 0, item })
                    .collect(),
                in_flight: 0,
                error: None,
                report: FaultReport::default(),
                finished: (0..n_items).map(|_| None).collect(),
                hbm: Hbm::new(),
                #[cfg(feature = "audit")]
                commits: vec![0; n_items],
            }),
            ready: Condvar::new(),
            work,
            plan: self.plan.clone(),
            site,
            validate: self.validate,
            #[cfg(feature = "audit")]
            order: self.order.clone(),
        });
        if self.scoped {
            run_scoped(&job, w);
        } else {
            // Caller-assist: enqueue w-1 helpers, then drain on this
            // thread too. The helpers may start late (or, past the pool
            // cap, never) — the caller's own drain guarantees progress,
            // and w = 1 does not touch the shared pool at all.
            for _ in 1..w {
                let j = Arc::clone(&job);
                submit(Box::new(move || j.drain()));
            }
            job.drain();
            // The caller's drain can exit (on error, or having claimed
            // the last item's requeue slot race) while helpers still run
            // their current attempt; wait for them so `hbm` reflects all
            // work performed, exactly like the scoped join.
            let mut st = job.lock();
            while st.in_flight > 0 {
                st = job.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        }
        let mut st = job.lock();
        hbm.merge(&st.hbm);
        match st.error.take() {
            Some(e) => Err(e),
            None => {
                // Audit check (c): success means every output window was
                // committed by exactly one attempt.
                #[cfg(feature = "audit")]
                super::audit::check_commits(site, &st.commits);
                let outs = st
                    .finished
                    .iter_mut()
                    .map(|slot| slot.take().expect("exec: committed item missing"))
                    .collect();
                Ok((outs, std::mem::take(&mut st.report)))
            }
        }
    }
}

/// Per-call-scope execution: `w` threads spawned for this run and joined
/// before returning — the pre-pool pool, bit-for-bit. The only sanctioned
/// `std::thread::scope` outside the per-slice reference kernels.
fn run_scoped<T, F>(job: &Arc<RunJob<T, F>>, w: usize)
where
    T: PoolItem,
    F: Fn(&mut T) -> Hbm + Send + Sync,
{
    std::thread::scope(|scope| {
        for _ in 0..w {
            let j = &*job;
            scope.spawn(move || j.drain());
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial owned-window item for pool-mechanics tests.
    struct SqItem {
        idx: usize,
        out: Vec<f32>,
    }

    impl PoolItem for SqItem {
        fn id(&self) -> (usize, usize) {
            (self.idx, 0)
        }
        fn reset(&mut self) {
            self.out.fill(0.0);
        }
        fn check_finite(&self) -> bool {
            self.out.iter().all(|x| x.is_finite())
        }
        fn poison(&mut self) {
            self.out.fill(f32::NAN);
        }
        #[cfg(feature = "audit")]
        fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
            vec![crate::attn::audit::SlotClaim::of("out", &self.out)]
        }
    }

    fn items(n: usize) -> Vec<SqItem> {
        (0..n).map(|idx| SqItem { idx, out: vec![0.0; 4] }).collect()
    }

    fn square(it: &mut SqItem) -> Hbm {
        let mut h = Hbm::new();
        h.load(4);
        for (j, o) in it.out.iter_mut().enumerate() {
            *o = (it.idx * 4 + j) as f32 * 0.5;
        }
        h.store(4);
        h
    }

    fn run_collect(exec: &Exec, n: usize) -> (Vec<f32>, u64) {
        let mut hbm = Hbm::new();
        let (done, report) = exec
            .run(items(n), FaultSite::BatchedFwd, &mut hbm, square)
            .expect("fault-free run");
        assert_eq!(report.retries, 0);
        let mut flat = Vec::new();
        for it in &done {
            assert_eq!(it.idx, flat.len() / 4, "items must return in index order");
            flat.extend_from_slice(&it.out);
        }
        (flat, hbm.accesses())
    }

    #[test]
    fn persistent_matches_scoped_bitwise_for_every_worker_count() {
        let (base, base_acc) = run_collect(&Exec::scoped(1), 23);
        for w in [1, 2, 5, 16] {
            let (s, sa) = run_collect(&Exec::scoped(w), 23);
            let (p, pa) = run_collect(&Exec::new(w), 23);
            assert_eq!(s, base, "scoped w={w}");
            assert_eq!(p, base, "persistent w={w}");
            assert_eq!(sa, base_acc);
            assert_eq!(pa, base_acc, "persistent HBM total w={w}");
        }
    }

    #[test]
    fn one_exec_reused_across_many_runs_is_stable() {
        let exec = Exec::new(4);
        let (first, acc) = run_collect(&exec, 9);
        for _ in 0..50 {
            let (again, acc2) = run_collect(&exec, 9);
            assert_eq!(again, first);
            assert_eq!(acc2, acc);
        }
    }

    #[test]
    fn empty_run_is_a_no_op() {
        let mut hbm = Hbm::new();
        let (done, report) = Exec::new(3)
            .run(Vec::<SqItem>::new(), FaultSite::BatchedFwd, &mut hbm, square)
            .unwrap();
        assert!(done.is_empty());
        assert_eq!(report.retries, 0);
        assert_eq!(hbm.accesses(), 0);
    }

    #[test]
    fn injected_panic_retries_and_recovers_on_the_persistent_pool() {
        for exec in [Exec::new(3), Exec::scoped(3)] {
            let plan =
                FaultPlan::none().with(FaultSite::BatchedFwd, 2, 0, FaultKind::WorkerPanic);
            let exec = exec.with_plan(&plan).validated();
            let mut hbm = Hbm::new();
            let (done, report) =
                exec.run(items(7), FaultSite::BatchedFwd, &mut hbm, square).expect("recovers");
            assert_eq!(report.panics, 1);
            assert_eq!(report.retries, 1);
            assert_eq!(report.retry_hbm.accesses(), 8, "one attempt's traffic re-done");
            let (clean, clean_acc) = run_collect(&Exec::scoped(1), 7);
            let flat: Vec<f32> = done.iter().flat_map(|it| it.out.iter().copied()).collect();
            assert_eq!(flat, clean, "recovered output bitwise identical");
            // The faulted run performed one extra attempt's traffic.
            assert_eq!(hbm.accesses(), clean_acc + 8);
        }
    }

    #[test]
    fn exhausted_retries_surface_the_typed_error() {
        let mut plan = FaultPlan::none();
        for attempt in 0..MAX_ATTEMPTS {
            plan = plan.with(FaultSite::BatchedDq, 1, attempt, FaultKind::WorkerPanic);
        }
        let exec = Exec::new(2).with_plan(&plan);
        let mut hbm = Hbm::new();
        let err = exec.run(items(3), FaultSite::BatchedDq, &mut hbm, square).unwrap_err();
        match err {
            AttnError::ItemFailed { site, slice, attempts, .. } => {
                assert_eq!(site, FaultSite::BatchedDq);
                assert_eq!(slice, 1);
                assert_eq!(attempts, MAX_ATTEMPTS);
            }
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn workers_beyond_items_and_pool_cap_are_clamped() {
        let (flat, _) = run_collect(&Exec::new(10_000), 5);
        let (base, _) = run_collect(&Exec::scoped(1), 5);
        assert_eq!(flat, base);
    }
}
