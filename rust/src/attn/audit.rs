//! Deterministic race auditor for the work pool (`--features audit`).
//!
//! The execution plane's signature rule — *workers race for work items,
//! never for output slots* — is what makes every schedule bitwise
//! worker-count independent. This module turns that rule from a comment
//! into a checked property. Under the `audit` feature, the drain loop
//! behind every [`crate::attn::Exec`] run (`attn::exec::Exec::run`)
//! calls in here to enforce, for every pool run:
//!
//! * **(a) Slot disjointness** — each work item declares the output
//!   windows it owns ([`PoolItem::claims`]); no two items of one run may
//!   claim overlapping memory. Checked before any worker spawns; a
//!   violation panics with both items' provenance.
//! * **(b) Worker-count-invariant item→slot mapping** — each run can be
//!   recorded as an address-free [`PoolRun`] fingerprint (item index,
//!   `(slice, block)` id, and per-field window *lengths*). The mapping
//!   from items to slots is pure partition geometry, so the fingerprint
//!   must be identical no matter how many workers (or shards, for the
//!   ring schedule's row-block items) execute the run. Tests replay a
//!   workload across worker counts and assert recorded-run equality.
//! * **(c) Exactly-once commits** — the pool counts `Disposal::Commit`s
//!   per item; on a successful run every item must have committed exactly
//!   once (faulted attempts are retries, not commits). Checked at pool
//!   exit, panicking on violation.
//!
//! Everything here is compiled only under `--features audit`; the plain
//! build pays zero cost (the guardrail bench section is unchanged).
//!
//! Lengths, not addresses, make the fingerprint: window base addresses
//! differ between runs (fresh allocations), but a schedule that changed
//! its partition geometry with the worker count — e.g. the per-worker
//! `chunk = t_r.div_ceil(w)` windows the pool replaced — would change
//! the per-item window lengths or the item list itself, and the
//! fingerprints would diverge.

use std::sync::Mutex;

use super::exec::Exec;
use super::faults::FaultSite;
use crate::util::rng::SplitMix64;

/// One output window a work item claims: a field tag (`"o"`, `"lse"`,
/// `"dq"`, `"dk"`, `"dv"`), the window's base address, and its length in
/// elements. The address witnesses within-run disjointness; the (tag,
/// length) pair enters the cross-run fingerprint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SlotClaim {
    pub field: &'static str,
    pub addr: usize,
    pub len: usize,
}

impl SlotClaim {
    /// Claim over a window of `f32` output slots.
    pub fn of(field: &'static str, win: &[f32]) -> SlotClaim {
        SlotClaim { field, addr: win.as_ptr() as usize, len: win.len() }
    }

    fn end(&self) -> usize {
        self.addr + self.len * std::mem::size_of::<f32>()
    }
}

/// The claim manifest of one work item, as collected by the pool before
/// any worker spawns.
#[derive(Clone, Debug)]
pub struct ItemClaims {
    /// Queue index (the fault plan's item coordinate).
    pub idx: usize,
    /// `(slice, block)` provenance from [`PoolItem::id`].
    pub id: (usize, usize),
    pub claims: Vec<SlotClaim>,
}

/// Address-free fingerprint of one recorded pool run: the site plus, per
/// item, its index, id, and `(field, len)` shape of every claimed
/// window. Two runs of the same workload — any worker count, any shard
/// count on the ring schedule — must record equal `PoolRun`s.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PoolRun {
    /// Site of the pool invocation.
    pub site: FaultSite,
    pub items: Vec<(usize, (usize, usize), Vec<(&'static str, usize)>)>,
}

/// Check (a): no two items of one run claim overlapping slots. Returns
/// the offending pair's provenance on violation. Pure function so the
/// must-flag case is unit-testable without tripping the pool's panic.
pub fn check_disjoint(items: &[ItemClaims]) -> Result<(), String> {
    let mut spans: Vec<(usize, usize, usize, (usize, usize))> = Vec::new();
    for it in items {
        for c in &it.claims {
            if c.len > 0 {
                spans.push((c.addr, c.end(), it.idx, it.id));
            }
        }
    }
    spans.sort_unstable();
    for w in spans.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        if b.0 < a.1 {
            return Err(format!(
                "items {} (slice {}, block {}) and {} (slice {}, block {}) claim \
                 overlapping output slots",
                a.2, a.3 .0, a.3 .1, b.2, b.3 .0, b.3 .1
            ));
        }
    }
    Ok(())
}

/// Global recording registry. Recording is off by default so long
/// processes under `--features audit` (e.g. the full test binaries) do
/// not accumulate fingerprints they never read; the disjointness and
/// exactly-once checks always run regardless.
static RUNS: Mutex<Vec<PoolRun>> = Mutex::new(Vec::new());
static RECORDING: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

fn lock_runs() -> std::sync::MutexGuard<'static, Vec<PoolRun>> {
    RUNS.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Start recording pool-run fingerprints (clears any prior recording).
pub fn start_recording() {
    lock_runs().clear();
    RECORDING.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Stop recording and drain the fingerprints, in pool-invocation order.
pub fn stop_recording() -> Vec<PoolRun> {
    RECORDING.store(false, std::sync::atomic::Ordering::SeqCst);
    std::mem::take(&mut *lock_runs())
}

/// Pool hook: enforce (a) and, if recording, append this run's
/// fingerprint. Called by `Exec::run` with the manifest built in queue
/// order, before any drain starts (either pool mode, any worker count).
pub(crate) fn check_and_record(site: FaultSite, items: &[ItemClaims]) {
    if let Err(e) = check_disjoint(items) {
        panic!("audit[{site}]: {e}");
    }
    if RECORDING.load(std::sync::atomic::Ordering::SeqCst) {
        lock_runs().push(PoolRun {
            site,
            items: items
                .iter()
                .map(|it| {
                    (it.idx, it.id, it.claims.iter().map(|c| (c.field, c.len)).collect())
                })
                .collect(),
        });
    }
}

/// Pool hook for check (c): on a successful run, every item committed
/// exactly once.
pub(crate) fn check_commits(site: FaultSite, commits: &[u32]) {
    for (idx, &n) in commits.iter().enumerate() {
        assert!(n == 1, "audit[{site}]: item {idx} committed {n} times (expected exactly once)");
    }
}

// ---------------------------------------------------------------------
// Schedule-space explorer
// ---------------------------------------------------------------------
//
// The production pool always claims LIFO; a commit path that happens to
// be correct only *because* of that fixed order would pass every replay
// test above. The explorer closes that gap: it re-runs a pooled
// workload under many distinct claim orders (`Exec::with_drain_order`)
// and worker counts and asserts the outputs are bitwise identical and
// the recorded fingerprints equal, fault-free and under `FaultPlan`
// injection (a retried item re-enters the claim competition, so retry
// requeue interleavings are explored too). Worker park/wake boundaries
// are covered by driving the same orders through the persistent pool
// (parked helpers) and the per-call scope mode.

/// All `n!` rank tables over `n` items, in lexicographic order. Callers
/// keep `n` small: `4! = 24` is the standard per-site budget.
pub fn permutations(n: usize) -> Vec<Vec<usize>> {
    fn rec(prefix: &mut Vec<usize>, rest: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if rest.is_empty() {
            out.push(prefix.clone());
            return;
        }
        for i in 0..rest.len() {
            let x = rest.remove(i);
            prefix.push(x);
            rec(prefix, rest, out);
            prefix.pop();
            rest.insert(i, x);
        }
    }
    let mut out = Vec::new();
    let mut rest: Vec<usize> = (0..n).collect();
    rec(&mut Vec::new(), &mut rest, &mut out);
    out
}

/// `count` seeded adversarial rank tables over `n` items: deterministic
/// Fisher–Yates shuffles. Pools too large to permute exhaustively get a
/// reproducible sample of the schedule space instead.
pub fn adversarial_orders(n: usize, count: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = SplitMix64::new(seed);
    (0..count)
        .map(|_| {
            let mut ranks: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut ranks);
            ranks
        })
        .collect()
}

/// Explore one pooled workload's schedule space. `work` runs the
/// workload under the handle it is given and returns its output in a
/// bitwise-comparable form; `base` carries everything but worker count
/// and claim order (pool mode, fault plan, guardrail). The baseline is
/// `base` at one worker under the production LIFO claim; every
/// `orders × workers` candidate must reproduce its output bit for bit
/// and record equal [`PoolRun`] fingerprints.
///
/// A rank table steers every pool the workload drives (ranks index by
/// item idx; items past the table rank as themselves), so one call
/// explores all of a workload's sites at once. Recording drains the
/// process-global registry — callers hold their recording gate.
pub fn explore_schedules<O, F>(
    label: &str,
    base: &Exec,
    orders: &[Vec<usize>],
    workers: &[usize],
    work: F,
) where
    O: PartialEq + std::fmt::Debug,
    F: Fn(&Exec) -> O,
{
    start_recording();
    let base_out = work(&base.clone().with_workers(1));
    let base_runs = stop_recording();
    assert!(!base_runs.is_empty(), "explore[{label}]: workload drove no pool run");
    for (oi, ranks) in orders.iter().enumerate() {
        for &w in workers {
            let exec = base.clone().with_workers(w).with_drain_order(ranks.clone());
            start_recording();
            let out = work(&exec);
            let runs = stop_recording();
            assert_eq!(
                out, base_out,
                "explore[{label}]: output diverged under order #{oi} {ranks:?}, w={w}"
            );
            assert_eq!(
                runs, base_runs,
                "explore[{label}]: fingerprints diverged under order #{oi} {ranks:?}, w={w}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sentinel field tag no kernel ever claims: lets the recording test
    // filter out pool runs from other tests sharing this binary.
    const TEST_FIELD: &str = "audit-test";

    fn item(idx: usize, addr: usize, len: usize) -> ItemClaims {
        ItemClaims { idx, id: (idx, 0), claims: vec![SlotClaim { field: TEST_FIELD, addr, len }] }
    }

    #[test]
    fn disjoint_claims_pass() {
        // Adjacent windows (end == next start) are disjoint.
        assert!(check_disjoint(&[item(0, 0, 4), item(1, 16, 4), item(2, 32, 0)]).is_ok());
    }

    #[test]
    fn overlapping_claims_flagged_with_provenance() {
        let err = check_disjoint(&[item(0, 0, 4), item(1, 12, 4)]).unwrap_err();
        assert!(err.contains("items 0"), "{err}");
        assert!(err.contains("and 1"), "{err}");
    }

    #[test]
    fn zero_length_claims_never_overlap() {
        // Empty windows share addresses freely (split_windows on an
        // empty tail yields zero-length slices at the same pointer).
        assert!(check_disjoint(&[item(0, 8, 0), item(1, 8, 0), item(2, 8, 1)]).is_ok());
    }

    /// Keep only this test's own runs: other tests in the binary may
    /// drive real pools while recording is on, appending fingerprints
    /// with kernel field tags ("o", "lse", "dq", …) — never the sentinel.
    fn own(runs: Vec<PoolRun>) -> Vec<PoolRun> {
        runs.into_iter()
            .filter(|r| r.items.iter().all(|(_, _, c)| c.iter().all(|&(f, _)| f == TEST_FIELD)))
            .collect()
    }

    #[test]
    fn recording_round_trips_in_invocation_order() {
        start_recording();
        check_and_record(FaultSite::BatchedFwd, &[item(0, 0, 4)]);
        check_and_record(FaultSite::BatchedDq, &[item(0, 64, 2)]);
        let runs = own(stop_recording());
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].site, FaultSite::BatchedFwd);
        assert_eq!(runs[0].items, vec![(0usize, (0usize, 0usize), vec![(TEST_FIELD, 4usize)])]);
        // Address-free: a second recording at different addresses is equal.
        start_recording();
        check_and_record(FaultSite::BatchedFwd, &[item(0, 4096, 4)]);
        check_and_record(FaultSite::BatchedDq, &[item(0, 8192, 2)]);
        assert_eq!(own(stop_recording()), runs);
    }

    #[test]
    fn permutations_enumerate_the_full_factorial() {
        let p = permutations(4);
        assert_eq!(p.len(), 24);
        let unique: std::collections::BTreeSet<_> = p.iter().cloned().collect();
        assert_eq!(unique.len(), 24, "all 4! orders distinct");
        for ranks in &p {
            let mut sorted = ranks.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "each order is a permutation");
        }
        assert_eq!(permutations(0), vec![Vec::<usize>::new()]);
    }

    #[test]
    fn adversarial_orders_are_seed_deterministic_permutations() {
        let a = adversarial_orders(9, 8, 0xC0FFEE);
        assert_eq!(a, adversarial_orders(9, 8, 0xC0FFEE), "same seed, same orders");
        assert_eq!(a.len(), 8);
        for ranks in &a {
            let mut s = ranks.clone();
            s.sort_unstable();
            assert_eq!(s, (0..9).collect::<Vec<_>>(), "each order is a permutation");
        }
        assert_ne!(a, adversarial_orders(9, 8, 0xBEEF), "seed steers the sample");
    }
}
