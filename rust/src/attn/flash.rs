//! Algorithms 1/2 (FlashAttention forward) and Algorithm 4 (backward) as
//! faithful tiled Rust implementations with explicit "SRAM" tile buffers and
//! HBM accounting at exactly the lines the paper's pseudo-code moves data.
//!
//! Loop order matches the paper exactly: outer loop over K/V blocks j,
//! inner loop over Q blocks i, with O/l/m read-modified-written to HBM every
//! inner iteration (Algorithm 1 lines 12-13) — that is what produces the
//! Θ(N²d²/M) access count of Theorem 2. This is the *faithful instrumented
//! reference* of the two-kernel policy (see the `attn` module docs); the
//! fast Q-outer production kernel lives in `attn::flash2`. The only
//! concession to speed here is `tile_fully_unmasked`: tiles that provably
//! contain no masked entry skip the per-element mask pass, which changes
//! neither numerics nor HBM accounting.

use super::masks::{dropout_scale, masked_score, NEG_INF};
use super::{AttnConfig, AttnGrads, AttnOutput, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::Tensor;

/// True iff the tile rows×cols [r0, r1) × [c0, c1) cannot contain a masked
/// entry: entirely at-or-below the causal diagonal (every col ≤ every row,
/// i.e. c1 - 1 ≤ r0) and inside the valid key length. Tiles above the
/// diagonal are skipped outright; this is the complement — fully *live*
/// tiles skip the per-element `masked_score` pass. `c1` and `kv_len` are
/// **global** key coordinates (callers pass `cfg.kv_offset + local_c1`
/// and `cfg.kv_limit(n_k)`), so shard slices take exactly the fast path
/// the unsharded kernel takes.
#[inline]
pub(crate) fn tile_fully_unmasked(causal: bool, r0: usize, c1: usize, kv_len: usize) -> bool {
    (!causal || c1 <= r0 + 1) && c1 <= kv_len
}

/// Tile geometry per Algorithm 1 line 1: B_c = ceil(M/4d), B_r = min(B_c, d).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocks {
    pub b_r: usize,
    pub b_c: usize,
}

impl Blocks {
    pub fn from_sram(m_floats: usize, d: usize, n: usize) -> Blocks {
        let b_c = m_floats.div_ceil(4 * d).max(1).min(n);
        let b_r = b_c.min(d).min(n);
        Blocks { b_r, b_c }
    }

    pub fn explicit(b_r: usize, b_c: usize) -> Blocks {
        Blocks { b_r, b_c }
    }

    /// Backward-specific tile policy (ROADMAP item): the fast two-phase
    /// backward (`attn::flash2::flash2_backward`) streams K/V once per
    /// *row* block in phase 1 and Q/dO once per *column* block in phase 2
    /// — per live tile pair that is 2·B_c·d + 2·B_r·d elements against
    /// Algorithm 4's 5·B_r·d, so the fast kernel wins exactly when
    /// 3·B_r > 2·B_c (see `sim::cost::flash2_bwd`). The paper's forward
    /// rule `B_r = min(B_c, d)` picks wide flat tiles that violate the
    /// inequality as soon as B_c > 3d/2; for the backward pair both
    /// kernels instead take the largest *square* tile B_r = B_c = B —
    /// square satisfies the inequality by construction — whose working
    /// set fits in M floats: K_j, V_j, Q_i, dO_i and the on-chip dQ or
    /// dK~/dV~ accumulators (≤ 6·B·d) plus the S and dP tiles (2·B²).
    pub fn for_backward(m_floats: usize, d: usize) -> Blocks {
        let fits = |b: usize| 6 * b * d + 2 * b * b <= m_floats;
        let mut b = 1usize;
        while fits(b + 1) {
            b += 1;
        }
        Blocks { b_r: b, b_c: b }
    }

    /// SRAM floats consumed by one iteration's tiles:
    /// K_j, V_j (B_c x d each), Q_i, O_i (B_r x d each), S_ij (B_r x B_c).
    pub fn sram_floats(&self, d: usize) -> usize {
        2 * self.b_c * d + 2 * self.b_r * d + self.b_r * self.b_c
    }
}

/// Algorithm 1/2: tiled exact forward. q,k,v: [n, d].
pub fn flash_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    hbm: &mut Hbm,
) -> AttnOutput {
    // Rectangular in general: n query rows attend n_k key rows (n_k < n in
    // the sequence-parallel sharded path, attn::distributed).
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let t_c = n_k.div_ceil(b_c);

    // Line 2: initialise O = 0, l = 0, m = -inf in HBM.
    let mut o = Tensor::zeros(&[n, d]);
    let mut l = vec![0.0f32; n];
    let mut m = vec![f32::NEG_INFINITY; n];
    hbm.store(n * d + 2 * n);
    // On-chip scratch, allocated once (perf: no allocation in the tile loop).
    let mut p_buf = vec![0.0f32; b_c];
    let mut pv = vec![0.0f32; d];

    for j in 0..t_c {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        // Line 6: load K_j, V_j from HBM to SRAM.
        hbm.load(2 * (c1 - c0) * d);
        let kj = k.slice_rows(c0, c1);
        let vj = v.slice_rows(c0, c1);

        for i in 0..t_r {
            let r0 = i * b_r;
            let r1 = ((i + 1) * b_r).min(n);
            // Causal block skip: whole tile above the diagonal, in
            // global key coordinates.
            if cfg.causal && cfg.kv_offset + c0 > r1 - 1 {
                continue;
            }
            // Line 8: load Q_i, O_i, l_i, m_i.
            hbm.load((r1 - r0) * d * 2 + 2 * (r1 - r0));
            let qi = q.slice_rows(r0, r1);

            // Line 9: S_ij = tau Q_i K_j^T (on chip).
            let mut s = qi.matmul_bt(&kj).scale(tau);
            if !tile_fully_unmasked(cfg.causal, r0, cfg.kv_offset + c1, kv_limit) {
                for (rr, row) in (r0..r1).enumerate() {
                    for (cc, col) in (c0..c1).enumerate() {
                        let x = s.data[rr * (c1 - c0) + cc];
                        s.data[rr * (c1 - c0) + cc] =
                            masked_score(x, row, cfg.kv_offset + col, cfg.causal, kv_limit);
                    }
                }
            }

            // Lines 10-12: online softmax update.
            let bc = c1 - c0;
            for (rr, row) in (r0..r1).enumerate() {
                let srow = &s.data[rr * bc..(rr + 1) * bc];
                let m_tile = srow.iter().cloned().fold(NEG_INF, f32::max);
                let p = &mut p_buf[..bc];
                let mut l_tile = 0.0f32;
                for (pw, &x) in p.iter_mut().zip(srow) {
                    *pw = (x - m_tile).exp();
                    l_tile += *pw;
                }

                let m_new = m[row].max(m_tile);
                let alpha = (m[row] - m_new).exp();
                let beta = (m_tile - m_new).exp();
                let l_new = alpha * l[row] + beta * l_tile;

                if cfg.dropout_p > 0.0 {
                    for (cc, pw) in p.iter_mut().enumerate() {
                        *pw *= dropout_scale(
                            cfg.bh_index,
                            row,
                            cfg.kv_offset + c0 + cc,
                            n,
                            cfg.dropout_seed,
                            cfg.dropout_p,
                        );
                    }
                }

                // Line 12: O_i <- diag(l_new)^-1 (l_i e^{m-m_new} O_i + e^{mt-m_new} P~ V_j).
                // P~ V_j accumulated row-of-V-major: contiguous, vectorisable
                // (perf pass: was column-major with stride-d access).
                pv[..d].fill(0.0);
                for (cc, &pw) in p.iter().enumerate() {
                    if pw != 0.0 {
                        let vrow = &vj.data[cc * d..(cc + 1) * d];
                        for c in 0..d {
                            pv[c] += pw * vrow[c];
                        }
                    }
                }
                let inv = 1.0 / l_new.max(1e-37);
                let a_coef = l[row] * alpha * inv;
                let b_coef = beta * inv;
                let orow = o.row_mut(row);
                for c in 0..d {
                    orow[c] = a_coef * orow[c] + b_coef * pv[c];
                }
                l[row] = l_new;
                m[row] = m_new;
            }
            // Lines 12-13: write O_i, l_i, m_i back to HBM.
            hbm.store((r1 - r0) * d + 2 * (r1 - r0));
        }
    }

    AttnOutput { o, l, m }
}

/// Algorithm 4: tiled backward with on-chip recomputation of P_ij.
///
/// `stats` accepts either saved-statistics representation (see
/// [`AttnStats`]): the paper's (l, m) pair from [`flash_forward`] or the
/// single logsumexp from [`super::flash2::flash2_forward`] — the
/// recomputation only ever needs `P_ij = exp(s_ij - L_i)`.
///
/// Shapes may be rectangular, matching the forwards: q, o, dout: [n, d];
/// k, v: [n_k, d] (the sequence-parallel sharded layout). The key-side
/// tiling, padding mask and dK/dV shapes all follow n_k, not n.
#[allow(clippy::too_many_arguments)]
pub fn flash_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: AttnStats<'_>,
    cfg: &AttnConfig,
    blocks: Blocks,
    hbm: &mut Hbm,
) -> AttnGrads {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    assert_eq!(k.cols(), d, "flash_backward: K feature dim mismatch");
    assert_eq!((v.rows(), v.cols()), (n_k, d), "flash_backward: V shape mismatch");
    assert_eq!((dout.rows(), dout.cols()), (n, d), "flash_backward: dO shape mismatch");
    assert_eq!(stats.len(), n, "flash_backward: stats length mismatch");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let t_c = n_k.div_ceil(b_c);

    // Line 5: initialise dQ, dK, dV = 0 in HBM.
    let mut dq = Tensor::zeros(&[n, d]);
    let mut dk = Tensor::zeros(&[n_k, d]);
    let mut dv = Tensor::zeros(&[n_k, d]);
    hbm.store(n * d + 2 * n_k * d);

    for j in 0..t_c {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        let bc = c1 - c0;
        // Line 7: load K_j, V_j.
        hbm.load(2 * bc * d);
        let kj = k.slice_rows(c0, c1);
        let vj = v.slice_rows(c0, c1);
        // Line 8: dK~_j, dV~_j = 0 on SRAM.
        let mut dkj = Tensor::zeros(&[bc, d]);
        let mut dvj = Tensor::zeros(&[bc, d]);

        for i in 0..t_r {
            let r0 = i * b_r;
            let r1 = ((i + 1) * b_r).min(n);
            let br = r1 - r0;
            if cfg.causal && cfg.kv_offset + c0 > r1 - 1 {
                continue;
            }
            // Line 10: load Q_i, O_i, dO_i, dQ_i, l_i, m_i.
            hbm.load(4 * br * d + 2 * br);
            let qi = q.slice_rows(r0, r1);

            // Lines 11-13: recompute S_ij, P_ij on chip.
            let mut s = qi.matmul_bt(&kj).scale(tau);
            if !tile_fully_unmasked(cfg.causal, r0, cfg.kv_offset + c1, kv_limit) {
                for rr in 0..br {
                    for cc in 0..bc {
                        let x = s.data[rr * bc + cc];
                        s.data[rr * bc + cc] =
                            masked_score(x, r0 + rr, cfg.kv_offset + c0 + cc, cfg.causal, kv_limit);
                    }
                }
            }
            let mut p = Tensor::zeros(&[br, bc]);
            for rr in 0..br {
                let lse = stats.lse(r0 + rr);
                // lse = -inf marks a fully-masked forward row (zero mass);
                // exp(s - -inf) would overflow to +inf, so leave P at 0.
                if lse == f32::NEG_INFINITY {
                    continue;
                }
                for cc in 0..bc {
                    p.data[rr * bc + cc] = (s.data[rr * bc + cc] - lse).exp();
                }
            }

            // Lines 14-15: regenerate dropout mask, P^dropped = P o Z.
            let mut p_dropped = p.clone();
            if cfg.dropout_p > 0.0 {
                for rr in 0..br {
                    for cc in 0..bc {
                        p_dropped.data[rr * bc + cc] *= dropout_scale(
                            cfg.bh_index,
                            r0 + rr,
                            cfg.kv_offset + c0 + cc,
                            n,
                            cfg.dropout_seed,
                            cfg.dropout_p,
                        );
                    }
                }
            }

            // Line 16: dV~_j += (P^dropped)^T dO_i.
            for rr in 0..br {
                let dorow = dout.row(r0 + rr);
                for cc in 0..bc {
                    let pw = p_dropped.data[rr * bc + cc];
                    if pw != 0.0 {
                        let dvrow = &mut dvj.data[cc * d..(cc + 1) * d];
                        for c in 0..d {
                            dvrow[c] += pw * dorow[c];
                        }
                    }
                }
            }

            // Lines 17-20: dP, D_i, dS.
            let mut ds = Tensor::zeros(&[br, bc]);
            for rr in 0..br {
                let row = r0 + rr;
                let dorow = dout.row(row);
                let orow = o.row(row);
                // Line 19: D_i = rowsum(dO o O).
                let mut di = 0.0f32;
                for c in 0..d {
                    di += dorow[c] * orow[c];
                }
                for cc in 0..bc {
                    // Line 17: dP^dropped = dO V^T ; line 18: dP = dP^dropped o Z.
                    let vrow = &vj.data[cc * d..(cc + 1) * d];
                    let mut dp = 0.0f32;
                    for c in 0..d {
                        dp += dorow[c] * vrow[c];
                    }
                    if cfg.dropout_p > 0.0 {
                        dp *= dropout_scale(
                            cfg.bh_index,
                            row,
                            cfg.kv_offset + c0 + cc,
                            n,
                            cfg.dropout_seed,
                            cfg.dropout_p,
                        );
                    }
                    // Line 20: dS = P o (dP - D_i).
                    ds.data[rr * bc + cc] = p.data[rr * bc + cc] * (dp - di);
                }
            }

            // Line 21: dQ_i += tau dS K_j (written to HBM).
            for rr in 0..br {
                let dqrow = dq.row_mut(r0 + rr);
                for cc in 0..bc {
                    let w = tau * ds.data[rr * bc + cc];
                    if w != 0.0 {
                        let krow = &kj.data[cc * d..(cc + 1) * d];
                        for c in 0..d {
                            dqrow[c] += w * krow[c];
                        }
                    }
                }
            }
            hbm.store(br * d); // dQ_i writeback

            // Line 22: dK~_j += tau dS^T Q_i.
            for rr in 0..br {
                let qrow = qi.row(rr);
                for cc in 0..bc {
                    let w = tau * ds.data[rr * bc + cc];
                    if w != 0.0 {
                        let dkrow = &mut dkj.data[cc * d..(cc + 1) * d];
                        for c in 0..d {
                            dkrow[c] += w * qrow[c];
                        }
                    }
                }
            }
        }

        // Line 24: write dK_j, dV_j to HBM.
        dk.data[c0 * d..c1 * d].copy_from_slice(&dkj.data);
        dv.data[c0 * d..c1 * d].copy_from_slice(&dvj.data);
        hbm.store(2 * bc * d);
    }

    AttnGrads { dq, dk, dv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::standard::{standard_backward, standard_forward};
    use crate::util::prop::{assert_allclose, for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn blocks_paper_formula() {
        let b = Blocks::from_sram(48 * 1024, 64, 4096);
        assert_eq!(b.b_c, 192);
        assert_eq!(b.b_r, 64);
    }

    #[test]
    fn matches_standard_forward() {
        let (q, k, v) = qkv(48, 8, 0);
        let std = standard_forward(&q, &k, &v, &AttnConfig::default(), &mut Hbm::new());
        let fla = flash_forward(
            &q, &k, &v, &AttnConfig::default(), Blocks::explicit(8, 16), &mut Hbm::new(),
        );
        assert!(std.o.max_abs_diff(&fla.o) < 1e-5);
        assert_allclose(&std.l, &fla.l, 1e-4, 1e-4, "l");
        assert_allclose(&std.m, &fla.m, 1e-6, 0.0, "m");
    }

    #[test]
    fn matches_standard_causal_and_padding() {
        let (q, k, v) = qkv(40, 8, 1);
        let cfg = AttnConfig { causal: true, kv_len: Some(23), ..Default::default() };
        let std = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        let fla = flash_forward(&q, &k, &v, &cfg, Blocks::explicit(8, 8), &mut Hbm::new());
        assert!(std.o.max_abs_diff(&fla.o) < 1e-5);
    }

    #[test]
    fn dropout_matches_standard() {
        let (q, k, v) = qkv(32, 8, 2);
        let cfg =
            AttnConfig { dropout_p: 0.25, dropout_seed: 9, bh_index: 3, ..Default::default() };
        let std = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
        let fla = flash_forward(&q, &k, &v, &cfg, Blocks::explicit(8, 8), &mut Hbm::new());
        assert!(std.o.max_abs_diff(&fla.o) < 1e-5);
    }

    #[test]
    fn block_size_invariance() {
        let (q, k, v) = qkv(64, 16, 3);
        let cfg = AttnConfig::default();
        let base = flash_forward(&q, &k, &v, &cfg, Blocks::explicit(64, 64), &mut Hbm::new());
        for (br, bc) in [(8, 8), (16, 32), (8, 64), (64, 8)] {
            let f = flash_forward(&q, &k, &v, &cfg, Blocks::explicit(br, bc), &mut Hbm::new());
            assert!(base.o.max_abs_diff(&f.o) < 1e-5, "blocks ({br},{bc})");
        }
    }

    #[test]
    fn backward_matches_standard() {
        let (q, k, v) = qkv(32, 8, 4);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(8, 8);
        let fwd = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
        let mut rng = SplitMix64::new(9);
        let dout = Tensor::randn(&[32, 8], &mut rng, 1.0);
        let fg =
            flash_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new());
        let sg = standard_backward(&q, &k, &v, &dout, &cfg, &mut Hbm::new());
        assert!(fg.dq.max_abs_diff(&sg.dq) < 1e-4);
        assert!(fg.dk.max_abs_diff(&sg.dk) < 1e-4);
        assert!(fg.dv.max_abs_diff(&sg.dv) < 1e-4);
    }

    #[test]
    fn backward_dropout_matches_standard() {
        let (q, k, v) = qkv(24, 8, 5);
        let cfg = AttnConfig { dropout_p: 0.2, dropout_seed: 5, ..Default::default() };
        let blocks = Blocks::explicit(8, 8);
        let fwd = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
        let mut rng = SplitMix64::new(10);
        let dout = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let fg =
            flash_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new());
        let sg = standard_backward(&q, &k, &v, &dout, &cfg, &mut Hbm::new());
        assert!(fg.dq.max_abs_diff(&sg.dq) < 1e-4);
        assert!(fg.dk.max_abs_diff(&sg.dk) < 1e-4);
        assert!(fg.dv.max_abs_diff(&sg.dv) < 1e-4);
    }

    #[test]
    fn property_random_shapes_match_standard() {
        for_each_case("flash_vs_standard", 15, |rng| {
            let n = usize_in(rng, 2, 48);
            let d = *crate::util::prop::choose(rng, &[2usize, 4, 8]);
            let b_r = usize_in(rng, 1, n);
            let b_c = usize_in(rng, 1, n);
            let causal = rng.next_f32() < 0.5;
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let cfg = AttnConfig { causal, ..Default::default() };
            let std = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            let fla = flash_forward(&q, &k, &v, &cfg, Blocks::explicit(b_r, b_c), &mut Hbm::new());
            assert!(
                std.o.max_abs_diff(&fla.o) < 1e-4,
                "n={n} d={d} blocks=({b_r},{b_c}) causal={causal}"
            );
        });
    }

    #[test]
    fn io_flash_less_than_standard_at_scale() {
        // The paper's headline: fewer HBM accesses once N >> M/d.
        let (q, k, v) = qkv(256, 16, 6);
        let mut h_std = Hbm::new();
        standard_forward(&q, &k, &v, &AttnConfig::default(), &mut h_std);
        let mut h_fla = Hbm::new();
        let blocks = Blocks::from_sram(4096, 16, 256);
        flash_forward(&q, &k, &v, &AttnConfig::default(), blocks, &mut h_fla);
        assert!(
            h_fla.accesses() < h_std.accesses(),
            "flash {} vs std {}",
            h_fla.accesses(),
            h_std.accesses()
        );
    }
}
