//! Block-sparse FlashAttention (§3.3) — both halves of the two-pair
//! policy for the sparse workload:
//!
//! * [`block_sparse_forward`] — Algorithm 5, the *faithful instrumented
//!   reference*: the dense tiled loop (K/V-outer, accumulators
//!   round-tripped to HBM) with zero blocks skipped. IO complexity
//!   Θ(Nd + N²d²s/M) (Proposition 4). Local key coordinates only
//!   (`kv_offset == 0` asserted): this mirror stays line-for-line with
//!   the paper's pseudo-code.
//! * [`block_sparse2_forward`] / [`block_sparse2_backward`] — the *fast
//!   production pair*: the FlashAttention-2-style Q-outer sweeps of
//!   `attn::flash2` with one extra skip, the `BlockMask` zero-block
//!   filter. The filter is the ONLY difference from the dense pair —
//!   live tiles run the dense per-tile arithmetic bit for bit, so a
//!   dense mask makes both kernels **bitwise identical** to
//!   `flash2_forward`/`flash2_backward` for any worker count (asserted
//!   below). Mask columns are interpreted in **global** key
//!   coordinates: column tile `t` of the mask covers global keys
//!   [t·B_c, (t+1)·B_c), and a key shard at `cfg.kv_offset` (which
//!   must be tile-aligned, as the sharded driver's shards are) reads
//!   tile `kv_offset/B_c + local_tile` — so the sequence-parallel path
//!   can hand every shard the same global mask and each shard skips
//!   exactly the blocks the unsharded kernel skips.
//!
//! The fast pair's HBM accounting mirrors the dense pair's: Q (and in
//! the backward Q/dO/D/L) load once per row block, outputs store
//! exactly once, and only *live* tiles stream K/V (forward + dQ phase)
//! or Q/dO (dK/dV phase) — the closed forms in
//! `sim::cost::block_sparse2_fwd`/`block_sparse2_bwd` are asserted
//! access-for-access in `rust/tests/io_complexity.rs`, and traffic is
//! strictly decreasing in the number of live blocks (Proposition 4).

use std::sync::Arc;

use super::batched::{block_rows, DkvItem, DqItem, FwdItem};
use super::exec::Exec;
use super::faults::FaultSite;
use super::flash::{tile_fully_unmasked, Blocks};
use super::flash2::{
    dkv_col_sweep_filtered, stream_kv_dq_filtered, stream_kv_filtered, write_epilogue,
    Flash2Output, RowBlockState,
};
use super::masks::{masked_score, BlockMask, NEG_INF};
use super::{AttnConfig, AttnGrads, AttnOutput, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::{dot4, Tensor};

/// Global column-tile index of a slice's local tile 0. The mask is
/// indexed in global tiles, so a key shard must start on a column-tile
/// boundary — the sharded driver's shards are tile-aligned by
/// construction, and anything else would put the mask's blocks on the
/// wrong global columns.
pub(crate) fn mask_tile_base(kv_offset: usize, b_c: usize) -> usize {
    assert_eq!(
        kv_offset % b_c,
        0,
        "block_sparse2: kv_offset ({kv_offset}) must align to whole column tiles (b_c = {b_c})"
    );
    kv_offset / b_c
}

/// The mask must have exactly `t_r` row tiles and cover this slice's
/// global column span. A key shard sees a *window* of the global mask,
/// so the mask may extend past `tile_base + t_c` (later shards own
/// those tiles); with `kv_offset = 0` and a mask built for this K/V
/// this reduces to the exact-geometry check.
pub(crate) fn check_mask_geometry(mask: &BlockMask, t_r: usize, tile_base: usize, t_c: usize) {
    assert_eq!(
        mask.t_r, t_r,
        "mask geometry mismatch: {} row tiles for t_r = {t_r}",
        mask.t_r
    );
    assert!(
        mask.t_c >= tile_base + t_c,
        "mask geometry mismatch: {} column tiles < tile base {tile_base} + t_c {t_c}",
        mask.t_c
    );
}

/// Algorithm 5 forward — the faithful instrumented reference. `mask`
/// has shape [ceil(n/b_r), ceil(n_k/b_c)]; K/V may be rectangular
/// (n_k ≠ n), e.g. cross-attention shapes.
pub fn block_sparse_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    hbm: &mut Hbm,
) -> AttnOutput {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    // The reference mirror stays in local key coordinates, line for line
    // with the paper's pseudo-code. Key shards go through the fast pair
    // (`block_sparse2_forward`), whose mask columns are global.
    assert_eq!(cfg.kv_offset, 0, "block_sparse_forward: key shards go through block_sparse2");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let t_c = n_k.div_ceil(b_c);
    assert_eq!((mask.t_r, mask.t_c), (t_r, t_c), "mask geometry mismatch");

    let mut o = Tensor::zeros(&[n, d]);
    let mut l = vec![0.0f32; n];
    let mut m = vec![f32::NEG_INFINITY; n];
    hbm.store(n * d + 2 * n);
    // On-chip scratch, allocated once (perf: no allocation in the tile loop,
    // matching the flash mirror's earlier perf pass).
    let mut p_buf = vec![0.0f32; b_c];
    let mut pv = vec![0.0f32; d];

    for j in 0..t_c {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        // Skip loading K_j/V_j entirely if column-block j is all-zero.
        if (0..t_r).all(|i| !mask.get(i, j)) {
            continue;
        }
        hbm.load(2 * (c1 - c0) * d);
        let kj = k.slice_rows(c0, c1);
        let vj = v.slice_rows(c0, c1);

        for i in 0..t_r {
            if !mask.get(i, j) {
                continue; // Algorithm 5 line 8
            }
            let r0 = i * b_r;
            let r1 = ((i + 1) * b_r).min(n);
            if cfg.causal && c0 > r1 - 1 {
                continue;
            }
            hbm.load((r1 - r0) * d * 2 + 2 * (r1 - r0));
            let qi = q.slice_rows(r0, r1);
            let bc = c1 - c0;
            let mut s = qi.matmul_bt(&kj).scale(tau);
            // Causal fast path: tiles that provably contain no masked entry
            // skip the per-element pass (same rule as the flash kernels;
            // local == global here, kv_offset is asserted 0 above).
            if !tile_fully_unmasked(cfg.causal, r0, c1, kv_limit) {
                for (rr, row) in (r0..r1).enumerate() {
                    for (cc, col) in (c0..c1).enumerate() {
                        let x = s.data[rr * bc + cc];
                        s.data[rr * bc + cc] = masked_score(x, row, col, cfg.causal, kv_limit);
                    }
                }
            }
            for (rr, row) in (r0..r1).enumerate() {
                let srow = &s.data[rr * bc..(rr + 1) * bc];
                let m_tile = srow.iter().cloned().fold(NEG_INF, f32::max);
                let p = &mut p_buf[..bc];
                let mut l_tile = 0.0f32;
                for (pw, &x) in p.iter_mut().zip(srow) {
                    *pw = (x - m_tile).exp();
                    l_tile += *pw;
                }
                let m_new = m[row].max(m_tile);
                let alpha = (m[row] - m_new).exp();
                let beta = (m_tile - m_new).exp();
                let l_new = alpha * l[row] + beta * l_tile;
                // P̃·V accumulated row-of-V-major: contiguous and
                // vectorisable, with the same per-column summation order as
                // the old stride-d loop. The O update below now uses the
                // flash kernel's inv-premultiplied form (one divide per
                // row) — same numerics to rounding, not bitwise.
                pv[..d].fill(0.0);
                for (cc, &pw) in p.iter().enumerate() {
                    let vrow = &vj.data[cc * d..(cc + 1) * d];
                    for c in 0..d {
                        pv[c] += pw * vrow[c];
                    }
                }
                let inv = 1.0 / l_new.max(1e-37);
                let a_coef = l[row] * alpha * inv;
                let b_coef = beta * inv;
                let orow = o.row_mut(row);
                for c in 0..d {
                    orow[c] = a_coef * orow[c] + b_coef * pv[c];
                }
                l[row] = l_new;
                m[row] = m_new;
            }
            hbm.store((r1 - r0) * d + 2 * (r1 - r0));
        }
    }

    // Rows never visited by any nonzero block keep O = 0 (kernel semantics).
    AttnOutput { o, l, m }
}

/// Fast block-sparse forward: the Q-outer production kernel
/// (`attn::flash2::flash2_forward`) with the `BlockMask` zero-block
/// skip fused into the K/V stream. q: [n, d]; k, v: [n_k, d]
/// (rectangular K/V and key shards both supported — `cfg.kv_offset`
/// shifts the slice's mask window, see the module docs). Per row block,
/// Q loads once and the accumulators live on chip for the whole sweep;
/// only live column tiles load K/V; O and the logsumexp store exactly
/// once. Work runs on `exec` (persistent pool or per-call scope, with
/// `exec`'s fault plan and guardrail honored); the result is bitwise
/// independent of the worker count and pool mode, and with a dense mask
/// bitwise identical to `flash2_forward`. Per the per-slice kernel
/// contract a work item that exhausts its retry budget panics with the
/// typed error — callers needing `Result` use the batched entry points.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse2_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Flash2Output {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let b_r = blocks.b_r;
    let t_r = n.div_ceil(b_r);

    let mut o = Tensor::zeros(&[n, d]);
    let mut lse = vec![0.0f32; n];
    if t_r == 0 || n_k == 0 {
        // No keys at all: the defined all-masked semantics (zero rows,
        // lse = -inf), exactly as the dense fast kernel.
        lse.fill(f32::NEG_INFINITY);
        return Flash2Output { o, lse };
    }
    let tile_base = mask_tile_base(cfg.kv_offset, blocks.b_c);
    check_mask_geometry(mask, t_r, tile_base, n_k.div_ceil(blocks.b_c));

    // One work item per Q row block through the execution plane
    // (invariant R1): each item owns its O/lse windows outright and the
    // per-block arithmetic is self-contained, so output and traffic are
    // bitwise identical to the per-worker chunk partition this replaces
    // — for any worker count and pool mode — and the audit feature
    // covers the partition.
    let items: Vec<FwdItem> = (0..t_r)
        .map(|rb| {
            let rows = block_rows(rb, b_r, n);
            FwdItem { s: 0, rb, o_win: vec![0.0; rows * d], lse_win: vec![0.0; rows] }
        })
        .collect();
    let (qd, kd, vd) = (q.data.clone(), k.data.clone(), v.data.clone());
    let (mask_o, cfg_o) = (mask.clone(), cfg.clone());
    let (done, _report) = exec
        .run(items, FaultSite::SparseFwd, hbm, move |it: &mut FwdItem| {
            sparse_row_block_sweep(
                &qd, &kd, &vd, n, n_k, d, &mask_o, tile_base, &cfg_o, blocks, tau, kv_limit,
                it.rb, it.rb + 1, &mut it.o_win, &mut it.lse_win,
            )
        })
        .unwrap_or_else(|e| panic!("block_sparse2_forward: retries exhausted: {e:?}"));
    for it in done {
        let r0 = it.rb * b_r;
        o.data[r0 * d..r0 * d + it.o_win.len()].copy_from_slice(&it.o_win);
        lse[r0..r0 + it.lse_win.len()].copy_from_slice(&it.lse_win);
    }

    Flash2Output { o, lse }
}

/// Sequential sparse sweep over row blocks [rb_lo, rb_hi): the dense
/// [`super::flash2::row_block_sweep`] with the mask filter on the K/V
/// stream. Flat row-major slices and self-contained per-block
/// arithmetic, so the batched scheduler dispatches single-block work
/// items through exactly this path (`attn::batched`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sparse_row_block_sweep(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    n_k: usize,
    d: usize,
    mask: &BlockMask,
    tile_base: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    rb_lo: usize,
    rb_hi: usize,
    o_out: &mut [f32],
    lse_out: &mut [f32],
) -> Hbm {
    let b_r = blocks.b_r;
    let mut hbm = Hbm::new();
    let mut state = RowBlockState::new(blocks, d);

    for i in rb_lo..rb_hi {
        let r0 = i * b_r;
        let r1 = ((i + 1) * b_r).min(n);
        let br = r1 - r0;
        // Q_i once per row block (the dense kernel's accounting — a
        // fully-dead row block still owns its zero/epilogue output);
        // only live column tiles stream K/V.
        hbm.load(br * d);
        state.reset(br, d);
        stream_kv_filtered(
            &mut state,
            &q[r0 * d..r1 * d],
            k,
            v,
            n_k,
            n,
            d,
            r0,
            r1,
            cfg,
            blocks,
            tau,
            kv_limit,
            &mut hbm,
            |j| mask.get(i, tile_base + j),
        );
        let off = (i - rb_lo) * b_r;
        write_epilogue(
            &state,
            br,
            d,
            &mut o_out[off * d..off * d + br * d],
            &mut lse_out[off..off + br],
            &mut hbm,
        );
    }

    hbm
}

/// Fast block-sparse backward: the two-phase production gradient kernel
/// (`attn::flash2::flash2_backward`) with the zero-block skip in both
/// phases — phase 1 (Q-outer dQ) never loads a zero block's K/V, phase
/// 2 (column-parallel dK/dV) never streams a zero block's Q/dO. `D =
/// rowsum(dO ∘ O)` is precomputed in one epilogue pass; both phases
/// recompute `P = exp(s − L)` from the forward's logsumexp and fan out
/// as work items on `exec` with bitwise worker-count- and
/// pool-mode-independent output. With a dense mask this is
/// `flash2_backward` bit for bit. Rows whose logsumexp is `-inf`
/// (fully masked, including rows with no live block at all) contribute
/// zero gradient everywhere. Retry exhaustion panics with the typed
/// error (per-slice kernel contract).
#[allow(clippy::too_many_arguments)]
pub fn block_sparse2_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: AttnStats<'_>,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> AttnGrads {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    assert_eq!(k.cols(), d, "block_sparse2_backward: K feature dim mismatch");
    assert_eq!((v.rows(), v.cols()), (n_k, d), "block_sparse2_backward: V shape mismatch");
    assert_eq!((o.rows(), o.cols()), (n, d), "block_sparse2_backward: O shape mismatch");
    assert_eq!((dout.rows(), dout.cols()), (n, d), "block_sparse2_backward: dO shape mismatch");
    assert_eq!(stats.len(), n, "block_sparse2_backward: stats length mismatch");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let t_c = n_k.div_ceil(b_c);

    let mut dq = Tensor::zeros(&[n, d]);
    let mut dk = Tensor::zeros(&[n_k, d]);
    let mut dv = Tensor::zeros(&[n_k, d]);
    if t_r == 0 || t_c == 0 {
        return AttnGrads { dq, dk, dv };
    }
    let tile_base = mask_tile_base(cfg.kv_offset, b_c);
    check_mask_geometry(mask, t_r, tile_base, t_c);

    // Phase 0 (epilogue pass): D_i = rowsum(dO ∘ O), once.
    hbm.load(2 * n * d);
    let d_vec: Vec<f32> = (0..n).map(|r| dot4(dout.row(r), o.row(r))).collect();
    hbm.store(n);

    // One owned snapshot of the slice, shared by both phases' closures.
    struct Shared {
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        dout: Vec<f32>,
        lse: Vec<f32>,
        d_vec: Vec<f32>,
        mask: BlockMask,
        cfg: AttnConfig,
    }
    let data = Arc::new(Shared {
        q: q.data.clone(),
        k: k.data.clone(),
        v: v.data.clone(),
        dout: dout.data.clone(),
        lse: stats.to_lse_vec(),
        d_vec,
        mask: mask.clone(),
        cfg: cfg.clone(),
    });

    // Phase 1: dQ with a Q-outer sweep, one work item per row block
    // through the execution plane (invariant R1) — bitwise identical to
    // the per-worker chunk partition it replaces.
    let dq_items: Vec<DqItem> = (0..t_r)
        .map(|rb| DqItem { s: 0, rb, dq_win: vec![0.0; block_rows(rb, b_r, n) * d] })
        .collect();
    let dq_data = Arc::clone(&data);
    let (dq_done, _) = exec
        .run(dq_items, FaultSite::SparseDq, hbm, move |it: &mut DqItem| {
            let sh = &dq_data;
            sparse_dq_row_sweep(
                &sh.q, &sh.k, &sh.v, &sh.dout, &sh.lse, &sh.d_vec, n, n_k, d, &sh.mask,
                tile_base, &sh.cfg, blocks, tau, kv_limit, it.rb, it.rb + 1, &mut it.dq_win,
            )
        })
        .unwrap_or_else(|e| panic!("block_sparse2_backward: retries exhausted: {e:?}"));
    for it in dq_done {
        let r0 = it.rb * b_r;
        dq.data[r0 * d..r0 * d + it.dq_win.len()].copy_from_slice(&it.dq_win);
    }

    // Phase 2: dK/dV with the column-block-parallel sweep, one item per
    // column block; the filter skips a zero block's whole Q/dO stream.
    let dkv_items: Vec<DkvItem> = (0..t_c)
        .map(|cb| {
            let cols = block_rows(cb, b_c, n_k);
            DkvItem { s: 0, cb, dk_win: vec![0.0; cols * d], dv_win: vec![0.0; cols * d] }
        })
        .collect();
    let (dkv_done, _) = exec
        .run(dkv_items, FaultSite::SparseDkv, hbm, move |it: &mut DkvItem| {
            let sh = &data;
            dkv_col_sweep_filtered(
                &sh.q,
                &sh.k,
                &sh.v,
                &sh.dout,
                &sh.lse,
                &sh.d_vec,
                n,
                n_k,
                d,
                &sh.cfg,
                blocks,
                tau,
                kv_limit,
                it.cb,
                it.cb + 1,
                &mut it.dk_win,
                &mut it.dv_win,
                |i, j| sh.mask.get(i, tile_base + j),
            )
        })
        .unwrap_or_else(|e| panic!("block_sparse2_backward: retries exhausted: {e:?}"));
    for it in dkv_done {
        let c0 = it.cb * b_c;
        dk.data[c0 * d..c0 * d + it.dk_win.len()].copy_from_slice(&it.dk_win);
        dv.data[c0 * d..c0 * d + it.dv_win.len()].copy_from_slice(&it.dv_win);
    }

    AttnGrads { dq, dk, dv }
}

/// Phase-1 sweep over Q row blocks [rb_lo, rb_hi): the dense
/// [`super::flash2::dq_row_sweep`] with the mask filter on the K/V
/// stream. Flat slices, single-block-dispatchable (see
/// [`sparse_row_block_sweep`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sparse_dq_row_sweep(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    lse: &[f32],
    d_vec: &[f32],
    n: usize,
    n_k: usize,
    d: usize,
    mask: &BlockMask,
    tile_base: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    rb_lo: usize,
    rb_hi: usize,
    dq_out: &mut [f32],
) -> Hbm {
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let row_base = rb_lo * b_r;
    let mut hbm = Hbm::new();
    let mut s_buf = vec![0.0f32; b_r * b_c];
    let mut dp_buf = vec![0.0f32; b_r * b_c];

    for i in rb_lo..rb_hi {
        let r0 = i * b_r;
        let r1 = ((i + 1) * b_r).min(n);
        let br = r1 - r0;
        // Q_i, dO_i, D_i, L_i once per row block; dQ_i accumulates in
        // the worker-owned window and stores once below.
        hbm.load(2 * br * d + 2 * br);
        stream_kv_dq_filtered(
            &mut dq_out[(r0 - row_base) * d..(r1 - row_base) * d],
            &q[r0 * d..r1 * d],
            &dout[r0 * d..r1 * d],
            k,
            v,
            n_k,
            n,
            d,
            r0,
            r1,
            lse,
            d_vec,
            cfg,
            blocks,
            tau,
            kv_limit,
            &mut s_buf,
            &mut dp_buf,
            &mut hbm,
            |j| mask.get(i, tile_base + j),
        );
        hbm.store(br * d);
    }

    hbm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash::flash_forward;
    use crate::attn::flash2::{flash2_backward, flash2_forward};
    use crate::attn::masks::dropout_scale;
    use crate::attn::standard::standard_forward;
    use crate::util::prop::{choose, for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn dense_mask_equals_flash() {
        let (q, k, v) = qkv(32, 8, 0);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let dense = BlockMask::dense(4, 4);
        let bs = block_sparse_forward(&q, &k, &v, &dense, &cfg, blocks, &mut Hbm::new());
        let fl = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
        assert!(bs.o.max_abs_diff(&fl.o) < 1e-6);
    }

    #[test]
    fn diagonal_mask_is_block_local() {
        let (q, k, v) = qkv(32, 8, 1);
        let blocks = Blocks::explicit(8, 8);
        let mut mask = BlockMask::zeros(4, 4);
        for i in 0..4 {
            mask.set(i, i, true);
        }
        let bs = block_sparse_forward(
            &q, &k, &v, &mask, &AttnConfig::default(), blocks, &mut Hbm::new(),
        );
        for blk in 0..4 {
            let (r0, r1) = (blk * 8, (blk + 1) * 8);
            let ql = q.slice_rows(r0, r1);
            let kl = k.slice_rows(r0, r1);
            let vl = v.slice_rows(r0, r1);
            let cfg = AttnConfig { tau: Some(1.0 / (8f32).sqrt()), ..Default::default() };
            let loc = standard_forward(&ql, &kl, &vl, &cfg, &mut Hbm::new());
            assert!(bs.o.slice_rows(r0, r1).max_abs_diff(&loc.o) < 1e-5, "block {blk}");
        }
    }

    #[test]
    fn io_scales_with_sparsity() {
        // Proposition 4: accesses scale ~ s for the quadratic term.
        let (q, k, v) = qkv(128, 8, 2);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let dense = BlockMask::dense(16, 16);
        let butter = BlockMask::butterfly(16, 16);
        let mut h_dense = Hbm::new();
        block_sparse_forward(&q, &k, &v, &dense, &cfg, blocks, &mut h_dense);
        let mut h_sparse = Hbm::new();
        block_sparse_forward(&q, &k, &v, &butter, &cfg, blocks, &mut h_sparse);
        let ratio = h_sparse.accesses() as f64 / h_dense.accesses() as f64;
        let s = butter.sparsity();
        assert!((ratio - s).abs() < 0.25, "ratio {ratio} vs sparsity {s}");
    }

    #[test]
    fn zero_mask_row_outputs_zero() {
        let (q, k, v) = qkv(16, 4, 3);
        let blocks = Blocks::explicit(8, 8);
        let mut mask = BlockMask::zeros(2, 2);
        mask.set(1, 0, true);
        mask.set(1, 1, true);
        let bs = block_sparse_forward(
            &q, &k, &v, &mask, &AttnConfig::default(), blocks, &mut Hbm::new(),
        );
        assert!(bs.o.slice_rows(0, 8).data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rectangular_kv_tall_and_wide_geometry() {
        // Satellite fix: t_c derives from the key count, not the query
        // count — tall (n_k < n) and wide (n_k > n) grids both work for
        // the reference kernel and match a dense oracle over the keys.
        let mut rng = SplitMix64::new(4);
        let q = Tensor::randn(&[24, 8], &mut rng, 1.0);
        for n_k in [8usize, 40] {
            let k = Tensor::randn(&[n_k, 8], &mut rng, 1.0);
            let v = Tensor::randn(&[n_k, 8], &mut rng, 1.0);
            let blocks = Blocks::explicit(8, 8);
            let mask = BlockMask::dense(3, n_k / 8);
            let cfg = AttnConfig::default();
            let bs = block_sparse_forward(&q, &k, &v, &mask, &cfg, blocks, &mut Hbm::new());
            let fl = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
            assert!(bs.o.max_abs_diff(&fl.o) < 1e-5, "n_k={n_k}");
        }
    }

    #[test]
    fn butterfly_closer_to_dense_than_antilocal() {
        // Quality claim behind Table 3: the butterfly pattern (diagonal +
        // power-of-two bands) approximates dense attention better than an
        // equally-sparse pattern that *misses* the diagonal.
        let n = 64;
        let d = 8;
        let mut rng = SplitMix64::new(4);
        let q = Tensor::randn(&[n, d], &mut rng, 2.0);
        let k = Tensor::randn(&[n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let dense = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());

        let butter = BlockMask::butterfly(8, 8);
        // Anti-local: same number of nonzero blocks, but shifted off the
        // butterfly structure (cyclic shift by t/2).
        let mut anti = BlockMask::zeros(8, 8);
        for i in 0..8 {
            for j in 0..8 {
                if butter.get(i, j) {
                    anti.set(i, (j + 4) % 8, true);
                }
            }
        }
        assert_eq!(butter.nonzero_blocks(), anti.nonzero_blocks());
        let err = |mask: &BlockMask| {
            let o = block_sparse_forward(&q, &k, &v, mask, &cfg, blocks, &mut Hbm::new()).o;
            dense
                .o
                .data
                .iter()
                .zip(&o.data)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
        };
        let e_butter = err(&butter);
        let e_anti = err(&anti);
        assert!(e_butter < e_anti, "butterfly {e_butter} vs anti-local {e_anti}");
    }

    // ---- fast pair (block_sparse2) ----

    /// Element-level sparse oracle, independent of every tiled kernel:
    /// softmax over the keys whose block is live (and causal/padding
    /// allowed, in global coordinates), dropout applied to P after
    /// normalisation (the kernels' convention).
    fn sparse_oracle_forward(
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        mask: &BlockMask,
        cfg: &AttnConfig,
        blocks: Blocks,
    ) -> Tensor {
        let (n, d) = (q.rows(), q.cols());
        let n_k = k.rows();
        let tau = cfg.tau_for(d);
        let kv_limit = cfg.kv_limit(n_k);
        let mut o = Tensor::zeros(&[n, d]);
        for r in 0..n {
            let i = r / blocks.b_r;
            let allowed: Vec<usize> = (0..n_k)
                .filter(|&c| {
                    let g = cfg.kv_offset + c;
                    mask.get(i, g / blocks.b_c) && !(cfg.causal && g > r) && g < kv_limit
                })
                .collect();
            if allowed.is_empty() {
                continue; // zero-mass row keeps O = 0
            }
            let scores: Vec<f32> =
                allowed.iter().map(|&c| tau * dot4(q.row(r), k.row(c))).collect();
            let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let e: Vec<f32> = scores.iter().map(|&s| (s - mx).exp()).collect();
            let z: f32 = e.iter().sum();
            for (&c, &ev) in allowed.iter().zip(&e) {
                let p = ev / z
                    * dropout_scale(
                        cfg.bh_index,
                        r,
                        cfg.kv_offset + c,
                        n,
                        cfg.dropout_seed,
                        cfg.dropout_p,
                    );
                let orow = o.row_mut(r);
                for cd in 0..d {
                    orow[cd] += p * v.row(c)[cd];
                }
            }
        }
        o
    }

    #[test]
    fn dense_mask_forward_bitwise_equals_flash2_grid() {
        // The ISSUE grid: causal × dropout × rectangular kv_len × worker
        // count {1, 2, 5}. A dense mask leaves only the filter's
        // always-true path, so output must be BITWISE equal to the dense
        // fast kernel — any deviation is a scheduling/coordinate bug.
        for_each_case("bs2_dense_parity", 20, |rng| {
            let n = usize_in(rng, 2, 40);
            let n_k = if rng.next_f32() < 0.5 { n } else { usize_in(rng, 1, 48) };
            let d = *choose(rng, &[2usize, 4, 8]);
            let b_r = usize_in(rng, 1, n);
            let b_c = usize_in(rng, 1, n_k);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = *choose(rng, &[1usize, 2, 5]);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n_k, d], rng, 1.0);
            let v = Tensor::randn(&[n_k, d], rng, 1.0);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let blocks = Blocks::explicit(b_r, b_c);
            let dense = BlockMask::dense(n.div_ceil(b_r), n_k.div_ceil(b_c));
            let exec =
                if rng.next_f32() < 0.5 { Exec::new(workers) } else { Exec::scoped(workers) };
            let fast = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
            let sparse =
                block_sparse2_forward(&q, &k, &v, &dense, &cfg, blocks, &exec, &mut Hbm::new());
            let ctx = format!(
                "n={n} n_k={n_k} d={d} blocks=({b_r},{b_c}) causal={causal} \
                 kv_len={kv_len:?} p={dropout_p} w={workers}"
            );
            assert_eq!(sparse.o.data, fast.o.data, "O not bitwise equal: {ctx}");
            assert_eq!(sparse.lse, fast.lse, "lse not bitwise equal: {ctx}");
        });
    }

    #[test]
    fn dense_mask_backward_bitwise_equals_flash2_grid() {
        for_each_case("bs2_dense_bwd_parity", 20, |rng| {
            let n = usize_in(rng, 2, 36);
            let n_k = if rng.next_f32() < 0.5 { n } else { usize_in(rng, 1, 44) };
            let d = *choose(rng, &[2usize, 4, 8]);
            let b_r = usize_in(rng, 1, n);
            let b_c = usize_in(rng, 1, n_k);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = *choose(rng, &[1usize, 2, 5]);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n_k, d], rng, 1.0);
            let v = Tensor::randn(&[n_k, d], rng, 1.0);
            let dout = Tensor::randn(&[n, d], rng, 1.0);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let blocks = Blocks::explicit(b_r, b_c);
            let dense = BlockMask::dense(n.div_ceil(b_r), n_k.div_ceil(b_c));
            let exec =
                if rng.next_f32() < 0.5 { Exec::new(workers) } else { Exec::scoped(workers) };
            let one = Exec::scoped(1);
            let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &one, &mut Hbm::new());
            let fast = flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &one, &mut Hbm::new(),
            );
            let sparse = block_sparse2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &dense, &cfg, blocks, &exec,
                &mut Hbm::new(),
            );
            let ctx = format!(
                "n={n} n_k={n_k} d={d} blocks=({b_r},{b_c}) causal={causal} \
                 kv_len={kv_len:?} p={dropout_p} w={workers}"
            );
            assert_eq!(sparse.dq.data, fast.dq.data, "dQ not bitwise equal: {ctx}");
            assert_eq!(sparse.dk.data, fast.dk.data, "dK not bitwise equal: {ctx}");
            assert_eq!(sparse.dv.data, fast.dv.data, "dV not bitwise equal: {ctx}");
        });
    }

    #[test]
    fn sparse_patterns_match_element_oracle() {
        // Butterfly and local_global against the element-level oracle,
        // with causal / dropout / padding active.
        for (pattern, causal, dropout_p, kv_len) in [
            ("butterfly", false, 0.0f32, None),
            ("butterfly", true, 0.0, None),
            ("butterfly", true, 0.25, Some(29)),
            ("local_global", false, 0.0, None),
            ("local_global", false, 0.25, Some(21)),
            ("local_global", true, 0.0, None),
        ] {
            let (q, k, v) = qkv(32, 8, 11);
            let blocks = Blocks::explicit(4, 4);
            let mask = if pattern == "butterfly" {
                BlockMask::butterfly(8, 8)
            } else {
                BlockMask::local_global(8, 8, 1, 1)
            };
            let cfg = AttnConfig {
                causal,
                dropout_p,
                dropout_seed: 5,
                kv_len,
                ..Default::default()
            };
            let fast = block_sparse2_forward(
                &q, &k, &v, &mask, &cfg, blocks, &Exec::new(3), &mut Hbm::new(),
            );
            let oracle = sparse_oracle_forward(&q, &k, &v, &mask, &cfg, blocks);
            let diff = fast.o.max_abs_diff(&oracle);
            assert!(
                diff < 1e-4,
                "{pattern} causal={causal} p={dropout_p} kv_len={kv_len:?}: diff {diff}"
            );
        }
    }

    #[test]
    fn sparse_forward_agrees_with_algorithm5_reference() {
        // The two-pair contract: fast and faithful sparse kernels agree
        // on the same mask (to fp rounding; they tile identically but
        // normalise differently).
        let (q, k, v) = qkv(64, 8, 12);
        let blocks = Blocks::explicit(8, 8);
        for mask in [BlockMask::butterfly(8, 8), BlockMask::local_global(8, 8, 1, 1)] {
            let cfg = AttnConfig::default();
            let slow = block_sparse_forward(&q, &k, &v, &mask, &cfg, blocks, &mut Hbm::new());
            let fast = block_sparse2_forward(
                &q, &k, &v, &mask, &cfg, blocks, &Exec::new(2), &mut Hbm::new(),
            );
            assert!(slow.o.max_abs_diff(&fast.o) < 1e-5);
        }
    }

    #[test]
    fn sparse_grads_match_finite_difference() {
        // The ISSUE FD wall: dQ, dK, dV by central differences through
        // the sparse forward itself, butterfly AND local_global, causal
        // and dropout included (the dropout mask is a deterministic
        // function of indices, so the loss stays differentiable).
        let (n, d) = (12usize, 4usize);
        let (q, k, v) = qkv(n, d, 13);
        let blocks = Blocks::explicit(2, 2);
        for (pattern, causal, dropout_p) in [
            ("butterfly", false, 0.0f32),
            ("butterfly", true, 0.25),
            ("local_global", true, 0.0),
            ("local_global", false, 0.25),
        ] {
            let mask = if pattern == "butterfly" {
                BlockMask::butterfly(6, 6)
            } else {
                BlockMask::local_global(6, 6, 1, 1)
            };
            let cfg = AttnConfig { causal, dropout_p, dropout_seed: 3, ..Default::default() };
            let ex2 = Exec::new(2);
            let fwd =
                block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &ex2, &mut Hbm::new());
            let dout = Tensor::full(&[n, d], 1.0);
            let g = block_sparse2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &mask, &cfg, blocks, &ex2,
                &mut Hbm::new(),
            );
            let ex1 = Exec::new(1);
            let f = |q_: &Tensor, k_: &Tensor, v_: &Tensor| -> f32 {
                block_sparse2_forward(q_, k_, v_, &mask, &cfg, blocks, &ex1, &mut Hbm::new())
                    .o
                    .data
                    .iter()
                    .sum()
            };
            let eps = 1e-3f32;
            for (which, (x, gx)) in [(0, (&q, &g.dq)), (1, (&k, &g.dk)), (2, (&v, &g.dv))] {
                for idx in [0usize, 9, 17, 25, 33, 41] {
                    let mut xp = x.clone();
                    xp.data[idx] += eps;
                    let mut xm = x.clone();
                    xm.data[idx] -= eps;
                    let (fp, fm) = match which {
                        0 => (f(&xp, &k, &v), f(&xm, &k, &v)),
                        1 => (f(&q, &xp, &v), f(&q, &xm, &v)),
                        _ => (f(&q, &k, &xp), f(&q, &k, &xm)),
                    };
                    let fd = (fp - fm) / (2.0 * eps);
                    let an = gx.data[idx];
                    assert!(
                        (fd - an).abs() < 3e-2 + 0.05 * an.abs(),
                        "{pattern} causal={causal} p={dropout_p} which={which} idx={idx}: \
                         fd={fd} analytic={an}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_deterministic_across_worker_counts() {
        // Forward O/lse AND all three gradients bitwise identical for
        // any worker count — per-block arithmetic is partition-
        // independent exactly as in the dense pair.
        let (q, k, v) = qkv(64, 8, 14);
        let mask = BlockMask::butterfly(8, 8);
        let cfg =
            AttnConfig { causal: true, dropout_p: 0.1, dropout_seed: 2, ..Default::default() };
        let blocks = Blocks::explicit(8, 8);
        let mut rng = SplitMix64::new(15);
        let dout = Tensor::randn(&[64, 8], &mut rng, 1.0);
        let one = Exec::scoped(1);
        let base = block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &one, &mut Hbm::new());
        let gbase = block_sparse2_backward(
            &q, &k, &v, &base.o, &dout, base.stats(), &mask, &cfg, blocks, &one, &mut Hbm::new(),
        );
        for workers in [2usize, 3, 5, 8, 64] {
            for exec in [Exec::new(workers), Exec::scoped(workers)] {
                let mode = if exec.is_scoped() { "scoped" } else { "persistent" };
                let multi = block_sparse2_forward(
                    &q, &k, &v, &mask, &cfg, blocks, &exec, &mut Hbm::new(),
                );
                assert_eq!(base.o.data, multi.o.data, "O at {mode} workers={workers}");
                assert_eq!(base.lse, multi.lse, "lse at {mode} workers={workers}");
                let g = block_sparse2_backward(
                    &q, &k, &v, &base.o, &dout, base.stats(), &mask, &cfg, blocks, &exec,
                    &mut Hbm::new(),
                );
                assert_eq!(gbase.dq.data, g.dq.data, "dQ at {mode} workers={workers}");
                assert_eq!(gbase.dk.data, g.dk.data, "dK at {mode} workers={workers}");
                assert_eq!(gbase.dv.data, g.dv.data, "dV at {mode} workers={workers}");
            }
        }
    }

    #[test]
    fn zero_mask_rows_zero_output_zero_grads_no_nan() {
        // A row block with no live column tile anywhere must produce the
        // defined all-masked semantics (zero rows, lse = -inf) and zero,
        // finite gradients for those rows.
        let (q, k, v) = qkv(16, 4, 16);
        let blocks = Blocks::explicit(8, 8);
        let mut mask = BlockMask::zeros(2, 2);
        mask.set(1, 0, true);
        mask.set(1, 1, true);
        let cfg = AttnConfig::default();
        let ex2 = Exec::new(2);
        let fwd = block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &ex2, &mut Hbm::new());
        assert!(fwd.o.slice_rows(0, 8).data.iter().all(|&x| x == 0.0));
        assert!(fwd.lse[..8].iter().all(|&x| x == f32::NEG_INFINITY));
        assert!(fwd.o.data.iter().all(|x| x.is_finite()));
        let dout = Tensor::full(&[16, 4], 1.0);
        let g = block_sparse2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &mask, &cfg, blocks, &ex2, &mut Hbm::new(),
        );
        assert!(g.dq.slice_rows(0, 8).data.iter().all(|&x| x == 0.0), "dead rows get zero dQ");
        assert!(g.dq.data.iter().chain(&g.dk.data).chain(&g.dv.data).all(|x| x.is_finite()));
    }

    #[test]
    fn sharded_mask_slices_merge_to_unsharded() {
        // The global-coordinate mask contract: tile-aligned key shards
        // each hold the SAME global mask, run with cfg.for_shard(lo),
        // and their partials merge (§5 identity) to the unsharded sparse
        // kernel's output — causal + dropout + padding all active.
        use crate::attn::distributed::merge_partials;
        let (n, d) = (32usize, 8usize);
        let (q, k, v) = qkv(n, d, 17);
        let blocks = Blocks::explicit(4, 4);
        let mask = BlockMask::butterfly(8, 8);
        let cfg = AttnConfig {
            causal: true,
            dropout_p: 0.2,
            dropout_seed: 9,
            kv_len: Some(27),
            ..Default::default()
        };
        let ex2 = Exec::new(2);
        let single = block_sparse2_forward(&q, &k, &v, &mask, &cfg, blocks, &ex2, &mut Hbm::new());
        for bounds in [vec![0usize, 16, 32], vec![0, 4, 12, 32], vec![0, 8, 16, 24, 32]] {
            let merged = bounds
                .windows(2)
                .map(|w| {
                    let (lo, hi) = (w[0], w[1]);
                    let ks = k.slice_rows(lo, hi);
                    let vs = v.slice_rows(lo, hi);
                    block_sparse2_forward(
                        &q, &ks, &vs, &mask, &cfg.for_shard(lo), blocks, &ex2, &mut Hbm::new(),
                    )
                    .into_attn_output()
                })
                .reduce(|a, b| merge_partials(&a, &b))
                .unwrap();
            let diff = single.o.max_abs_diff(&merged.o);
            assert!(diff < 1e-4, "bounds {bounds:?}: diff {diff}");
        }
    }

    #[test]
    #[should_panic(expected = "must align to whole column tiles")]
    fn unaligned_kv_offset_panics_loudly() {
        let (q, k, v) = qkv(8, 4, 18);
        let mask = BlockMask::dense(2, 4);
        let cfg = AttnConfig { kv_offset: 3, ..Default::default() };
        block_sparse2_forward(
            &q, &k, &v, &mask, &cfg, Blocks::explicit(4, 4), &Exec::new(1), &mut Hbm::new(),
        );
    }

    #[test]
    #[should_panic(expected = "mask geometry mismatch")]
    fn short_mask_panics_loudly() {
        let (q, k, v) = qkv(16, 4, 19);
        let mask = BlockMask::dense(4, 2); // 16/4 = 4 column tiles needed
        block_sparse2_forward(
            &q,
            &k,
            &v,
            &mask,
            &AttnConfig::default(),
            Blocks::explicit(4, 4),
            &Exec::new(1),
            &mut Hbm::new(),
        );
    }
}
