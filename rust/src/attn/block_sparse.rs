//! Algorithm 5: block-sparse FlashAttention — the dense tiled loop with
//! zero blocks skipped. IO complexity Θ(Nd + N²d²s/M) (Proposition 4).

use super::flash::{tile_fully_unmasked, Blocks};
use super::masks::{masked_score, BlockMask, NEG_INF};
use super::{AttnConfig, AttnOutput};
use crate::sim::hbm::Hbm;
use crate::tensor::Tensor;

/// Algorithm 5 forward. `mask` has shape [ceil(n/b_r), ceil(n/b_c)].
pub fn block_sparse_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    blocks: Blocks,
    hbm: &mut Hbm,
) -> AttnOutput {
    let (n, d) = (q.rows(), q.cols());
    // The block-sparse mirror is single-device: K/V are square with Q and
    // the sparsity pattern M is indexed in local tile coordinates, so a
    // key shard cannot be expressed here. Reject the sharded config
    // loudly instead of silently placing M's blocks on the wrong global
    // columns; sequence-parallel callers shard the dense kernels.
    assert_eq!(cfg.kv_offset, 0, "block_sparse_forward: key shards are not supported");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let t_c = n.div_ceil(b_c);
    assert_eq!((mask.t_r, mask.t_c), (t_r, t_c), "mask geometry mismatch");

    let mut o = Tensor::zeros(&[n, d]);
    let mut l = vec![0.0f32; n];
    let mut m = vec![f32::NEG_INFINITY; n];
    hbm.store(n * d + 2 * n);
    // On-chip scratch, allocated once (perf: no allocation in the tile loop,
    // matching the flash mirror's earlier perf pass).
    let mut p_buf = vec![0.0f32; b_c];
    let mut pv = vec![0.0f32; d];

    for j in 0..t_c {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n);
        // Skip loading K_j/V_j entirely if column-block j is all-zero.
        if (0..t_r).all(|i| !mask.get(i, j)) {
            continue;
        }
        hbm.load(2 * (c1 - c0) * d);
        let kj = k.slice_rows(c0, c1);
        let vj = v.slice_rows(c0, c1);

        for i in 0..t_r {
            if !mask.get(i, j) {
                continue; // Algorithm 5 line 8
            }
            let r0 = i * b_r;
            let r1 = ((i + 1) * b_r).min(n);
            if cfg.causal && c0 > r1 - 1 {
                continue;
            }
            hbm.load((r1 - r0) * d * 2 + 2 * (r1 - r0));
            let qi = q.slice_rows(r0, r1);
            let bc = c1 - c0;
            let mut s = qi.matmul_bt(&kj).scale(tau);
            // Causal fast path: tiles that provably contain no masked entry
            // skip the per-element pass (same rule as the flash kernels;
            // local == global here, kv_offset is asserted 0 above).
            if !tile_fully_unmasked(cfg.causal, r0, c1, kv_limit) {
                for (rr, row) in (r0..r1).enumerate() {
                    for (cc, col) in (c0..c1).enumerate() {
                        let x = s.data[rr * bc + cc];
                        s.data[rr * bc + cc] = masked_score(x, row, col, cfg.causal, kv_limit);
                    }
                }
            }
            for (rr, row) in (r0..r1).enumerate() {
                let srow = &s.data[rr * bc..(rr + 1) * bc];
                let m_tile = srow.iter().cloned().fold(NEG_INF, f32::max);
                let p = &mut p_buf[..bc];
                let mut l_tile = 0.0f32;
                for (pw, &x) in p.iter_mut().zip(srow) {
                    *pw = (x - m_tile).exp();
                    l_tile += *pw;
                }
                let m_new = m[row].max(m_tile);
                let alpha = (m[row] - m_new).exp();
                let beta = (m_tile - m_new).exp();
                let l_new = alpha * l[row] + beta * l_tile;
                // P̃·V accumulated row-of-V-major: contiguous and
                // vectorisable, with the same per-column summation order as
                // the old stride-d loop. The O update below now uses the
                // flash kernel's inv-premultiplied form (one divide per
                // row) — same numerics to rounding, not bitwise.
                pv[..d].fill(0.0);
                for (cc, &pw) in p.iter().enumerate() {
                    let vrow = &vj.data[cc * d..(cc + 1) * d];
                    for c in 0..d {
                        pv[c] += pw * vrow[c];
                    }
                }
                let inv = 1.0 / l_new.max(1e-37);
                let a_coef = l[row] * alpha * inv;
                let b_coef = beta * inv;
                let orow = o.row_mut(row);
                for c in 0..d {
                    orow[c] = a_coef * orow[c] + b_coef * pv[c];
                }
                l[row] = l_new;
                m[row] = m_new;
            }
            hbm.store((r1 - r0) * d + 2 * (r1 - r0));
        }
    }

    // Rows never visited by any nonzero block keep O = 0 (kernel semantics).
    AttnOutput { o, l, m }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash::flash_forward;
    use crate::attn::standard::standard_forward;
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn dense_mask_equals_flash() {
        let (q, k, v) = qkv(32, 8, 0);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let dense = BlockMask::dense(4, 4);
        let bs = block_sparse_forward(&q, &k, &v, &dense, &cfg, blocks, &mut Hbm::new());
        let fl = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
        assert!(bs.o.max_abs_diff(&fl.o) < 1e-6);
    }

    #[test]
    fn diagonal_mask_is_block_local() {
        let (q, k, v) = qkv(32, 8, 1);
        let blocks = Blocks::explicit(8, 8);
        let mut mask = BlockMask::zeros(4, 4);
        for i in 0..4 {
            mask.set(i, i, true);
        }
        let bs = block_sparse_forward(
            &q, &k, &v, &mask, &AttnConfig::default(), blocks, &mut Hbm::new(),
        );
        for blk in 0..4 {
            let (r0, r1) = (blk * 8, (blk + 1) * 8);
            let ql = q.slice_rows(r0, r1);
            let kl = k.slice_rows(r0, r1);
            let vl = v.slice_rows(r0, r1);
            let cfg = AttnConfig { tau: Some(1.0 / (8f32).sqrt()), ..Default::default() };
            let loc = standard_forward(&ql, &kl, &vl, &cfg, &mut Hbm::new());
            assert!(bs.o.slice_rows(r0, r1).max_abs_diff(&loc.o) < 1e-5, "block {blk}");
        }
    }

    #[test]
    fn io_scales_with_sparsity() {
        // Proposition 4: accesses scale ~ s for the quadratic term.
        let (q, k, v) = qkv(128, 8, 2);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let dense = BlockMask::dense(16, 16);
        let butter = BlockMask::butterfly(16, 16);
        let mut h_dense = Hbm::new();
        block_sparse_forward(&q, &k, &v, &dense, &cfg, blocks, &mut h_dense);
        let mut h_sparse = Hbm::new();
        block_sparse_forward(&q, &k, &v, &butter, &cfg, blocks, &mut h_sparse);
        let ratio = h_sparse.accesses() as f64 / h_dense.accesses() as f64;
        let s = butter.sparsity();
        assert!((ratio - s).abs() < 0.25, "ratio {ratio} vs sparsity {s}");
    }

    #[test]
    fn zero_mask_row_outputs_zero() {
        let (q, k, v) = qkv(16, 4, 3);
        let blocks = Blocks::explicit(8, 8);
        let mut mask = BlockMask::zeros(2, 2);
        mask.set(1, 0, true);
        mask.set(1, 1, true);
        let bs = block_sparse_forward(
            &q, &k, &v, &mask, &AttnConfig::default(), blocks, &mut Hbm::new(),
        );
        assert!(bs.o.slice_rows(0, 8).data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn butterfly_closer_to_dense_than_antilocal() {
        // Quality claim behind Table 3: the butterfly pattern (diagonal +
        // power-of-two bands) approximates dense attention better than an
        // equally-sparse pattern that *misses* the diagonal.
        let n = 64;
        let d = 8;
        let mut rng = SplitMix64::new(4);
        let q = Tensor::randn(&[n, d], &mut rng, 2.0);
        let k = Tensor::randn(&[n, d], &mut rng, 1.0);
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let dense = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());

        let butter = BlockMask::butterfly(8, 8);
        // Anti-local: same number of nonzero blocks, but shifted off the
        // butterfly structure (cyclic shift by t/2).
        let mut anti = BlockMask::zeros(8, 8);
        for i in 0..8 {
            for j in 0..8 {
                if butter.get(i, j) {
                    anti.set(i, (j + 4) % 8, true);
                }
            }
        }
        assert_eq!(butter.nonzero_blocks(), anti.nonzero_blocks());
        let err = |mask: &BlockMask| {
            let o = block_sparse_forward(&q, &k, &v, mask, &cfg, blocks, &mut Hbm::new()).o;
            dense
                .o
                .data
                .iter()
                .zip(&o.data)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
        };
        let e_butter = err(&butter);
        let e_anti = err(&anti);
        assert!(e_butter < e_anti, "butterfly {e_butter} vs anti-local {e_anti}");
    }
}
