//! Algorithm 0 (standard attention) and Algorithm 3 (standard backward) —
//! the materialise-everything baseline, instrumented with the HBM traffic
//! the paper attributes to it: Θ(Nd + N²) per pass (Theorems 2/5).

use super::masks::{dropout_scale, masked_score};
use super::{AttnConfig, AttnGrads, AttnOutput};
use crate::sim::hbm::Hbm;
use crate::tensor::Tensor;

/// Algorithm 0: S = tau Q K^T (write S), P = softmax(S) (read S, write P),
/// O = P V (read P, V, write O). q,k,v: [n, d].
pub fn standard_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    hbm: &mut Hbm,
) -> AttnOutput {
    let (n, d) = (q.rows(), q.cols());
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n);

    // Line 1: load Q, K; compute S; write S to HBM. Mask and dropout
    // decisions use global key coordinates (kv_offset + col), matching
    // the tiled kernels.
    hbm.load(n * d * 2);
    let mut s = q.matmul_bt(k).scale(tau);
    for row in 0..n {
        for col in 0..n {
            let x = s.data[row * n + col];
            s.data[row * n + col] =
                masked_score(x, row, cfg.kv_offset + col, cfg.causal, kv_limit);
        }
    }
    hbm.store(n * n);

    // Line 2: read S; compute P = softmax(S); write P.
    hbm.load(n * n);
    let mut l = vec![0.0f32; n];
    let mut m = vec![0.0f32; n];
    let mut p = s.clone();
    for row in 0..n {
        let prow = p.row_mut(row);
        let mx = prow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0;
        for x in prow.iter_mut() {
            *x = (*x - mx).exp();
            z += *x;
        }
        for x in prow.iter_mut() {
            *x /= z;
        }
        l[row] = z;
        m[row] = mx;
    }
    if cfg.dropout_p > 0.0 {
        for row in 0..n {
            for col in 0..n {
                p.data[row * n + col] *= dropout_scale(
                    cfg.bh_index,
                    row,
                    cfg.kv_offset + col,
                    n,
                    cfg.dropout_seed,
                    cfg.dropout_p,
                );
            }
        }
    }
    hbm.store(n * n);

    // Line 3: load P, V; compute O = P V; write O.
    hbm.load(n * n + n * d);
    let o = p.matmul(v);
    hbm.store(n * d);

    AttnOutput { o, l, m }
}

/// Algorithm 3: standard attention backward, materialising P, dP, dS.
/// Needs P from the forward (re-derived here from q,k for self-containment,
/// with the same HBM accounting the paper uses: P is *read* from HBM).
pub fn standard_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    dout: &Tensor,
    cfg: &AttnConfig,
    hbm: &mut Hbm,
) -> AttnGrads {
    let (n, d) = (q.rows(), q.cols());
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n);

    // Recreate P (in the real Algorithm 3 it was stored by the forward;
    // accounting: read P from HBM).
    let mut s = q.matmul_bt(k).scale(tau);
    for row in 0..n {
        for col in 0..n {
            let x = s.data[row * n + col];
            s.data[row * n + col] =
                masked_score(x, row, cfg.kv_offset + col, cfg.causal, kv_limit);
        }
    }
    let mut p = s.softmax_rows();
    let p_pre = p.clone();
    if cfg.dropout_p > 0.0 {
        for row in 0..n {
            for col in 0..n {
                p.data[row * n + col] *= dropout_scale(
                    cfg.bh_index,
                    row,
                    cfg.kv_offset + col,
                    n,
                    cfg.dropout_seed,
                    cfg.dropout_p,
                );
            }
        }
    }

    // Line 1: load P, dO; dV = P^T dO; write dV.
    hbm.load(n * n + n * d);
    let dv = p.matmul_at(dout);
    hbm.store(n * d);

    // Line 2: load dO, V; dP = dO V^T; write dP.
    hbm.load(n * d * 2);
    let mut dp = dout.matmul_bt(v);
    hbm.store(n * n);
    if cfg.dropout_p > 0.0 {
        for row in 0..n {
            for col in 0..n {
                dp.data[row * n + col] *= dropout_scale(
                    cfg.bh_index,
                    row,
                    cfg.kv_offset + col,
                    n,
                    cfg.dropout_seed,
                    cfg.dropout_p,
                );
            }
        }
    }

    // Line 3: read P, dP; dS = P o (dP - rowdot); write dS.
    hbm.load(n * n * 2);
    let mut ds = Tensor::zeros(&[n, n]);
    for row in 0..n {
        let mut di = 0.0f32;
        for col in 0..n {
            di += p_pre.data[row * n + col] * dp.data[row * n + col];
        }
        for col in 0..n {
            ds.data[row * n + col] =
                p_pre.data[row * n + col] * (dp.data[row * n + col] - di);
        }
    }
    hbm.store(n * n);

    // Lines 4-5: dQ = tau dS K, dK = tau dS^T Q.
    hbm.load(n * n + n * d);
    let dq = ds.matmul(k).scale(tau);
    hbm.store(n * d);
    hbm.load(n * n + n * d);
    let dk = ds.matmul_at(q).scale(tau);
    hbm.store(n * d);

    AttnGrads { dq, dk, dv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_allclose;
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn uniform_when_keys_identical() {
        // All keys equal -> softmax uniform -> O = mean(V).
        let (q, _, v) = qkv(8, 4, 0);
        let k = Tensor::full(&[8, 4], 0.5);
        let out = standard_forward(&q, &k, &v, &AttnConfig::default(), &mut Hbm::new());
        let mut mean = vec![0.0f32; 4];
        for r in 0..8 {
            for c in 0..4 {
                mean[c] += v.data[r * 4 + c] / 8.0;
            }
        }
        for r in 0..8 {
            assert_allclose(out.o.row(r), &mean, 1e-5, 0.0, "uniform");
        }
    }

    #[test]
    fn causal_first_row_is_v0() {
        let (q, k, v) = qkv(8, 4, 1);
        let out = standard_forward(&q, &k, &v, &AttnConfig::new().causal(), &mut Hbm::new());
        assert_allclose(out.o.row(0), v.row(0), 1e-6, 0.0, "first row");
    }

    #[test]
    fn hbm_accesses_quadratic() {
        // Theorem 2: standard attention -> Theta(Nd + N^2).
        let (q, k, v) = qkv(64, 8, 2);
        let mut hbm = Hbm::new();
        standard_forward(&q, &k, &v, &AttnConfig::default(), &mut hbm);
        let n = 64u64;
        let d = 8u64;
        let expected = 4 * n * n + 4 * n * d; // 4 N^2 + 4 Nd from the 3 steps
        assert_eq!(hbm.accesses(), expected);
    }

    #[test]
    fn grads_match_finite_difference() {
        let (q, k, v) = qkv(6, 3, 3);
        let cfg = AttnConfig::default();
        let dout = Tensor::full(&[6, 3], 1.0);
        let g = standard_backward(&q, &k, &v, &dout, &cfg, &mut Hbm::new());
        let eps = 1e-3f32;
        let f = |q_: &Tensor, k_: &Tensor, v_: &Tensor| -> f32 {
            standard_forward(q_, k_, v_, &cfg, &mut Hbm::new()).o.data.iter().sum()
        };
        for (which, (x, gx)) in [(0, (&q, &g.dq)), (1, (&k, &g.dk)), (2, (&v, &g.dv))] {
            for idx in [0usize, 7, 17] {
                let mut xp = x.clone();
                xp.data[idx] += eps;
                let mut xm = x.clone();
                xm.data[idx] -= eps;
                let (fp, fm) = match which {
                    0 => (f(&xp, &k, &v), f(&xm, &k, &v)),
                    1 => (f(&q, &xp, &v), f(&q, &xm, &v)),
                    _ => (f(&q, &k, &xp), f(&q, &k, &xm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                let an = gx.data[idx];
                assert!(
                    (fd - an).abs() < 2e-2 + 0.05 * an.abs(),
                    "which={which} idx={idx}: fd={fd} analytic={an}"
                );
            }
        }
    }
}
