//! Deterministic fault injection + the typed error/report surface of the
//! attention execution plane.
//!
//! The paper's §5 decomposition (per-block partials combined by an
//! associative softmax merge) is a *recovery* primitive, not just a
//! parallelism trick: any work item's contribution can be recomputed and
//! re-merged without touching the rest. This module supplies the pieces
//! the persistent guarded runtime ([`crate::attn::Exec`], `attn::exec`)
//! threads through every batched and sharded schedule:
//!
//! * [`FaultPlan`] — deterministic fault injection at chosen
//!   (site, item, attempt) coordinates, either targeted exactly or driven
//!   by a SplitMix64 coordinate hash (the same counter-style construction
//!   as the dropout stream, so decisions are independent of claim order
//!   and worker count). Zero-cost when disabled: the hot path asks one
//!   `is_enabled()` bool per item.
//! * [`FaultKind`] — the four injected fault classes: worker panic,
//!   poisoned (NaN) partial, delayed shard (a straggler, not a failure),
//!   and dropped merge (the completion record is lost, the work re-runs).
//! * [`FaultReport`] — what a guarded run observed: retry counts
//!   per class, the exact HBM traffic the retries re-did (asserted
//!   access-for-access against `sim::cost` per-item forms in the chaos
//!   wall), and classified dead shards.
//! * [`AttnError`] — the typed error taxonomy replacing hot-path panics,
//!   with (slice, batch, head, block) provenance on guardrail trips.
//!
//! Injection happens at *publish time*: a faulted attempt runs its work
//! to completion first, so every attempt — faulted or not — performs and
//! counts its full item traffic, which is what makes retry accounting
//! exact. An injected panic unwinds with an [`InjectedPanic`] payload
//! carrying the attempt's counter (via `resume_unwind`, skipping the
//! panic hook); a genuine mid-item panic has unknowable partial traffic
//! and is kept out of every counter.

use crate::sim::hbm::Hbm;
use crate::util::rng::SplitMix64;

/// Retry budget per work item: the first run plus two retries. Three
/// deterministic failures of the same item is a bug, not bad luck, and
/// surfaces as a typed [`AttnError`].
pub const MAX_ATTEMPTS: u32 = 3;

/// The injected fault classes of the chaos wall.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker panics after computing the item (contained by
    /// `catch_unwind`; the item is requeued).
    WorkerPanic,
    /// The item's output windows are scribbled with NaN after the work
    /// completes — the numeric guardrail must catch it and requeue.
    PoisonedPartial,
    /// The item completes late (a straggler). No retry, no extra
    /// traffic; output must still be bitwise identical.
    DelayedShard,
    /// The completion record is lost: the work ran (its traffic is
    /// real) but the item re-runs from scratch.
    DroppedMerge,
}

/// Which pool dispatch a fault (or guardrail trip) belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Batched dense forward row-block items.
    BatchedFwd,
    /// Batched dense backward dQ row-block items.
    BatchedDq,
    /// Batched dense backward dK/dV column-block items.
    BatchedDkv,
    /// Batched block-sparse forward row-block items.
    SparseFwd,
    /// Batched block-sparse backward dQ row-block items.
    SparseDq,
    /// Batched block-sparse backward dK/dV column-block items.
    SparseDkv,
    /// Ring-schedule forward row-block items (each streams all shards).
    RingFwd,
    /// Ring-schedule backward dQ row-block items.
    RingDq,
    /// Ring-schedule backward per-shard dK/dV column-block items.
    RingDkv,
    /// Tree-schedule per-shard partial items (via `flash2_forward_many`).
    TreePartial,
    /// Split-KV decode span items (via `flash2_decode`).
    DecodeSpan,
}

impl FaultSite {
    /// Stable coordinate code for the seeded decision hash.
    fn code(self) -> u64 {
        match self {
            FaultSite::BatchedFwd => 1,
            FaultSite::BatchedDq => 2,
            FaultSite::BatchedDkv => 3,
            FaultSite::SparseFwd => 4,
            FaultSite::SparseDq => 5,
            FaultSite::SparseDkv => 6,
            FaultSite::RingFwd => 7,
            FaultSite::RingDq => 8,
            FaultSite::RingDkv => 9,
            FaultSite::TreePartial => 10,
            FaultSite::DecodeSpan => 11,
        }
    }
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultSite::BatchedFwd => "batched forward",
            FaultSite::BatchedDq => "batched backward dQ",
            FaultSite::BatchedDkv => "batched backward dK/dV",
            FaultSite::SparseFwd => "block-sparse forward",
            FaultSite::SparseDq => "block-sparse backward dQ",
            FaultSite::SparseDkv => "block-sparse backward dK/dV",
            FaultSite::RingFwd => "ring-sharded forward",
            FaultSite::RingDq => "ring-sharded backward dQ",
            FaultSite::RingDkv => "ring-sharded backward dK/dV",
            FaultSite::TreePartial => "tree-sharded partial",
            FaultSite::DecodeSpan => "split-KV decode span",
        })
    }
}

/// Seeded random-mode parameters: each (site, item) first attempt faults
/// with probability `rate`, choosing uniformly among `kinds`.
#[derive(Clone, Debug)]
struct RandomFaults {
    seed: u64,
    rate: f32,
    kinds: Vec<FaultKind>,
}

/// A deterministic fault schedule. Decisions are a pure function of
/// (site, item index, attempt index) — never of claim order, worker
/// count, or wall clock — so a faulted run's retry set (and therefore
/// its extra HBM traffic) is exactly reproducible.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    targeted: Vec<(FaultSite, usize, u32, FaultKind)>,
    random: Option<RandomFaults>,
}

impl FaultPlan {
    /// The disabled plan: injects nothing, costs one bool per item.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a targeted fault at exact (site, item, attempt) coordinates.
    /// Targeting every attempt of an item exhausts its retry budget —
    /// that is how the chaos wall pins the typed-error path.
    pub fn with(mut self, site: FaultSite, item: usize, attempt: u32, kind: FaultKind) -> Self {
        self.targeted.push((site, item, attempt, kind));
        self
    }

    /// Seeded random mode: every (site, item) *first* attempt faults with
    /// probability `rate` (kind chosen uniformly from `kinds`), via a
    /// SplitMix64 hash of the coordinates — the dropout-stream
    /// construction, so the schedule is claim-order independent. Only
    /// first attempts fault, so recovery always succeeds within the
    /// attempt budget.
    pub fn seeded(seed: u64, rate: f32, kinds: &[FaultKind]) -> FaultPlan {
        assert!(!kinds.is_empty(), "FaultPlan::seeded needs at least one fault kind");
        FaultPlan {
            targeted: Vec::new(),
            random: Some(RandomFaults { seed, rate, kinds: kinds.to_vec() }),
        }
    }

    /// Whether any injection is configured (the hot path's fast-out).
    pub fn is_enabled(&self) -> bool {
        !self.targeted.is_empty() || self.random.is_some()
    }

    /// The fault (if any) planned for attempt `attempt` of item `item`
    /// at `site`.
    pub fn fault_for(&self, site: FaultSite, item: usize, attempt: u32) -> Option<FaultKind> {
        if !self.is_enabled() {
            return None;
        }
        for &(s, i, a, kind) in &self.targeted {
            if s == site && i == item && a == attempt {
                return Some(kind);
            }
        }
        let r = self.random.as_ref()?;
        if attempt != 0 {
            return None;
        }
        let mut h = SplitMix64::new(
            r.seed ^ (site.code() << 48) ^ (item as u64).wrapping_mul(0x9E37_79B9),
        );
        if h.next_f32() >= r.rate {
            return None;
        }
        Some(r.kinds[h.below(r.kinds.len() as u64) as usize])
    }
}

/// What a guarded run observed: per-class fault counts, how many
/// re-executions were scheduled, and the exact extra HBM traffic those
/// re-executions re-did (the chaos wall asserts it against the
/// per-item `sim::cost` forms access-for-access).
#[derive(Clone, Debug, Default)]
pub struct FaultReport {
    /// Re-executions scheduled (any cause).
    pub retries: u64,
    /// Contained worker panics (injected or genuine).
    pub panics: u64,
    /// Injected poisoned partials caught by the guardrail.
    pub poisoned: u64,
    /// Dropped completion records (work re-ran).
    pub dropped: u64,
    /// Delayed (straggler) items — completed late, no retry.
    pub delayed: u64,
    /// Guardrail trips on genuinely non-finite output (not injected).
    pub guardrail: u64,
    /// HBM traffic of faulted attempts whose work fully ran — exactly
    /// the traffic the retries re-do. Genuine mid-item panics have
    /// unknowable partial traffic and are excluded.
    pub retry_hbm: Hbm,
    /// Dead shards the sharded schedules classified instead of silently
    /// substituting: (shard index, reason).
    pub dead_shards: Vec<(usize, &'static str)>,
}

impl FaultReport {
    /// Fold another phase's report into this one.
    pub fn merge(&mut self, other: &FaultReport) {
        self.retries += other.retries;
        self.panics += other.panics;
        self.poisoned += other.poisoned;
        self.dropped += other.dropped;
        self.delayed += other.delayed;
        self.guardrail += other.guardrail;
        self.retry_hbm.merge(&other.retry_hbm);
        self.dead_shards.extend(other.dead_shards.iter().cloned());
    }

    /// Total faults observed (excluding benign delays).
    pub fn faults(&self) -> u64 {
        self.panics + self.poisoned + self.dropped + self.guardrail
    }
}

/// Typed errors of the attention execution plane — the replacement for
/// hot-path panics on the fallible `Exec`-driven entry points.
#[derive(Clone, Debug, PartialEq)]
pub enum AttnError {
    /// A work item's output failed the finiteness guardrail on every
    /// attempt: NaN/Inf with (slice, batch, head, block) provenance.
    /// `block` is the q row block for forward/dQ items and the key
    /// column block for dK/dV items.
    NonFinite {
        site: FaultSite,
        slice: usize,
        batch: usize,
        head: usize,
        block: usize,
        attempts: u32,
    },
    /// A work item kept failing (panic or dropped merge) past its
    /// attempt budget.
    ItemFailed { site: FaultSite, slice: usize, block: usize, attempts: u32, message: String },
    /// A sharded schedule was handed a key range it cannot explain —
    /// which shard, its global key window, and why.
    ShardConfig { shard: usize, lo: usize, hi: usize, reason: String },
    /// A self-check invariant broke: which one, and by how much.
    Preflight { invariant: &'static str, detail: String },
}

impl AttnError {
    /// Enrich pool provenance (flat slice index) with the batched
    /// layout's (batch, head) coordinates.
    pub(crate) fn located(self, heads: usize) -> AttnError {
        match self {
            AttnError::NonFinite { site, slice, block, attempts, .. } if heads > 0 => {
                AttnError::NonFinite {
                    site,
                    slice,
                    batch: slice / heads,
                    head: slice % heads,
                    block,
                    attempts,
                }
            }
            e => e,
        }
    }
}

impl std::fmt::Display for AttnError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AttnError::NonFinite { site, slice, batch, head, block, attempts } => write!(
                f,
                "{site}: non-finite output in slice {slice} (batch {batch}, head {head}), \
                 block {block} — still non-finite after {attempts} attempt(s)"
            ),
            AttnError::ItemFailed { site, slice, block, attempts, message } => write!(
                f,
                "{site}: work item (slice {slice}, block {block}) failed after {attempts} \
                 attempt(s): {message}"
            ),
            AttnError::ShardConfig { shard, lo, hi, reason } => {
                write!(f, "shard {shard} over global keys [{lo}, {hi}): {reason}")
            }
            AttnError::Preflight { invariant, detail } => {
                write!(f, "preflight invariant '{invariant}' broke: {detail}")
            }
        }
    }
}

impl std::error::Error for AttnError {}

/// Behaviors the guarded pool needs from a work item: provenance, a
/// reset to the pre-run (all-zero) window state so a retry reproduces a
/// fresh run bit for bit (the backward sweeps *accumulate* into their
/// windows), the finiteness guardrail, and NaN scribbling for injection.
pub(crate) trait PoolItem: Send + 'static {
    /// (slice, block) provenance for typed errors.
    fn id(&self) -> (usize, usize);
    /// Zero the output windows back to their pre-run state.
    fn reset(&mut self);
    /// Guardrail scan: true iff every output value is defined. A
    /// logsumexp of -inf is the defined all-masked value and passes.
    fn check_finite(&self) -> bool;
    /// Scribble NaN over the output windows (fault injection only).
    fn poison(&mut self);
    /// Audit-mode claim manifest: one [`SlotClaim`] per output window
    /// this item owns. The pool checks within-run disjointness against
    /// the claims' addresses and fingerprints their (field, length)
    /// shape across runs — see `attn::audit`.
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim>;
}

/// Unwind payload of an injected [`FaultKind::WorkerPanic`]: carries the
/// attempt's exact HBM counter so retry traffic stays accountable, and
/// travels via `resume_unwind` so the global panic hook (and its stderr
/// backtrace) is skipped for planned chaos.
pub(crate) struct InjectedPanic(pub Hbm);

/// Best-effort panic payload → message (for `AttnError::ItemFailed`).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if payload.is::<InjectedPanic>() {
        "injected worker panic".to_string()
    } else {
        "worker panicked (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_injects_nothing() {
        let plan = FaultPlan::none();
        assert!(!plan.is_enabled());
        for item in 0..64 {
            for attempt in 0..MAX_ATTEMPTS {
                assert_eq!(plan.fault_for(FaultSite::BatchedFwd, item, attempt), None);
            }
        }
    }

    #[test]
    fn targeted_plan_hits_exact_coordinates_only() {
        let plan = FaultPlan::none()
            .with(FaultSite::BatchedFwd, 3, 0, FaultKind::WorkerPanic)
            .with(FaultSite::BatchedFwd, 3, 1, FaultKind::PoisonedPartial);
        assert_eq!(plan.fault_for(FaultSite::BatchedFwd, 3, 0), Some(FaultKind::WorkerPanic));
        assert_eq!(plan.fault_for(FaultSite::BatchedFwd, 3, 1), Some(FaultKind::PoisonedPartial));
        assert_eq!(plan.fault_for(FaultSite::BatchedFwd, 3, 2), None);
        assert_eq!(plan.fault_for(FaultSite::BatchedFwd, 2, 0), None);
        assert_eq!(plan.fault_for(FaultSite::BatchedDq, 3, 0), None);
    }

    #[test]
    fn seeded_plan_is_deterministic_and_first_attempt_only() {
        let kinds = [FaultKind::WorkerPanic, FaultKind::DroppedMerge];
        let a = FaultPlan::seeded(0xC0FFEE, 0.5, &kinds);
        let b = FaultPlan::seeded(0xC0FFEE, 0.5, &kinds);
        let mut hits = 0usize;
        for item in 0..256 {
            let fa = a.fault_for(FaultSite::TreePartial, item, 0);
            assert_eq!(fa, b.fault_for(FaultSite::TreePartial, item, 0), "item {item}");
            if let Some(k) = fa {
                hits += 1;
                assert!(kinds.contains(&k));
            }
            // Retries never re-fault in random mode.
            assert_eq!(a.fault_for(FaultSite::TreePartial, item, 1), None);
            assert_eq!(a.fault_for(FaultSite::TreePartial, item, 2), None);
        }
        assert!((64..192).contains(&hits), "rate 0.5 should hit roughly half: {hits}");
        // Different sites draw different streams.
        let same_site = (0..256)
            .filter(|&i| {
                a.fault_for(FaultSite::TreePartial, i, 0) == a.fault_for(FaultSite::RingFwd, i, 0)
            })
            .count();
        assert!(same_site < 256, "site must enter the coordinate hash");
    }

    #[test]
    fn error_display_carries_provenance() {
        let e = AttnError::NonFinite {
            site: FaultSite::BatchedFwd,
            slice: 5,
            batch: 1,
            head: 2,
            block: 3,
            attempts: 3,
        };
        let msg = e.located(3).to_string();
        assert!(msg.contains("batch 1"), "{msg}");
        assert!(msg.contains("head 2"), "{msg}");
        assert!(msg.contains("block 3"), "{msg}");
        let s = AttnError::ShardConfig {
            shard: 2,
            lo: 64,
            hi: 128,
            reason: "every mask block in the shard's window is zero".into(),
        };
        assert!(s.to_string().contains("shard 2"), "{s}");
    }

    #[test]
    fn report_merge_accumulates() {
        let mut a = FaultReport { retries: 1, panics: 1, ..Default::default() };
        a.retry_hbm.load(10);
        let mut b = FaultReport { retries: 2, poisoned: 1, delayed: 3, ..Default::default() };
        b.retry_hbm.store(5);
        b.dead_shards.push((1, "beyond kv_len"));
        a.merge(&b);
        assert_eq!(a.retries, 3);
        assert_eq!(a.faults(), 2);
        assert_eq!(a.delayed, 3);
        assert_eq!((a.retry_hbm.loads, a.retry_hbm.stores), (10, 5));
        assert_eq!(a.dead_shards.len(), 1);
    }
}
