//! Fast exact forward kernel (FlashAttention-2-style) — the production half
//! of the two-kernel policy (see the `attn` module docs).
//!
//! Differences from the faithful Algorithm 1 mirror in `attn::flash`, each
//! one of the overheads FlashAttention-2 (Dao, 2023) identifies:
//!
//! * **Q-outer loop order.** The outer loop walks Q row blocks; each row
//!   block's accumulators (unnormalised O~, running max m, running sum l)
//!   live on chip for the entire K/V sweep and are written to HBM exactly
//!   once. Counted O/stats store traffic drops from Θ(T_c·(N·d + 2N))
//!   (Algorithm 1 lines 2, 12-13) to exactly N·d + N.
//! * **Single normalisation epilogue.** No per-tile diag(l)⁻¹ rescale: the
//!   division by l happens once per row after the sweep, and the (l, m)
//!   pair collapses into one logsumexp statistic L = m + ln(l) (Rabe &
//!   Staats 2021) — all the backward pass needs ([`AttnStats`]).
//! * **Row-block parallelism.** Output rows are disjoint across Q row
//!   blocks, so blocks fan out over `std::thread::scope` workers with zero
//!   synchronisation (the same worker pattern as `attn::distributed`, one
//!   hierarchy level down). Per-block arithmetic is independent of the
//!   partition, so output is **bitwise identical for any worker count**.
//!   Callers fold batch·head slices into the same pool by invoking the
//!   kernel per slice with `workers` spread across slices.
//! * **Register-blocked micro-kernels.** S = tau·Q·Kᵀ and the P̃·V update
//!   run through `tensor::dot4` / `tensor::pv_accum` (4-wide unrolled
//!   accumulators) into scratch buffers allocated once per worker — no
//!   allocation inside the tile loop, unlike the reference kernel's
//!   per-tile `matmul_bt`.
//!
//! The kernel is exact: parity with `flash_forward` / `standard_forward`
//! (including causal, padding and dropout) is property-tested below.

use super::flash::{tile_fully_unmasked, Blocks};
use super::masks::{dropout_scale, masked_score, NEG_INF};
use super::{AttnConfig, AttnOutput, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::{matmul_bt_scaled_into, pv_accum, Tensor};

/// Forward outputs of the fast kernel: O plus the per-row logsumexp.
#[derive(Clone, Debug)]
pub struct Flash2Output {
    pub o: Tensor,
    /// L_i = m_i + ln(l_i) — the single softmax statistic per row.
    pub lse: Vec<f32>,
}

impl Flash2Output {
    /// Borrow the statistics for the backward pass.
    pub fn stats(&self) -> AttnStats<'_> {
        AttnStats::Lse(&self.lse)
    }

    /// Convert to the (l, m)-pair output type: (l, m) = (1, L) is a valid
    /// decomposition (l·eᵐ = e^L), so merge/consumer code written against
    /// [`AttnOutput`] — e.g. `attn::distributed::merge_partials` — works
    /// unchanged.
    pub fn into_attn_output(self) -> AttnOutput {
        let n = self.lse.len();
        AttnOutput { o: self.o, l: vec![1.0; n], m: self.lse }
    }
}

/// Fast exact forward. q: [n, d]; k, v: [n_k, d] (rectangular shapes serve
/// the sequence-parallel sharded path). `workers` bounds the thread count;
/// the result is bitwise independent of it.
pub fn flash2_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> Flash2Output {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    let tau = cfg.tau_for(d);
    let kv_len = cfg.kv_len.unwrap_or(n_k).min(n_k);
    let b_r = blocks.b_r;
    let t_r = n.div_ceil(b_r);

    let mut o = Tensor::zeros(&[n, d]);
    let mut lse = vec![0.0f32; n];
    if t_r == 0 || n_k == 0 {
        return Flash2Output { o, lse };
    }

    let w = workers.max(1).min(t_r);
    let chunk = t_r.div_ceil(w);

    std::thread::scope(|scope| {
        // Carve the output into disjoint per-worker windows: worker wi owns
        // row blocks [wi*chunk, (wi+1)*chunk)— a contiguous row range, so
        // chunks_mut yields exactly one window per (nonempty) worker.
        let o_chunks = o.data.chunks_mut(chunk * b_r * d);
        let lse_chunks = lse.chunks_mut(chunk * b_r);
        let mut handles = Vec::new();
        for (wi, (o_mine, lse_mine)) in o_chunks.zip(lse_chunks).enumerate() {
            let rb_lo = wi * chunk;
            let rb_hi = ((wi + 1) * chunk).min(t_r);
            handles.push(scope.spawn(move || {
                row_block_sweep(q, k, v, cfg, blocks, tau, kv_len, rb_lo, rb_hi, o_mine, lse_mine)
            }));
        }
        // Per-worker HBM counters merge associatively: totals are exact and
        // independent of the partition.
        for h in handles {
            let local = h.join().expect("flash2 worker panicked");
            hbm.merge(&local);
        }
    });

    Flash2Output { o, lse }
}

/// Sequential sweep over row blocks [rb_lo, rb_hi): the whole K/V stream
/// per block with on-chip accumulators, one epilogue store per block.
#[allow(clippy::too_many_arguments)]
fn row_block_sweep(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_len: usize,
    rb_lo: usize,
    rb_hi: usize,
    o_out: &mut [f32],
    lse_out: &mut [f32],
) -> Hbm {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_c = n_k.div_ceil(b_c);
    let row_base = rb_lo * b_r;
    let mut hbm = Hbm::new();

    // Worker-local scratch, allocated once (nothing allocates in the loop).
    let mut s_buf = vec![0.0f32; b_r * b_c];
    let mut acc = vec![0.0f32; b_r * d]; // unnormalised O~
    let mut m_run = vec![f32::NEG_INFINITY; b_r];
    let mut l_run = vec![0.0f32; b_r];

    for i in rb_lo..rb_hi {
        let r0 = i * b_r;
        let r1 = ((i + 1) * b_r).min(n);
        let br = r1 - r0;
        // Q_i is loaded once per row block; O/l/m never round-trip to HBM —
        // they live in `acc`/`m_run`/`l_run` until the epilogue.
        hbm.load(br * d);
        let q_rows = &q.data[r0 * d..r1 * d];
        acc[..br * d].fill(0.0);
        m_run[..br].fill(f32::NEG_INFINITY);
        l_run[..br].fill(0.0);

        for j in 0..t_c {
            let c0 = j * b_c;
            let c1 = ((j + 1) * b_c).min(n_k);
            let bc = c1 - c0;
            // Above-diagonal tiles contribute nothing (same skip as flash).
            if cfg.causal && c0 > r1 - 1 {
                continue;
            }
            // K_j, V_j stream through SRAM once per row block.
            hbm.load(2 * bc * d);
            let kj = &k.data[c0 * d..c1 * d];
            let vj = &v.data[c0 * d..c1 * d];

            // S = tau Q_i K_jᵀ, register-blocked, into the reused buffer.
            let s = &mut s_buf[..br * bc];
            matmul_bt_scaled_into(q_rows, kj, d, tau, s);
            // Causal fast path: fully-live tiles skip the mask pass.
            if !tile_fully_unmasked(cfg.causal, r0, c1, kv_len) {
                for rr in 0..br {
                    for cc in 0..bc {
                        let x = s[rr * bc + cc];
                        s[rr * bc + cc] =
                            masked_score(x, r0 + rr, c0 + cc, cfg.causal, kv_len);
                    }
                }
            }

            // Online softmax with deferred normalisation: rescale the
            // accumulators only when the running max actually moves.
            for rr in 0..br {
                let row = r0 + rr;
                let srow = &mut s[rr * bc..(rr + 1) * bc];
                let m_tile = srow.iter().cloned().fold(NEG_INF, f32::max);
                let m_new = m_run[rr].max(m_tile);
                let alpha = (m_run[rr] - m_new).exp(); // exp(-inf)=0 first tile
                let arow = &mut acc[rr * d..(rr + 1) * d];
                if alpha != 1.0 {
                    l_run[rr] *= alpha;
                    for x in arow.iter_mut() {
                        *x *= alpha;
                    }
                }
                m_run[rr] = m_new;
                let mut l_tile = 0.0f32;
                for pw in srow.iter_mut() {
                    *pw = (*pw - m_new).exp();
                    l_tile += *pw;
                }
                // As in flash/standard: the normaliser excludes dropout.
                l_run[rr] += l_tile;
                if cfg.dropout_p > 0.0 {
                    for (cc, pw) in srow.iter_mut().enumerate() {
                        *pw *= dropout_scale(
                            cfg.bh_index,
                            row,
                            c0 + cc,
                            n,
                            cfg.dropout_seed,
                            cfg.dropout_p,
                        );
                    }
                }
                pv_accum(srow, vj, d, arow);
            }
        }

        // Epilogue: one division per row, one HBM store per row block
        // (O rows + a single logsumexp stat each).
        for rr in 0..br {
            let inv = 1.0 / l_run[rr].max(1e-37);
            let arow = &acc[rr * d..(rr + 1) * d];
            let out_off = (r0 - row_base + rr) * d;
            let orow = &mut o_out[out_off..out_off + d];
            for c in 0..d {
                orow[c] = arow[c] * inv;
            }
            lse_out[r0 - row_base + rr] = m_run[rr] + l_run[rr].max(1e-37).ln();
        }
        hbm.store(br * d + br);
    }

    hbm
}

/// Fixed cross-kernel agreement probe (causal + padding + rectangular-ish
/// shape, multi-threaded): max |flash2 - flash| over the workload. Used by
/// the coordinator preflight before any training/serving runs.
pub fn self_check() -> f32 {
    use crate::util::rng::SplitMix64;
    let (n, d) = (48usize, 16usize);
    let mut rng = SplitMix64::new(0xF1A5_42);
    let q = Tensor::randn(&[n, d], &mut rng, 1.0);
    let k = Tensor::randn(&[n, d], &mut rng, 1.0);
    let v = Tensor::randn(&[n, d], &mut rng, 1.0);
    let cfg = AttnConfig { causal: true, kv_len: Some(37), ..Default::default() };
    let blocks = Blocks::explicit(8, 8);
    let reference = super::flash::flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
    let fast = flash2_forward(&q, &k, &v, &cfg, blocks, 3, &mut Hbm::new());
    let mut diff = reference.o.max_abs_diff(&fast.o);
    for r in 0..n {
        diff = diff.max((reference.stats().lse(r) - fast.lse[r]).abs());
    }
    diff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash::{flash_backward, flash_forward};
    use crate::attn::standard::{standard_backward, standard_forward};
    use crate::tensor::dot4;
    use crate::util::prop::{for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn matches_standard_forward() {
        let (q, k, v) = qkv(48, 8, 0);
        let std = standard_forward(&q, &k, &v, &AttnConfig::default(), &mut Hbm::new());
        let fast =
            flash2_forward(&q, &k, &v, &AttnConfig::default(), Blocks::explicit(8, 16), 2, &mut Hbm::new());
        assert!(std.o.max_abs_diff(&fast.o) < 1e-5);
        for r in 0..48 {
            assert!(
                (std.stats().lse(r) - fast.lse[r]).abs() < 1e-4,
                "lse row {r}: {} vs {}",
                std.stats().lse(r),
                fast.lse[r]
            );
        }
    }

    #[test]
    fn property_parity_flash2_vs_flash_vs_standard() {
        // The ISSUE grid: (n, d, B_r, B_c, causal, kv_len, dropout_p, workers).
        for_each_case("flash2_parity", 20, |rng| {
            let n = usize_in(rng, 2, 48);
            let d = *crate::util::prop::choose(rng, &[2usize, 4, 8]);
            let b_r = usize_in(rng, 1, n);
            let b_c = usize_in(rng, 1, n);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let cfg = AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let blocks = Blocks::explicit(b_r, b_c);
            let std = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            let fla = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
            let fa2 = flash2_forward(&q, &k, &v, &cfg, blocks, workers, &mut Hbm::new());
            let ctx = format!(
                "n={n} d={d} blocks=({b_r},{b_c}) causal={causal} kv_len={kv_len:?} p={dropout_p} w={workers}"
            );
            assert!(std.o.max_abs_diff(&fa2.o) < 1e-4, "vs standard: {ctx}");
            assert!(fla.o.max_abs_diff(&fa2.o) < 1e-4, "vs flash: {ctx}");
        });
    }

    #[test]
    fn deterministic_across_worker_counts() {
        // Per-row-block arithmetic is partition-independent, so the
        // epilogue output must be bitwise identical for any worker count.
        let (q, k, v) = qkv(64, 16, 3);
        let cfg = AttnConfig::causal();
        let blocks = Blocks::explicit(8, 16);
        let base = flash2_forward(&q, &k, &v, &cfg, blocks, 1, &mut Hbm::new());
        for workers in [2usize, 3, 4, 8, 64] {
            let multi = flash2_forward(&q, &k, &v, &cfg, blocks, workers, &mut Hbm::new());
            assert_eq!(base.o.data, multi.o.data, "O not bitwise equal at workers={workers}");
            assert_eq!(base.lse, multi.lse, "lse not bitwise equal at workers={workers}");
        }
    }

    #[test]
    fn hbm_accounting_independent_of_worker_count() {
        let (q, k, v) = qkv(64, 8, 4);
        let blocks = Blocks::explicit(16, 16);
        let mut h1 = Hbm::new();
        flash2_forward(&q, &k, &v, &AttnConfig::default(), blocks, 1, &mut h1);
        let mut h4 = Hbm::new();
        flash2_forward(&q, &k, &v, &AttnConfig::default(), blocks, 4, &mut h4);
        assert_eq!(h1.loads, h4.loads);
        assert_eq!(h1.stores, h4.stores);
    }

    #[test]
    fn o_and_stats_written_exactly_once() {
        // The tentpole IO claim: store traffic is exactly N·d + N floats —
        // one O row + one stat per row, once — for any tiling.
        for (n, d, br, bc) in [(64usize, 8usize, 16usize, 16usize), (48, 4, 8, 32), (40, 8, 16, 8)] {
            let (q, k, v) = qkv(n, d, 5);
            let mut hbm = Hbm::new();
            flash2_forward(&q, &k, &v, &AttnConfig::default(), Blocks::explicit(br, bc), 2, &mut hbm);
            assert_eq!(hbm.stores, (n * d + n) as u64, "n={n} d={d} blocks=({br},{bc})");
        }
    }

    #[test]
    fn backward_consumes_lse_stats() {
        // flash2 forward -> Algorithm 4 backward via AttnStats::Lse.
        let (q, k, v) = qkv(32, 8, 6);
        let cfg = AttnConfig::causal();
        let blocks = Blocks::explicit(8, 8);
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, 2, &mut Hbm::new());
        let mut rng = SplitMix64::new(9);
        let dout = Tensor::randn(&[32, 8], &mut rng, 1.0);
        let fg =
            flash_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new());
        let sg = standard_backward(&q, &k, &v, &dout, &cfg, &mut Hbm::new());
        assert!(fg.dq.max_abs_diff(&sg.dq) < 1e-4);
        assert!(fg.dk.max_abs_diff(&sg.dk) < 1e-4);
        assert!(fg.dv.max_abs_diff(&sg.dv) < 1e-4);
    }

    #[test]
    fn rectangular_kv_matches_standard_padding() {
        // Rectangular K/V (n_k != n) is what the sharded path feeds.
        let mut rng = SplitMix64::new(8);
        let q = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let k = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let v = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let cfg = AttnConfig { kv_len: Some(33), tau: Some(0.25), ..Default::default() };
        let fast = flash2_forward(&q, &k, &v, &cfg, Blocks::explicit(8, 8), 3, &mut Hbm::new());
        // Oracle: dense softmax over the first kv_len keys.
        let tau = 0.25f32;
        for r in 0..24 {
            let mut scores: Vec<f32> =
                (0..33).map(|c| tau * dot4(q.row(r), k.row(c))).collect();
            let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - mx).exp();
                z += *s;
            }
            for c in 0..8 {
                let expect: f32 =
                    (0..33).map(|cc| scores[cc] / z * v.row(cc)[c]).sum();
                assert!((fast.o.row(r)[c] - expect).abs() < 1e-4, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn into_attn_output_round_trips_stats() {
        let (q, k, v) = qkv(16, 4, 10);
        let fast = flash2_forward(&q, &k, &v, &AttnConfig::default(), Blocks::explicit(4, 4), 1, &mut Hbm::new());
        let lse_before = fast.lse.clone();
        let out = fast.into_attn_output();
        for r in 0..16 {
            assert!((out.stats().lse(r) - lse_before[r]).abs() < 1e-6);
        }
    }

    #[test]
    fn self_check_is_tight() {
        assert!(self_check() < 1e-4, "self_check diff {}", self_check());
    }
}
