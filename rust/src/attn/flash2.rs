//! Fast exact forward **and backward** kernels (FlashAttention-2-style) —
//! the production half of the two-kernel policy (see the `attn` module
//! docs).
//!
//! Differences from the faithful Algorithm 1 mirror in `attn::flash`, each
//! one of the overheads FlashAttention-2 (Dao, 2023) identifies:
//!
//! * **Q-outer loop order.** The outer loop walks Q row blocks; each row
//!   block's accumulators (unnormalised O~, running max m, running sum l)
//!   live on chip for the entire K/V sweep and are written to HBM exactly
//!   once. Counted O/stats store traffic drops from Θ(T_c·(N·d + 2N))
//!   (Algorithm 1 lines 2, 12-13) to exactly N·d + N.
//! * **Single normalisation epilogue.** No per-tile diag(l)⁻¹ rescale: the
//!   division by l happens once per row after the sweep, and the (l, m)
//!   pair collapses into one logsumexp statistic L = m + ln(l) (Rabe &
//!   Staats 2021) — all the backward pass needs ([`AttnStats`]).
//! * **Row-block parallelism.** Output rows are disjoint across Q row
//!   blocks, so blocks fan out over `std::thread::scope` workers with zero
//!   synchronisation (the same worker pattern as `attn::distributed`, one
//!   hierarchy level down). Per-block arithmetic is independent of the
//!   partition, so output is **bitwise identical for any worker count**.
//!   Batch·head workloads do NOT call this kernel per slice on hot paths:
//!   `attn::batched` flattens every batch·head·row-block work item into
//!   one pool (`flash2_forward_batched` / `flash2_backward_batched`),
//!   reusing the per-block sweeps below — the per-slice entry points here
//!   remain the reference the batched scheduler is tested against.
//! * **Register-blocked micro-kernels.** S = tau·Q·Kᵀ and the P̃·V update
//!   run through `tensor::dot4` / `tensor::pv_accum` (4-wide unrolled
//!   accumulators) into scratch buffers allocated once per worker — no
//!   allocation inside the tile loop, unlike the reference kernel's
//!   per-tile `matmul_bt`.
//!
//! The same ideas give [`flash2_backward`], the fast gradient kernel:
//!
//! * **Two-phase split.** dQ rows are disjoint across Q row blocks and
//!   dK/dV rows are disjoint across K/V column blocks, so instead of
//!   Algorithm 4's single K/V-outer sweep (which read-modify-writes dQ_i
//!   to HBM on *every* inner tile), phase 1 sweeps Q row blocks with the
//!   dQ accumulator on chip for the whole K/V stream (written once) and
//!   phase 2 sweeps K/V column blocks with dK~/dV~ on chip (written
//!   once). Each phase fans out over `std::thread::scope` workers with
//!   bitwise worker-count-independent output, exactly like the forward.
//! * **Single-statistic recomputation.** Both phases rebuild
//!   `P_ij = exp(s_ij − L_i)` from the forward's logsumexp (Rabe & Staats
//!   2021) via the same register-blocked `tensor::dot4` path — no (l, m)
//!   pair, no per-tile rescale.
//! * **D precomputed in one epilogue pass.** `D_i = rowsum(dO ∘ O)` is
//!   computed once up front (2·N·d loads, N stores) instead of
//!   re-deriving it inside every tile.
//! * **Causal tile skip.** Tiles entirely above the diagonal are skipped
//!   in both phases, same as the forwards.
//!
//! Fully-masked rows have defined semantics end to end: the forward emits
//! a zero output row with `lse = -inf` (no NaN/Inf), and the backward
//! treats `lse = -inf` as "no probability mass" — zero gradient
//! contribution.
//!
//! Both kernels are exact: parity with the `flash`/`standard` mirrors
//! (including causal, padding, dropout and rectangular K/V) is
//! property-tested below.

use super::exec::Exec;
use super::faults::{AttnError, FaultReport, FaultSite, PoolItem};
use super::flash::{tile_fully_unmasked, Blocks};
use super::masks::{dropout_scale, masked_score, NEG_INF};
use super::{AttnConfig, AttnGrads, AttnOutput, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::{dot4, matmul_bt_scaled_into, pv_accum, Tensor};

/// Forward outputs of the fast kernel: O plus the per-row logsumexp.
#[derive(Clone, Debug)]
pub struct Flash2Output {
    pub o: Tensor,
    /// L_i = m_i + ln(l_i) — the single softmax statistic per row.
    pub lse: Vec<f32>,
}

impl Flash2Output {
    /// Borrow the statistics for the backward pass.
    pub fn stats(&self) -> AttnStats<'_> {
        AttnStats::Lse(&self.lse)
    }

    /// Convert to the (l, m)-pair output type: (l, m) = (1, L) is a valid
    /// decomposition (l·eᵐ = e^L), so merge/consumer code written against
    /// [`AttnOutput`] — e.g. `attn::distributed::merge_partials` — works
    /// unchanged.
    pub fn into_attn_output(self) -> AttnOutput {
        let n = self.lse.len();
        AttnOutput { o: self.o, l: vec![1.0; n], m: self.lse }
    }
}

/// Fast exact forward. q: [n, d]; k, v: [n_k, d] (rectangular shapes serve
/// the sequence-parallel sharded path). `exec.workers()` bounds the thread
/// count; the result is bitwise independent of it. This per-slice
/// reference kernel always runs per-call scoped threads — it is the
/// oracle the pooled schedules are bitwise-tested against — so the
/// handle's persistent/scoped mode and fault plan are intentionally
/// ignored here.
// lint::allow(R6, per-call scoped reference oracle: runs its own scoped threads by design and never touches the pool sink)
pub fn flash2_forward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Flash2Output {
    let workers = exec.workers();
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let b_r = blocks.b_r;
    let t_r = n.div_ceil(b_r);

    let mut o = Tensor::zeros(&[n, d]);
    let mut lse = vec![0.0f32; n];
    if t_r == 0 || n_k == 0 {
        // No keys at all: every row is fully masked — same defined
        // semantics as the masked epilogue path (zero rows, lse = -inf).
        lse.fill(f32::NEG_INFINITY);
        return Flash2Output { o, lse };
    }

    let w = workers.max(1).min(t_r);
    let chunk = t_r.div_ceil(w);
    let (qd, kd, vd) = (q.data.as_slice(), k.data.as_slice(), v.data.as_slice());

    // lint::allow(R1, per-slice reference kernel: the oracle the pooled schedules are bitwise-tested against)
    std::thread::scope(|scope| {
        // Carve the output into disjoint per-worker windows: worker wi owns
        // row blocks [wi*chunk, (wi+1)*chunk)— a contiguous row range, so
        // chunks_mut yields exactly one window per (nonempty) worker.
        // lint::allow(R5, oracle-only carve: disjoint per-worker O windows; traffic is counted inside row_block_sweep)
        let o_chunks = o.data.chunks_mut(chunk * b_r * d);
        // lint::allow(R5, oracle-only carve: disjoint per-worker lse windows; traffic is counted inside row_block_sweep)
        let lse_chunks = lse.chunks_mut(chunk * b_r);
        let mut handles = Vec::new();
        for (wi, (o_mine, lse_mine)) in o_chunks.zip(lse_chunks).enumerate() {
            let rb_lo = wi * chunk;
            let rb_hi = ((wi + 1) * chunk).min(t_r);
            handles.push(scope.spawn(move || {
                row_block_sweep(
                    qd, kd, vd, n, n_k, d, cfg, blocks, tau, kv_limit, rb_lo, rb_hi, o_mine,
                    lse_mine,
                )
            }));
        }
        // Per-worker HBM counters merge associatively: totals are exact and
        // independent of the partition.
        for h in handles {
            let local = h.join().expect("flash2 worker panicked");
            hbm.merge(&local);
        }
    });

    Flash2Output { o, lse }
}

/// On-chip online-softmax state for one Q row block: the unnormalised
/// O~ accumulator, the running max/sum pair and the S scratch tile.
/// [`stream_kv`] advances it over one K/V slice and is **resumable**:
/// threading one state through consecutive slices of the key sequence
/// in global order performs bit-for-bit the arithmetic of a single call
/// over the concatenated keys, provided every slice spans whole column
/// tiles. That resumability is what makes the sharded ring schedule
/// (`attn::distributed`) bitwise identical to this single-device kernel.
pub(crate) struct RowBlockState {
    pub acc: Vec<f32>, // unnormalised O~, [b_r, d]
    pub m_run: Vec<f32>,
    pub l_run: Vec<f32>,
    s_buf: Vec<f32>, // S tile scratch, [b_r, b_c]
}

impl RowBlockState {
    pub(crate) fn new(blocks: Blocks, d: usize) -> RowBlockState {
        RowBlockState {
            acc: vec![0.0; blocks.b_r * d],
            m_run: vec![f32::NEG_INFINITY; blocks.b_r],
            l_run: vec![0.0; blocks.b_r],
            s_buf: vec![0.0; blocks.b_r * blocks.b_c],
        }
    }

    pub(crate) fn reset(&mut self, br: usize, d: usize) {
        self.acc[..br * d].fill(0.0);
        self.m_run[..br].fill(f32::NEG_INFINITY);
        self.l_run[..br].fill(0.0);
    }
}

/// Stream one K/V slice (local columns [0, n_k), global offset
/// `cfg.kv_offset`) through the online softmax of query rows [r0, r1).
/// All mask and dropout decisions are made in **global** key
/// coordinates: `kv_limit` is the global padding limit
/// (`AttnConfig::kv_limit` of the *whole* key range) and the dropout
/// counter hashes `kv_offset + local_col` — a shard therefore computes
/// exactly what the unsharded kernel computes for the same columns.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_kv(
    state: &mut RowBlockState,
    q_rows: &[f32],
    k: &[f32],
    v: &[f32],
    n_k: usize,
    n: usize,
    d: usize,
    r0: usize,
    r1: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    hbm: &mut Hbm,
) {
    stream_kv_filtered(
        state, q_rows, k, v, n_k, n, d, r0, r1, cfg, blocks, tau, kv_limit, hbm, |_| true,
    );
}

/// [`stream_kv`] with a per-column-tile liveness filter: tile `j` (local
/// index) is processed only when `live(j)`. Skipped tiles are never
/// loaded — this is the Algorithm 5 zero-block skip expressed on the
/// fast pair's sweep, and it is the ONLY difference from the dense
/// sweep: a filter that always returns true runs the dense arithmetic
/// bit for bit, which is what makes `attn::block_sparse::block_sparse2_forward`
/// with a dense mask bitwise identical to [`flash2_forward`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_kv_filtered<F: Fn(usize) -> bool>(
    state: &mut RowBlockState,
    q_rows: &[f32],
    k: &[f32],
    v: &[f32],
    n_k: usize,
    n: usize,
    d: usize,
    r0: usize,
    r1: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    hbm: &mut Hbm,
    live: F,
) {
    let b_c = blocks.b_c;
    let t_c = n_k.div_ceil(b_c);
    let br = r1 - r0;
    let RowBlockState { acc, m_run, l_run, s_buf } = state;

    for j in 0..t_c {
        // Zero block (Algorithm 5 line 8): skip before any load.
        if !live(j) {
            continue;
        }
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        let bc = c1 - c0;
        let g0 = cfg.kv_offset + c0; // global column of the tile's first key
        // Above-diagonal tiles contribute nothing (same skip as flash),
        // judged on global columns so shards skip correctly.
        if cfg.causal && g0 > r1 - 1 {
            continue;
        }
        // K_j, V_j stream through SRAM once per row block.
        hbm.load(2 * bc * d);
        let kj = &k[c0 * d..c1 * d];
        let vj = &v[c0 * d..c1 * d];

        // S = tau Q_i K_jᵀ, register-blocked, into the reused buffer.
        let s = &mut s_buf[..br * bc];
        matmul_bt_scaled_into(q_rows, kj, d, tau, s);
        // Causal fast path: fully-live tiles skip the mask pass.
        if !tile_fully_unmasked(cfg.causal, r0, cfg.kv_offset + c1, kv_limit) {
            for rr in 0..br {
                for cc in 0..bc {
                    let x = s[rr * bc + cc];
                    s[rr * bc + cc] = masked_score(x, r0 + rr, g0 + cc, cfg.causal, kv_limit);
                }
            }
        }

        absorb_score_tile(acc, m_run, l_run, s, vj, br, bc, d, r0, g0, n, cfg);
    }
}

/// Absorb one masked score tile S (already τ-scaled and mask-applied)
/// into a row block's online-softmax state, in place: online softmax
/// with deferred normalisation — rescale the accumulators only when the
/// running max actually moves. `s` is consumed (overwritten with the P̃
/// weights).
///
/// This is the ONE body shared by the fused sweep
/// ([`stream_kv_filtered`], which computes S on chip and absorbs it
/// immediately) and the split-KV decode merge ([`absorb_scored_tiles`],
/// which replays spilled S tiles in global tile order) — sharing the
/// body is what makes [`flash2_decode`] bitwise identical to
/// [`flash2_forward`] by construction, not by tolerance. Takes no
/// [`Hbm`]: callers count the tile's traffic (K/V stream or S
/// spill/reload) before calling.
#[allow(clippy::too_many_arguments)]
fn absorb_score_tile(
    acc: &mut [f32],
    m_run: &mut [f32],
    l_run: &mut [f32],
    s: &mut [f32],
    vj: &[f32],
    br: usize,
    bc: usize,
    d: usize,
    r0: usize,
    g0: usize,
    n: usize,
    cfg: &AttnConfig,
) {
    for rr in 0..br {
        let row = r0 + rr;
        let srow = &mut s[rr * bc..(rr + 1) * bc];
        let m_tile = srow.iter().cloned().fold(NEG_INF, f32::max);
        // Fully-masked row slice: contributes no probability mass.
        // Folding it in would poison m_run with the NEG_INF sentinel
        // and make exp(s - m_new) = 1 for masked entries, so rows
        // with *no* live key anywhere would attend uniformly to
        // masked keys; skipping keeps them at (acc, l, m) =
        // (0, 0, -inf) and the epilogue gives them a zero output.
        if m_tile <= NEG_INF {
            continue;
        }
        let m_new = m_run[rr].max(m_tile);
        let alpha = (m_run[rr] - m_new).exp(); // exp(-inf)=0 first tile
        let arow = &mut acc[rr * d..(rr + 1) * d];
        if alpha != 1.0 {
            l_run[rr] *= alpha;
            for x in arow.iter_mut() {
                *x *= alpha;
            }
        }
        m_run[rr] = m_new;
        let mut l_tile = 0.0f32;
        for pw in srow.iter_mut() {
            *pw = (*pw - m_new).exp();
            l_tile += *pw;
        }
        // As in flash/standard: the normaliser excludes dropout.
        l_run[rr] += l_tile;
        if cfg.dropout_p > 0.0 {
            for (cc, pw) in srow.iter_mut().enumerate() {
                *pw *= dropout_scale(
                    cfg.bh_index,
                    row,
                    g0 + cc,
                    n,
                    cfg.dropout_seed,
                    cfg.dropout_p,
                );
            }
        }
        pv_accum(srow, vj, d, arow);
    }
}

/// Normalise a row block's streamed state into its output windows: one
/// division per row, one HBM store per row block (O rows + a single
/// logsumexp stat each). `o_out` is the block's [br, d] window; `lse_out`
/// its [br] window.
pub(crate) fn write_epilogue(
    state: &RowBlockState,
    br: usize,
    d: usize,
    o_out: &mut [f32],
    lse_out: &mut [f32],
    hbm: &mut Hbm,
) {
    for rr in 0..br {
        let orow = &mut o_out[rr * d..(rr + 1) * d];
        if state.l_run[rr] == 0.0 {
            // Every key masked for this row: zero output, lse = -inf
            // (log of zero mass) — defined, NaN/Inf-free semantics that
            // `merge_partials` and the backward both understand.
            orow.fill(0.0);
            lse_out[rr] = f32::NEG_INFINITY;
            continue;
        }
        let inv = 1.0 / state.l_run[rr];
        let arow = &state.acc[rr * d..(rr + 1) * d];
        for c in 0..d {
            orow[c] = arow[c] * inv;
        }
        lse_out[rr] = state.m_run[rr] + state.l_run[rr].ln();
    }
    hbm.store(br * d + br);
}

/// Sequential sweep over row blocks [rb_lo, rb_hi): the whole K/V stream
/// per block with on-chip accumulators, one epilogue store per block.
/// Operates on flat row-major slices (q: [n, d]; k, v: [n_k, d]) so the
/// batched scheduler (`attn::batched`) can dispatch single-block work
/// items through exactly this code path — per-block arithmetic is
/// self-contained, which is what makes every caller's output bitwise
/// independent of how blocks are distributed over workers. `kv_limit`
/// is the global padding limit (`cfg.kv_limit(n_k)`).
pub(crate) fn row_block_sweep(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    n_k: usize,
    d: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    rb_lo: usize,
    rb_hi: usize,
    o_out: &mut [f32],
    lse_out: &mut [f32],
) -> Hbm {
    let b_r = blocks.b_r;
    let mut hbm = Hbm::new();
    // Worker-local scratch, allocated once (nothing allocates in the loop).
    let mut state = RowBlockState::new(blocks, d);

    for i in rb_lo..rb_hi {
        let r0 = i * b_r;
        let r1 = ((i + 1) * b_r).min(n);
        let br = r1 - r0;
        // Q_i is loaded once per row block; O/l/m never round-trip to HBM —
        // they live in the on-chip state until the epilogue.
        hbm.load(br * d);
        state.reset(br, d);
        stream_kv(
            &mut state, &q[r0 * d..r1 * d], k, v, n_k, n, d, r0, r1, cfg, blocks, tau,
            kv_limit, &mut hbm,
        );
        let off = (i - rb_lo) * b_r;
        write_epilogue(
            &state,
            br,
            d,
            &mut o_out[off * d..off * d + br * d],
            &mut lse_out[off..off + br],
            &mut hbm,
        );
    }

    hbm
}

/// Column-tile range `[lo, hi)` of decode span `sp` when the KV axis's
/// `t_c` column tiles are split into spans of `span_tiles` tiles each
/// (the last span ragged).
fn span_tile_range(sp: usize, span_tiles: usize, t_c: usize) -> (usize, usize) {
    let lo = sp * span_tiles;
    let hi = ((sp + 1) * span_tiles).min(t_c);
    (lo, hi)
}

/// One split-KV decode work item: a span of KV column tiles scored
/// against the (short) Q block. The item owns the span's masked score
/// tiles — the "map" half of the decode kernel; the order-sensitive
/// online-softmax absorb happens at the merge site, in global tile
/// order, so the result is bitwise independent of how spans land on
/// workers.
pub(crate) struct DecodeItem {
    /// Span index along the KV axis.
    sp: usize,
    /// Column-tile range [tile_lo, tile_hi) this span covers.
    tile_lo: usize,
    tile_hi: usize,
    /// Masked score tiles, concatenated in tile order: one [n, bc]
    /// block per causally-live tile of the span. Masked entries hold
    /// the finite `NEG_INF` sentinel, so a NaN can only mean poison.
    s_win: Vec<f32>,
}

impl PoolItem for DecodeItem {
    fn id(&self) -> (usize, usize) {
        (0, self.sp)
    }

    fn reset(&mut self) {
        self.s_win.fill(0.0);
    }

    fn check_finite(&self) -> bool {
        self.s_win.iter().all(|x| x.is_finite())
    }

    fn poison(&mut self) {
        self.s_win.fill(f32::NAN);
    }

    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        vec![crate::attn::audit::SlotClaim::of("s", &self.s_win)]
    }
}

/// Decode item-side scoring accessor — the counted "map" half of the
/// split-KV decode. For each causally-live column tile of the span:
/// stream K_j once (bc·d loads), compute the τ-scaled masked score tile
/// exactly as [`stream_kv_filtered`] does (same `matmul_bt_scaled_into`
/// + `masked_score` pass, row block r0 = 0, r1 = n), and spill it to
/// HBM (n·bc stores). Q is loaded once per span (n·d) — the split-KV
/// replication cost the closed form charges per span.
#[allow(clippy::too_many_arguments)]
pub(crate) fn score_span_tiles(
    q_rows: &[f32],
    k: &[f32],
    n_k: usize,
    n: usize,
    d: usize,
    cfg: &AttnConfig,
    b_c: usize,
    tau: f32,
    kv_limit: usize,
    tile_lo: usize,
    tile_hi: usize,
    s_win: &mut [f32],
) -> Hbm {
    let mut hbm = Hbm::new();
    // Q is short (1-to-few rows) but every span re-reads it.
    hbm.load(n * d);
    let mut off = 0usize;
    for j in tile_lo..tile_hi {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        let bc = c1 - c0;
        let g0 = cfg.kv_offset + c0;
        // Above-diagonal tiles contribute nothing — the same skip as the
        // fused sweep with the whole Q block as one row block (r1 = n).
        if cfg.causal && g0 > n - 1 {
            continue;
        }
        // K_j streams through SRAM once per span.
        hbm.load(bc * d);
        let kj = &k[c0 * d..c1 * d];
        let s = &mut s_win[off..off + n * bc];
        matmul_bt_scaled_into(q_rows, kj, d, tau, s);
        // Same mask pass as the fused sweep; masked_score is the
        // identity on live entries, so values are bitwise identical.
        if !tile_fully_unmasked(cfg.causal, 0, cfg.kv_offset + c1, kv_limit) {
            for rr in 0..n {
                for cc in 0..bc {
                    let x = s[rr * bc + cc];
                    s[rr * bc + cc] = masked_score(x, rr, g0 + cc, cfg.causal, kv_limit);
                }
            }
        }
        // The span's masked score tile spills to HBM for the merge.
        hbm.store(n * bc);
        off += n * bc;
    }
    hbm
}

/// Decode merge-side absorb accessor — replays the spilled score tiles
/// in **global tile order** through [`absorb_score_tile`], the exact
/// body the fused sweep uses. Counts, per causally-live tile: the
/// spilled scores reloaded (n·bc) plus V_j streamed once (bc·d).
/// Because the absorb order and arithmetic are those of a single fused
/// sweep over the concatenated tiles, the state this produces is
/// bitwise identical to [`stream_kv`]'s for the same inputs —
/// independent of span size and of which worker scored which span.
#[allow(clippy::too_many_arguments)]
pub(crate) fn absorb_scored_tiles(
    state: &mut RowBlockState,
    s_all: &mut [f32],
    v: &[f32],
    n_k: usize,
    n: usize,
    d: usize,
    cfg: &AttnConfig,
    b_c: usize,
    hbm: &mut Hbm,
) {
    let t_c = n_k.div_ceil(b_c);
    let RowBlockState { acc, m_run, l_run, .. } = state;
    let mut off = 0usize;
    for j in 0..t_c {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        let bc = c1 - c0;
        let g0 = cfg.kv_offset + c0;
        // Recomputed identically to the item side: the spill layout is a
        // pure function of (causal, kv_offset, b_c, n, n_k).
        if cfg.causal && g0 > n - 1 {
            continue;
        }
        // Spilled scores reload + V_j streams once, per live tile.
        hbm.load(n * bc + bc * d);
        let vj = &v[c0 * d..c1 * d];
        let s = &mut s_all[off..off + n * bc];
        absorb_score_tile(acc, m_run, l_run, s, vj, n, bc, d, 0, g0, n, cfg);
        off += n * bc;
    }
}

/// Split-KV decode forward: the inference-serving kernel for a short Q
/// (one to a few rows) against a long KV history. The KV axis is split
/// into spans of `span_tiles` column tiles; each span is one pool work
/// item ([`DecodeItem`]) that *scores* its tiles (τ·Q·K_jᵀ + mask) and
/// spills them — order-free work that parallelises over the KV axis,
/// the FlashAttention-2 partitioning for the decode regime. The
/// order-sensitive half (online-softmax absorb + P̃·V) replays the
/// spilled tiles sequentially in global tile order at the merge site
/// through the exact loop body of the fused sweep, then runs the same
/// [`write_epilogue`]. This is the associative-merge recurrence of
/// `attn::distributed::merge_partials` applied in fixed span order — the
/// decode instance of the ring schedule's resumability argument — and it
/// makes the output **bitwise identical to [`flash2_forward`]** with the
/// same config and `blocks` for any worker count and any span size.
///
/// Traffic is counted access-for-access against
/// `sim::cost::flash2_decode`: per span one Q load (n·d); per
/// causally-live tile K and V each stream once (2·bc·d) plus the score
/// tile's spill + reload (2·n·bc); one epilogue store (n·d + n).
///
/// Runs on the plan-carrying `exec` handle: injected faults
/// (`FaultSite::DecodeSpan`) are retried per item, and an exhausted
/// retry budget surfaces as a typed [`AttnError`] — the serving loop
/// evicts that request and keeps the batch.
#[allow(clippy::too_many_arguments)]
pub fn flash2_decode(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    span_tiles: usize,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(Flash2Output, FaultReport), AttnError> {
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    assert_eq!(k.cols(), d, "flash2_decode: K feature dim mismatch");
    assert_eq!((v.rows(), v.cols()), (n_k, d), "flash2_decode: V shape mismatch");
    assert!(span_tiles >= 1, "flash2_decode: span_tiles must be >= 1");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let b_c = blocks.b_c;
    let t_c = n_k.div_ceil(b_c);

    let mut o = Tensor::zeros(&[n, d]);
    let mut lse = vec![0.0f32; n];
    if n == 0 || t_c == 0 {
        // No queries or no keys: same defined semantics as the fused
        // kernel's early return (zero rows, lse = -inf, zero traffic).
        lse.fill(f32::NEG_INFINITY);
        return Ok((Flash2Output { o, lse }, FaultReport::default()));
    }

    // One item per KV span; a span's spill window is sized by its
    // causally-live tiles so the item layout equals the merge layout.
    let spans = t_c.div_ceil(span_tiles);
    let mut items = Vec::with_capacity(spans);
    for sp in 0..spans {
        let (tile_lo, tile_hi) = span_tile_range(sp, span_tiles, t_c);
        let mut len = 0usize;
        for j in tile_lo..tile_hi {
            let c0 = j * b_c;
            let c1 = ((j + 1) * b_c).min(n_k);
            if cfg.causal && cfg.kv_offset + c0 > n - 1 {
                continue;
            }
            len += n * (c1 - c0);
        }
        items.push(DecodeItem { sp, tile_lo, tile_hi, s_win: vec![0.0; len] });
    }

    // Owned snapshots for the pool's 'static closure — bit-exact f32
    // copies, same marshalling as `attn::batched`; HBM counts stay
    // analytic inside the accessors.
    let qd = q.data.clone();
    let kd = k.data.clone();
    let cfg_item = cfg.clone();
    let (done, report) =
        exec.run(items, FaultSite::DecodeSpan, hbm, move |it: &mut DecodeItem| {
            score_span_tiles(
                &qd,
                &kd,
                n_k,
                n,
                d,
                &cfg_item,
                b_c,
                tau,
                kv_limit,
                it.tile_lo,
                it.tile_hi,
                &mut it.s_win,
            )
        })?;

    // Stitch the spans' spill windows into one flat buffer in span
    // order (= global tile order): the exactly-once commit per item.
    let total: usize = done.iter().map(|it| it.s_win.len()).sum();
    let mut s_all = vec![0.0f32; total];
    let mut base = 0usize;
    for it in &done {
        s_all[base..base + it.s_win.len()].copy_from_slice(&it.s_win);
        base += it.s_win.len();
    }

    // Merge: replay the tiles through the fused sweep's absorb body in
    // global order, then the shared epilogue.
    let mut state = RowBlockState {
        acc: vec![0.0; n * d],
        m_run: vec![f32::NEG_INFINITY; n],
        l_run: vec![0.0; n],
        s_buf: Vec::new(),
    };
    absorb_scored_tiles(&mut state, &mut s_all, &v.data, n_k, n, d, cfg, b_c, hbm);
    write_epilogue(&state, n, d, &mut o.data, &mut lse, hbm);

    Ok((Flash2Output { o, lse }, report))
}

/// Fast exact backward: the gradient half of the production kernel pair.
///
/// Two phases, both recomputing `P_ij = exp(s_ij − L_i)` on chip from the
/// forward's logsumexp:
///
/// 1. **dQ, Q-outer.** For each Q row block the dQ accumulator stays on
///    chip for the entire K/V stream and is written to HBM exactly once —
///    Algorithm 4 instead read-modify-writes dQ_i per inner tile
///    (its line 21), Θ(T_c·N·d) gradient traffic this phase deletes.
/// 2. **dK/dV, column-parallel.** For each K/V column block the dK~/dV~
///    accumulators stay on chip for the entire Q/dO stream and are written
///    exactly once (Algorithm 4 already had this structure; here the
///    column blocks additionally fan out over workers).
///
/// `D_i = rowsum(dO ∘ O)` is precomputed in one epilogue pass rather than
/// re-derived per tile. Both phases parallelise over `std::thread::scope`
/// workers with output that is **bitwise identical for any worker count**
/// (per-block arithmetic is partition-independent, exactly as in
/// [`flash2_forward`]). Shapes may be rectangular: q, o, dout: [n, d];
/// k, v: [n_k, d] — the sharded sequence-parallel layout. Rows whose
/// logsumexp is `-inf` (fully masked in the forward) contribute zero
/// gradient everywhere.
///
/// Like the forward, tiles beyond `kv_len` are streamed-and-masked, not
/// skipped: `sim::cost::flash2_bwd` models the causal skip but not the
/// padding mask, and the exactness tests assert measured == analytic
/// traffic. Key ranges that are *entirely* dead are cheaper to drop one
/// level up (as `flash_forward_sharded` now does with dead shards).
#[allow(clippy::too_many_arguments)]
// lint::allow(R6, per-call scoped reference oracle: runs its own scoped threads by design and never touches the pool sink)
pub fn flash2_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: AttnStats<'_>,
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> AttnGrads {
    let workers = exec.workers();
    let (n, d) = (q.rows(), q.cols());
    let n_k = k.rows();
    assert_eq!(k.cols(), d, "flash2_backward: K feature dim mismatch");
    assert_eq!((v.rows(), v.cols()), (n_k, d), "flash2_backward: V shape mismatch");
    assert_eq!((o.rows(), o.cols()), (n, d), "flash2_backward: O shape mismatch");
    assert_eq!((dout.rows(), dout.cols()), (n, d), "flash2_backward: dO shape mismatch");
    assert_eq!(stats.len(), n, "flash2_backward: stats length mismatch");
    let tau = cfg.tau_for(d);
    let kv_limit = cfg.kv_limit(n_k);
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let t_c = n_k.div_ceil(b_c);

    let mut dq = Tensor::zeros(&[n, d]);
    let mut dk = Tensor::zeros(&[n_k, d]);
    let mut dv = Tensor::zeros(&[n_k, d]);
    if t_r == 0 || t_c == 0 {
        return AttnGrads { dq, dk, dv };
    }

    // Phase 0 (epilogue pass): D_i = rowsum(dO ∘ O), loaded once here and
    // streamed alongside the logsumexp in both phases below. The lse is
    // materialised on chip from either stats representation.
    hbm.load(2 * n * d);
    let d_vec: Vec<f32> = (0..n).map(|r| dot4(dout.row(r), o.row(r))).collect();
    hbm.store(n);
    let lse = stats.to_lse_vec();
    let (qd, kd, vd, dod) =
        (q.data.as_slice(), k.data.as_slice(), v.data.as_slice(), dout.data.as_slice());

    // Phase 1: dQ with a Q-outer sweep. Disjoint per-worker dQ windows,
    // exactly the forward's partition.
    let w = workers.max(1).min(t_r);
    let chunk = t_r.div_ceil(w);
    // lint::allow(R1, per-slice reference kernel: the oracle the pooled schedules are bitwise-tested against)
    std::thread::scope(|scope| {
        // lint::allow(R5, oracle-only carve: disjoint per-worker dQ windows; traffic is counted inside dq_row_sweep)
        let dq_chunks = dq.data.chunks_mut(chunk * b_r * d);
        let mut handles = Vec::new();
        for (wi, dq_mine) in dq_chunks.enumerate() {
            let rb_lo = wi * chunk;
            let rb_hi = ((wi + 1) * chunk).min(t_r);
            let (lse, d_vec) = (&lse, &d_vec);
            handles.push(scope.spawn(move || {
                dq_row_sweep(
                    qd, kd, vd, dod, lse, d_vec, n, n_k, d, cfg, blocks, tau, kv_limit, rb_lo,
                    rb_hi, dq_mine,
                )
            }));
        }
        for h in handles {
            let local = h.join().expect("flash2_backward dQ worker panicked");
            hbm.merge(&local);
        }
    });

    // Phase 2: dK/dV with a column-block-parallel sweep over disjoint
    // per-worker dK/dV windows.
    let w = workers.max(1).min(t_c);
    let chunk = t_c.div_ceil(w);
    // lint::allow(R1, per-slice reference kernel: the oracle the pooled schedules are bitwise-tested against)
    std::thread::scope(|scope| {
        // lint::allow(R5, oracle-only carve: disjoint per-worker dK windows; traffic is counted inside dkv_col_sweep)
        let dk_chunks = dk.data.chunks_mut(chunk * b_c * d);
        // lint::allow(R5, oracle-only carve: disjoint per-worker dV windows; traffic is counted inside dkv_col_sweep)
        let dv_chunks = dv.data.chunks_mut(chunk * b_c * d);
        let mut handles = Vec::new();
        for (wi, (dk_mine, dv_mine)) in dk_chunks.zip(dv_chunks).enumerate() {
            let cb_lo = wi * chunk;
            let cb_hi = ((wi + 1) * chunk).min(t_c);
            let (lse, d_vec) = (&lse, &d_vec);
            handles.push(scope.spawn(move || {
                dkv_col_sweep(
                    qd, kd, vd, dod, lse, d_vec, n, n_k, d, cfg, blocks, tau, kv_limit, cb_lo,
                    cb_hi, dk_mine, dv_mine,
                )
            }));
        }
        for h in handles {
            let local = h.join().expect("flash2_backward dK/dV worker panicked");
            hbm.merge(&local);
        }
    });

    AttnGrads { dq, dk, dv }
}

/// Stream one K/V slice through the phase-1 dQ accumulation of query
/// rows [r0, r1). The dQ accumulator `dq_acc` ([br, d]) stays on chip;
/// like [`stream_kv`] this is resumable over consecutive tile-aligned
/// key slices in global order — the accumulation order per output
/// element is the global column order either way, so the sharded ring
/// schedule reproduces [`dq_row_sweep`] bit for bit. All mask/dropout
/// decisions use global key coordinates.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_kv_dq(
    dq_acc: &mut [f32],
    q_rows: &[f32],
    do_rows: &[f32],
    k: &[f32],
    v: &[f32],
    n_k: usize,
    n: usize,
    d: usize,
    r0: usize,
    r1: usize,
    lse: &[f32],
    d_vec: &[f32],
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    s_buf: &mut [f32],
    dp_buf: &mut [f32],
    hbm: &mut Hbm,
) {
    stream_kv_dq_filtered(
        dq_acc, q_rows, do_rows, k, v, n_k, n, d, r0, r1, lse, d_vec, cfg, blocks, tau,
        kv_limit, s_buf, dp_buf, hbm, |_| true,
    );
}

/// [`stream_kv_dq`] with a per-column-tile liveness filter — the phase-1
/// counterpart of [`stream_kv_filtered`]: a zero block contributes no
/// dQ, so it is skipped before any K/V load; an always-true filter is
/// the dense sweep bit for bit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_kv_dq_filtered<F: Fn(usize) -> bool>(
    dq_acc: &mut [f32],
    q_rows: &[f32],
    do_rows: &[f32],
    k: &[f32],
    v: &[f32],
    n_k: usize,
    n: usize,
    d: usize,
    r0: usize,
    r1: usize,
    lse: &[f32],
    d_vec: &[f32],
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    s_buf: &mut [f32],
    dp_buf: &mut [f32],
    hbm: &mut Hbm,
    live: F,
) {
    let b_c = blocks.b_c;
    let t_c = n_k.div_ceil(b_c);
    let br = r1 - r0;

    for j in 0..t_c {
        // Zero block: no dQ contribution, skip before any load.
        if !live(j) {
            continue;
        }
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        let bc = c1 - c0;
        let g0 = cfg.kv_offset + c0;
        // Above-diagonal tiles contribute nothing (same skip as fwd).
        if cfg.causal && g0 > r1 - 1 {
            continue;
        }
        // K_j, V_j stream through SRAM once per row block.
        hbm.load(2 * bc * d);
        let kj = &k[c0 * d..c1 * d];
        let vj = &v[c0 * d..c1 * d];

        // S = tau Q_i K_jᵀ and dP^dropped = dO_i V_jᵀ, register-blocked.
        let s = &mut s_buf[..br * bc];
        matmul_bt_scaled_into(q_rows, kj, d, tau, s);
        if !tile_fully_unmasked(cfg.causal, r0, cfg.kv_offset + c1, kv_limit) {
            for rr in 0..br {
                for cc in 0..bc {
                    let x = s[rr * bc + cc];
                    s[rr * bc + cc] = masked_score(x, r0 + rr, g0 + cc, cfg.causal, kv_limit);
                }
            }
        }
        let dp = &mut dp_buf[..br * bc];
        matmul_bt_scaled_into(do_rows, vj, d, 1.0, dp);

        for rr in 0..br {
            let row = r0 + rr;
            let l_row = lse[row];
            // Fully-masked forward row: zero mass, zero gradient.
            if l_row == f32::NEG_INFINITY {
                continue;
            }
            let di = d_vec[row];
            let srow = &mut s[rr * bc..(rr + 1) * bc];
            let dprow = &dp[rr * bc..(rr + 1) * bc];
            // dS~ = tau · P ∘ (dP − D_i), overwriting the score buffer;
            // masked entries have P = exp(NEG_INF − L) = 0.
            for cc in 0..bc {
                let p = (srow[cc] - l_row).exp();
                let mut dp_cc = dprow[cc];
                if cfg.dropout_p > 0.0 {
                    dp_cc *= dropout_scale(
                        cfg.bh_index,
                        row,
                        g0 + cc,
                        n,
                        cfg.dropout_seed,
                        cfg.dropout_p,
                    );
                }
                srow[cc] = tau * p * (dp_cc - di);
            }
            // dQ_i(rr) += dS~ K_j — the P̃·V micro-kernel reused.
            pv_accum(srow, kj, d, &mut dq_acc[rr * d..(rr + 1) * d]);
        }
    }
}

/// Phase-1 sweep over Q row blocks [rb_lo, rb_hi): the whole K/V stream per
/// block with the dQ accumulator on chip, one dQ store per block. Flat
/// row-major slices, single-block-dispatchable — see [`row_block_sweep`].
/// `kv_limit` is the global padding limit (`cfg.kv_limit(n_k)`).
pub(crate) fn dq_row_sweep(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    lse: &[f32],
    d_vec: &[f32],
    n: usize,
    n_k: usize,
    d: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    rb_lo: usize,
    rb_hi: usize,
    dq_out: &mut [f32],
) -> Hbm {
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let row_base = rb_lo * b_r;
    let mut hbm = Hbm::new();

    // Worker-local scratch, allocated once (nothing allocates in the loop).
    let mut s_buf = vec![0.0f32; b_r * b_c];
    let mut dp_buf = vec![0.0f32; b_r * b_c];

    for i in rb_lo..rb_hi {
        let r0 = i * b_r;
        let r1 = ((i + 1) * b_r).min(n);
        let br = r1 - r0;
        // Q_i, dO_i, D_i, L_i are loaded once per row block; dQ_i lives in
        // the (zero-initialised, worker-owned) output window until the
        // single store below — it never round-trips to HBM mid-sweep.
        hbm.load(2 * br * d + 2 * br);
        stream_kv_dq(
            &mut dq_out[(r0 - row_base) * d..(r1 - row_base) * d],
            &q[r0 * d..r1 * d],
            &dout[r0 * d..r1 * d],
            k,
            v,
            n_k,
            n,
            d,
            r0,
            r1,
            lse,
            d_vec,
            cfg,
            blocks,
            tau,
            kv_limit,
            &mut s_buf,
            &mut dp_buf,
            &mut hbm,
        );
        // Epilogue: dQ_i leaves chip exactly once.
        hbm.store(br * d);
    }

    hbm
}

/// Phase-2 sweep over K/V column blocks [cb_lo, cb_hi): the whole Q/dO
/// stream per block with dK~/dV~ on chip, one dK/dV store per block. Flat
/// row-major slices, single-block-dispatchable — see [`row_block_sweep`].
/// Column blocks are local to the k/v slice; every mask/dropout decision
/// is made at the global column `cfg.kv_offset + local_col`, so the
/// sharded driver dispatches a shard's column blocks through exactly
/// this path and gets the single-device kernel's dK/dV rows bit for
/// bit (per-column-block arithmetic touches no cross-shard state).
/// `kv_limit` is the global padding limit (`cfg.kv_limit(n_k)`).
pub(crate) fn dkv_col_sweep(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    lse: &[f32],
    d_vec: &[f32],
    n: usize,
    n_k: usize,
    d: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    cb_lo: usize,
    cb_hi: usize,
    dk_out: &mut [f32],
    dv_out: &mut [f32],
) -> Hbm {
    dkv_col_sweep_filtered(
        q, k, v, dout, lse, d_vec, n, n_k, d, cfg, blocks, tau, kv_limit, cb_lo, cb_hi,
        dk_out, dv_out, |_, _| true,
    )
}

/// [`dkv_col_sweep`] with a per-(row block, column block) liveness
/// filter `live(i, j)` (`j` local to the k/v slice): a zero block's Q/dO
/// stream is skipped before its load. K_j/V_j still load once and
/// dK_j/dV_j still store once per column block — the output rows leave
/// chip regardless of how sparse their column is — so an always-true
/// filter is the dense sweep bit for bit, loads and stores included.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dkv_col_sweep_filtered<F: Fn(usize, usize) -> bool>(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    lse: &[f32],
    d_vec: &[f32],
    n: usize,
    n_k: usize,
    d: usize,
    cfg: &AttnConfig,
    blocks: Blocks,
    tau: f32,
    kv_limit: usize,
    cb_lo: usize,
    cb_hi: usize,
    dk_out: &mut [f32],
    dv_out: &mut [f32],
    live: F,
) -> Hbm {
    let (b_r, b_c) = (blocks.b_r, blocks.b_c);
    let t_r = n.div_ceil(b_r);
    let col_base = cb_lo * b_c;
    let mut hbm = Hbm::new();

    let mut s_buf = vec![0.0f32; b_r * b_c];
    let mut dp_buf = vec![0.0f32; b_r * b_c];

    for j in cb_lo..cb_hi {
        let c0 = j * b_c;
        let c1 = ((j + 1) * b_c).min(n_k);
        let bc = c1 - c0;
        // K_j, V_j loaded once per column block; dK~_j/dV~_j accumulate in
        // the worker-owned output windows until the single store.
        hbm.load(2 * bc * d);
        let kj = &k[c0 * d..c1 * d];
        let vj = &v[c0 * d..c1 * d];
        let dk_acc = &mut dk_out[(c0 - col_base) * d..(c1 - col_base) * d];
        let dv_acc = &mut dv_out[(c0 - col_base) * d..(c1 - col_base) * d];

        for i in 0..t_r {
            let r0 = i * b_r;
            let r1 = ((i + 1) * b_r).min(n);
            let br = r1 - r0;
            let g0 = cfg.kv_offset + c0;
            // Zero block: skip before the Q/dO stream load.
            if !live(i, j) {
                continue;
            }
            if cfg.causal && g0 > r1 - 1 {
                continue;
            }
            // Q_i, dO_i, D_i, L_i stream through SRAM once per column block.
            hbm.load(2 * br * d + 2 * br);
            let q_rows = &q[r0 * d..r1 * d];
            let do_rows = &dout[r0 * d..r1 * d];

            let s = &mut s_buf[..br * bc];
            matmul_bt_scaled_into(q_rows, kj, d, tau, s);
            if !tile_fully_unmasked(cfg.causal, r0, cfg.kv_offset + c1, kv_limit) {
                for rr in 0..br {
                    for cc in 0..bc {
                        let x = s[rr * bc + cc];
                        s[rr * bc + cc] = masked_score(x, r0 + rr, g0 + cc, cfg.causal, kv_limit);
                    }
                }
            }
            let dp = &mut dp_buf[..br * bc];
            matmul_bt_scaled_into(do_rows, vj, d, 1.0, dp);

            for rr in 0..br {
                let row = r0 + rr;
                let l_row = lse[row];
                if l_row == f32::NEG_INFINITY {
                    continue;
                }
                let di = d_vec[row];
                let dorow = &do_rows[rr * d..(rr + 1) * d];
                let qrow = &q_rows[rr * d..(rr + 1) * d];
                for cc in 0..bc {
                    let p = (s[rr * bc + cc] - l_row).exp();
                    if p == 0.0 {
                        continue; // masked (or fully underflowed) entry
                    }
                    let z = if cfg.dropout_p > 0.0 {
                        dropout_scale(
                            cfg.bh_index,
                            row,
                            g0 + cc,
                            n,
                            cfg.dropout_seed,
                            cfg.dropout_p,
                        )
                    } else {
                        1.0
                    };
                    // dV~_j(cc) += (P ∘ Z)ᵀ dO_i — dropped entries skip.
                    let pz = p * z;
                    if pz != 0.0 {
                        let dvrow = &mut dv_acc[cc * d..(cc + 1) * d];
                        for c in 0..d {
                            dvrow[c] += pz * dorow[c];
                        }
                    }
                    // dS~ = tau · P ∘ (dP ∘ Z − D_i); dK~_j(cc) += dS~ᵀ Q_i.
                    let w = tau * p * (dp[rr * bc + cc] * z - di);
                    if w != 0.0 {
                        let dkrow = &mut dk_acc[cc * d..(cc + 1) * d];
                        for c in 0..d {
                            dkrow[c] += w * qrow[c];
                        }
                    }
                }
            }
        }
        // Epilogue: dK_j and dV_j leave chip exactly once.
        hbm.store(2 * bc * d);
    }

    hbm
}

/// One invariant probe in the fast-pair self check. `bitwise` probes
/// must come back with `diff == 0.0` (any deviation is a scheduling or
/// accounting bug, not float noise); tolerance probes compare against
/// the caller's threshold.
#[derive(Clone, Debug)]
pub struct CheckProbe {
    pub invariant: &'static str,
    pub diff: f32,
    pub bitwise: bool,
}

/// Per-invariant result of [`self_check_report`], so a preflight
/// failure names *which* guarantee broke — kernel parity, scheduler
/// determinism, or IO accounting — instead of one opaque scalar.
#[derive(Clone, Debug)]
pub struct SelfCheckReport {
    pub probes: Vec<CheckProbe>,
}

impl SelfCheckReport {
    /// Collapse to the legacy scalar: the max deviation, with any
    /// failed bitwise probe forced to at least 1.0 (the historical
    /// sentinel for "a determinism invariant broke").
    pub fn max_diff(&self) -> f32 {
        self.probes.iter().fold(0.0f32, |acc, p| {
            if p.bitwise && p.diff != 0.0 {
                acc.max(p.diff).max(1.0)
            } else {
                acc.max(p.diff)
            }
        })
    }

    /// The first broken invariant as a typed error, or Ok when every
    /// probe passes. Bitwise probes must be exactly zero; tolerance
    /// probes must be strictly below `tol` (NaN deviations fail).
    pub fn verdict(&self, tol: f32) -> Result<(), super::faults::AttnError> {
        for p in &self.probes {
            let broke = if p.bitwise { p.diff != 0.0 } else { !(p.diff < tol) };
            if broke {
                let bound = if p.bitwise { "bitwise".to_string() } else { format!("< {tol}") };
                return Err(super::faults::AttnError::Preflight {
                    invariant: p.invariant,
                    detail: format!("max deviation {} (required {bound})", p.diff),
                });
            }
        }
        Ok(())
    }
}

/// Fixed cross-kernel agreement probe (causal + padding + rectangular-ish
/// shape, multi-threaded) covering the full fast pair: max deviation of
/// flash2's forward (O, logsumexp) **and** backward (dQ, dK, dV) from the
/// paper-faithful reference kernels over the workload, plus the batched
/// multi-head scheduler (`attn::batched` — the entry points every hot path
/// actually calls) against the per-slice pair, the sharded
/// sequence-parallel ring schedule (`attn::distributed`) against the
/// single-device pair with causal + dropout + padding all active — both
/// of those agreements must be bitwise (any nonzero deviation is a
/// scheduling/coordinate bug, not float noise) — and the forward IO
/// accounting (instrumented counter vs the `sim::cost` closed form,
/// access-for-access). Used by the coordinator preflight before any
/// training/serving runs; one [`CheckProbe`] per invariant.
pub fn self_check_report() -> SelfCheckReport {
    self_check_report_on(&Exec::new(3))
}

/// [`self_check_report`] on a caller-supplied execution handle: the
/// batched, sharded and shared-entry-point probes all run on `exec`
/// (stripped of any fault plan — the preflight must judge the healthy
/// path), so a trainer preflighting on its own persistent pool
/// exercises exactly the plane its hot paths will run on.
pub fn self_check_report_on(exec: &Exec) -> SelfCheckReport {
    use super::batched::{bh_slice, flash2_backward_batched, flash2_forward_batched};
    use super::{attention_backward, BackwardKernel};
    use crate::util::rng::SplitMix64;
    let (n, d) = (48usize, 16usize);
    let mut rng = SplitMix64::new(0xF1A5_42);
    let q = Tensor::randn(&[n, d], &mut rng, 1.0);
    let k = Tensor::randn(&[n, d], &mut rng, 1.0);
    let v = Tensor::randn(&[n, d], &mut rng, 1.0);
    let cfg = AttnConfig { causal: true, kv_len: Some(37), ..Default::default() };
    let blocks = Blocks::explicit(8, 8);
    let reference = super::flash::flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
    // The caller's handle, fault-free: the preflight exercises the
    // execution plane the hot paths run on; per-slice oracles below use
    // scoped handles.
    let ex3 = exec.fault_free();
    let fast = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(3), &mut Hbm::new());
    let mut fwd_diff = reference.o.max_abs_diff(&fast.o);
    for r in 0..n {
        fwd_diff = fwd_diff.max((reference.stats().lse(r) - fast.lse[r]).abs());
    }
    // The gradient half of the pair, through the shared entry point.
    let dout = Tensor::randn(&[n, d], &mut rng, 1.0);
    let slow = attention_backward(
        BackwardKernel::Flash,
        &q, &k, &v, &reference.o, &dout, reference.stats(), &cfg, blocks, &mut Hbm::new(),
    );
    let fast_g = attention_backward(
        BackwardKernel::Flash2 { exec: &ex3 },
        &q, &k, &v, &fast.o, &dout, fast.stats(), &cfg, blocks, &mut Hbm::new(),
    );
    let bwd_diff = slow
        .dq
        .max_abs_diff(&fast_g.dq)
        .max(slow.dk.max_abs_diff(&fast_g.dk))
        .max(slow.dv.max_abs_diff(&fast_g.dv));

    // Batched scheduler probe: a [2, 2, n, d] workload through the batched
    // pair vs the per-slice pair, slice by slice (slice s advances
    // bh_index by s on both sides). These are the entry points the
    // trainer/serve/bench hot paths call; agreement is bitwise, so any
    // nonzero deviation here is a scheduling bug, not float noise.
    let (bsz, heads, nb, db) = (2usize, 2usize, 24usize, 8usize);
    let len = nb * db;
    let q4 = Tensor::randn(&[bsz, heads, nb, db], &mut rng, 1.0);
    let k4 = Tensor::randn(&[bsz, heads, nb, db], &mut rng, 1.0);
    let v4 = Tensor::randn(&[bsz, heads, nb, db], &mut rng, 1.0);
    let dout4 = Tensor::randn(&[bsz, heads, nb, db], &mut rng, 1.0);
    let bcfg = AttnConfig { causal: true, kv_len: Some(19), ..Default::default() };
    let bfwd = flash2_forward_batched(&q4, &k4, &v4, &bcfg, blocks, &ex3, &mut Hbm::new())
        .expect("preflight batched forward")
        .0;
    let bg = flash2_backward_batched(
        &q4, &k4, &v4, &bfwd.o, &dout4, &bfwd.stats, &bcfg, blocks, &ex3, &mut Hbm::new(),
    )
    .expect("preflight batched backward")
    .0;
    let max_abs =
        |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    let mut batched_diff = 0.0f32;
    for s in 0..bsz * heads {
        let cfg_s = AttnConfig { bh_index: s as u32, ..bcfg.clone() };
        let (qs, ks, vs) = (bh_slice(&q4, s), bh_slice(&k4, s), bh_slice(&v4, s));
        let dos = bh_slice(&dout4, s);
        let f = flash2_forward(&qs, &ks, &vs, &cfg_s, blocks, &Exec::scoped(1), &mut Hbm::new());
        let g = flash2_backward(
            &qs, &ks, &vs, &f.o, &dos, f.stats(), &cfg_s, blocks, &Exec::scoped(1), &mut Hbm::new(),
        );
        batched_diff = batched_diff
            .max(max_abs(&bfwd.o.data[s * len..(s + 1) * len], &f.o.data))
            .max(max_abs(&bfwd.stats.lse[s * nb..(s + 1) * nb], &f.lse))
            .max(max_abs(&bg.dq.data[s * len..(s + 1) * len], &g.dq.data))
            .max(max_abs(&bg.dk.data[s * len..(s + 1) * len], &g.dk.data))
            .max(max_abs(&bg.dv.data[s * len..(s + 1) * len], &g.dv.data));
    }

    // Sharded ring-schedule probe: causal + dropout + padding through 3
    // shards must be BITWISE identical to the single-device pair.
    use super::distributed::{flash_backward_sharded, flash_forward_sharded};
    let scfg = AttnConfig {
        causal: true,
        kv_len: Some(37),
        dropout_p: 0.15,
        dropout_seed: 11,
        ..Default::default()
    };
    let sfwd = flash2_forward(&q, &k, &v, &scfg, blocks, &Exec::scoped(2), &mut Hbm::new());
    let shard_fwd =
        flash_forward_sharded(&q, &k, &v, &scfg, blocks, 3, &ex3).expect("preflight sharded").0;
    let sbwd = flash2_backward(
        &q, &k, &v, &sfwd.o, &dout, sfwd.stats(), &scfg, blocks, &Exec::scoped(2), &mut Hbm::new(),
    );
    let shard_bwd = flash_backward_sharded(
        &q, &k, &v, &sfwd.o, &dout, sfwd.stats(), &scfg, blocks, 3, &ex3,
    )
    .expect("preflight sharded backward")
    .0;
    let sharded_broke = shard_fwd.o.data != sfwd.o.data
        || shard_fwd.m != sfwd.lse
        || shard_bwd.dq.data != sbwd.dq.data
        || shard_bwd.dk.data != sbwd.dk.data
        || shard_bwd.dv.data != sbwd.dv.data;

    // IO-accounting probe: the instrumented forward counter against the
    // analytic closed form on a clean divisible tiling — exact, every
    // access accounted.
    let io_cfg = AttnConfig { causal: true, ..Default::default() };
    let mut io_hbm = Hbm::new();
    let _ = flash2_forward(&q, &k, &v, &io_cfg, blocks, &Exec::scoped(3), &mut io_hbm);
    let expected =
        crate::sim::cost::flash2_fwd(n as u64, d as u64, blocks, true, false).hbm_elems;
    let io_diff = crate::sim::cost::measured(&io_hbm).abs_diff(expected) as f32;

    SelfCheckReport {
        probes: vec![
            CheckProbe {
                invariant: "forward parity (flash2 vs flash)",
                diff: fwd_diff,
                bitwise: false,
            },
            CheckProbe {
                invariant: "backward parity (flash2 vs flash)",
                diff: bwd_diff,
                bitwise: false,
            },
            CheckProbe {
                invariant: "batched scheduler bitwise agreement",
                diff: batched_diff,
                bitwise: true,
            },
            CheckProbe {
                invariant: "sharded ring bitwise agreement",
                diff: if sharded_broke { 1.0 } else { 0.0 },
                bitwise: true,
            },
            CheckProbe { invariant: "forward IO accounting", diff: io_diff, bitwise: true },
        ],
    }
}

/// Legacy scalar form of [`self_check_report`]: the max deviation, with
/// failed bitwise probes forced to ≥ 1.0.
pub fn self_check() -> f32 {
    self_check_report().max_diff()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash::{flash_backward, flash_forward};
    use crate::attn::standard::{standard_backward, standard_forward};
    use crate::tensor::dot4;
    use crate::util::prop::{for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        (
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
            Tensor::randn(&[n, d], &mut rng, 1.0),
        )
    }

    #[test]
    fn matches_standard_forward() {
        let (q, k, v) = qkv(48, 8, 0);
        let std = standard_forward(&q, &k, &v, &AttnConfig::default(), &mut Hbm::new());
        let fast = flash2_forward(
            &q,
            &k,
            &v,
            &AttnConfig::default(),
            Blocks::explicit(8, 16),
            &Exec::scoped(2),
            &mut Hbm::new(),
        );
        assert!(std.o.max_abs_diff(&fast.o) < 1e-5);
        for r in 0..48 {
            assert!(
                (std.stats().lse(r) - fast.lse[r]).abs() < 1e-4,
                "lse row {r}: {} vs {}",
                std.stats().lse(r),
                fast.lse[r]
            );
        }
    }

    #[test]
    fn property_parity_flash2_vs_flash_vs_standard() {
        // The ISSUE grid: (n, d, B_r, B_c, causal, kv_len, dropout_p, workers).
        for_each_case("flash2_parity", 20, |rng| {
            let n = usize_in(rng, 2, 48);
            let d = *crate::util::prop::choose(rng, &[2usize, 4, 8]);
            let b_r = usize_in(rng, 1, n);
            let b_c = usize_in(rng, 1, n);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let blocks = Blocks::explicit(b_r, b_c);
            let std = standard_forward(&q, &k, &v, &cfg, &mut Hbm::new());
            let fla = flash_forward(&q, &k, &v, &cfg, blocks, &mut Hbm::new());
            let ex = Exec::scoped(workers);
            let fa2 = flash2_forward(&q, &k, &v, &cfg, blocks, &ex, &mut Hbm::new());
            let ctx = format!(
                "n={n} d={d} blocks=({b_r},{b_c}) causal={causal} kv_len={kv_len:?} \
                 p={dropout_p} w={workers}"
            );
            assert!(std.o.max_abs_diff(&fa2.o) < 1e-4, "vs standard: {ctx}");
            assert!(fla.o.max_abs_diff(&fa2.o) < 1e-4, "vs flash: {ctx}");
        });
    }

    #[test]
    fn deterministic_across_worker_counts() {
        // Per-row-block arithmetic is partition-independent, so the
        // epilogue output must be bitwise identical for any worker count.
        let (q, k, v) = qkv(64, 16, 3);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(8, 16);
        let base = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
        for workers in [2usize, 3, 4, 8, 64] {
            let ex = Exec::scoped(workers);
            let multi = flash2_forward(&q, &k, &v, &cfg, blocks, &ex, &mut Hbm::new());
            assert_eq!(base.o.data, multi.o.data, "O not bitwise equal at workers={workers}");
            assert_eq!(base.lse, multi.lse, "lse not bitwise equal at workers={workers}");
        }
    }

    #[test]
    fn hbm_accounting_independent_of_worker_count() {
        let (q, k, v) = qkv(64, 8, 4);
        let blocks = Blocks::explicit(16, 16);
        let mut h1 = Hbm::new();
        flash2_forward(&q, &k, &v, &AttnConfig::default(), blocks, &Exec::scoped(1), &mut h1);
        let mut h4 = Hbm::new();
        flash2_forward(&q, &k, &v, &AttnConfig::default(), blocks, &Exec::scoped(4), &mut h4);
        assert_eq!(h1.loads, h4.loads);
        assert_eq!(h1.stores, h4.stores);
    }

    #[test]
    fn o_and_stats_written_exactly_once() {
        // The tentpole IO claim: store traffic is exactly N·d + N floats —
        // one O row + one stat per row, once — for any tiling.
        for (n, d, br, bc) in [(64usize, 8usize, 16usize, 16usize), (48, 4, 8, 32), (40, 8, 16, 8)]
        {
            let (q, k, v) = qkv(n, d, 5);
            let mut hbm = Hbm::new();
            flash2_forward(
                &q,
                &k,
                &v,
                &AttnConfig::default(),
                Blocks::explicit(br, bc),
                &Exec::scoped(2),
                &mut hbm,
            );
            assert_eq!(hbm.stores, (n * d + n) as u64, "n={n} d={d} blocks=({br},{bc})");
        }
    }

    #[test]
    fn backward_consumes_lse_stats() {
        // flash2 forward -> Algorithm 4 backward via AttnStats::Lse.
        let (q, k, v) = qkv(32, 8, 6);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(8, 8);
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(2), &mut Hbm::new());
        let mut rng = SplitMix64::new(9);
        let dout = Tensor::randn(&[32, 8], &mut rng, 1.0);
        let fg =
            flash_backward(&q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new());
        let sg = standard_backward(&q, &k, &v, &dout, &cfg, &mut Hbm::new());
        assert!(fg.dq.max_abs_diff(&sg.dq) < 1e-4);
        assert!(fg.dk.max_abs_diff(&sg.dk) < 1e-4);
        assert!(fg.dv.max_abs_diff(&sg.dv) < 1e-4);
    }

    #[test]
    fn rectangular_kv_matches_standard_padding() {
        // Rectangular K/V (n_k != n) is what the sharded path feeds.
        let mut rng = SplitMix64::new(8);
        let q = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let k = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let v = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let cfg = AttnConfig { kv_len: Some(33), tau: Some(0.25), ..Default::default() };
        let fast = flash2_forward(
            &q,
            &k,
            &v,
            &cfg,
            Blocks::explicit(8, 8),
            &Exec::scoped(3),
            &mut Hbm::new(),
        );
        // Oracle: dense softmax over the first kv_len keys.
        let tau = 0.25f32;
        for r in 0..24 {
            let mut scores: Vec<f32> =
                (0..33).map(|c| tau * dot4(q.row(r), k.row(c))).collect();
            let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - mx).exp();
                z += *s;
            }
            for c in 0..8 {
                let expect: f32 =
                    (0..33).map(|cc| scores[cc] / z * v.row(cc)[c]).sum();
                assert!((fast.o.row(r)[c] - expect).abs() < 1e-4, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn into_attn_output_round_trips_stats() {
        let (q, k, v) = qkv(16, 4, 10);
        let fast = flash2_forward(
            &q,
            &k,
            &v,
            &AttnConfig::default(),
            Blocks::explicit(4, 4),
            &Exec::scoped(1),
            &mut Hbm::new(),
        );
        let lse_before = fast.lse.clone();
        let out = fast.into_attn_output();
        for r in 0..16 {
            assert!((out.stats().lse(r) - lse_before[r]).abs() < 1e-6);
        }
    }

    #[test]
    fn self_check_is_tight() {
        assert!(self_check() < 1e-4, "self_check diff {}", self_check());
    }

    #[test]
    fn self_check_report_names_every_invariant() {
        let report = self_check_report();
        assert_eq!(report.probes.len(), 5, "probe set changed without updating this test");
        report.verdict(1e-4).expect("healthy build must pass every probe");
        // A broken probe must surface as a typed Preflight error naming
        // the invariant, and the legacy scalar must go to >= 1 for
        // bitwise breaks.
        let mut bad = report.clone();
        bad.probes[2].diff = 3e-7; // bitwise probe: ANY deviation fails
        let err = bad.verdict(1e-4).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("batched scheduler bitwise agreement"),
            "error must name the broken invariant: {msg}"
        );
        assert!(bad.max_diff() >= 1.0, "bitwise break must trip the legacy scalar");
    }

    /// Dense softmax-attention gradients on (possibly rectangular) shapes —
    /// an oracle independent of every tiled kernel under test.
    fn dense_backward_oracle(
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        dout: &Tensor,
        cfg: &AttnConfig,
    ) -> (Tensor, Tensor, Tensor) {
        use crate::attn::masks::{dropout_scale, masked_score, NEG_INF};
        let (n, d) = (q.rows(), q.cols());
        let n_k = k.rows();
        let tau = cfg.tau_for(d);
        let kv_len = cfg.kv_len.unwrap_or(n_k).min(n_k);
        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n_k, d]);
        let mut dv = Tensor::zeros(&[n_k, d]);
        for r in 0..n {
            let s: Vec<f32> = (0..n_k)
                .map(|c| masked_score(tau * dot4(q.row(r), k.row(c)), r, c, cfg.causal, kv_len))
                .collect();
            let mx = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            if mx <= NEG_INF {
                continue; // fully-masked row: zero mass, zero gradient
            }
            let e: Vec<f32> =
                s.iter().map(|&x| if x <= NEG_INF { 0.0 } else { (x - mx).exp() }).collect();
            let z_sum: f32 = e.iter().sum();
            let p: Vec<f32> = e.iter().map(|&x| x / z_sum).collect();
            let zs: Vec<f32> = (0..n_k)
                .map(|c| dropout_scale(cfg.bh_index, r, c, n, cfg.dropout_seed, cfg.dropout_p))
                .collect();
            let orow: Vec<f32> = (0..d)
                .map(|cd| (0..n_k).map(|c| p[c] * zs[c] * v.row(c)[cd]).sum())
                .collect();
            let di = dot4(dout.row(r), &orow);
            for c in 0..n_k {
                let pz = p[c] * zs[c];
                for cd in 0..d {
                    dv.row_mut(c)[cd] += pz * dout.row(r)[cd];
                }
                let dp = dot4(dout.row(r), v.row(c)) * zs[c];
                let ds = tau * p[c] * (dp - di);
                for cd in 0..d {
                    dq.row_mut(r)[cd] += ds * k.row(c)[cd];
                    dk.row_mut(c)[cd] += ds * q.row(r)[cd];
                }
            }
        }
        (dq, dk, dv)
    }

    #[test]
    fn backward_property_parity_vs_flash_and_standard() {
        // The ISSUE grid: causal × dropout × kv_len (× blocks × workers),
        // flash2_backward against both reference gradient producers.
        for_each_case("flash2_bwd_parity", 20, |rng| {
            let n = usize_in(rng, 2, 40);
            let d = *crate::util::prop::choose(rng, &[2usize, 4, 8]);
            let b_r = usize_in(rng, 1, n);
            let b_c = usize_in(rng, 1, n);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let q = Tensor::randn(&[n, d], rng, 1.0);
            let k = Tensor::randn(&[n, d], rng, 1.0);
            let v = Tensor::randn(&[n, d], rng, 1.0);
            let dout = Tensor::randn(&[n, d], rng, 1.0);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let blocks = Blocks::explicit(b_r, b_c);
            let ex = Exec::scoped(workers);
            let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &ex, &mut Hbm::new());
            let fast = flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &ex, &mut Hbm::new(),
            );
            let slow = flash_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new(),
            );
            let std = standard_backward(&q, &k, &v, &dout, &cfg, &mut Hbm::new());
            let ctx = format!(
                "n={n} d={d} blocks=({b_r},{b_c}) causal={causal} kv_len={kv_len:?} \
                 p={dropout_p} w={workers}"
            );
            assert!(fast.dq.max_abs_diff(&slow.dq) < 1e-4, "dq vs flash: {ctx}");
            assert!(fast.dk.max_abs_diff(&slow.dk) < 1e-4, "dk vs flash: {ctx}");
            assert!(fast.dv.max_abs_diff(&slow.dv) < 1e-4, "dv vs flash: {ctx}");
            assert!(fast.dq.max_abs_diff(&std.dq) < 1e-4, "dq vs standard: {ctx}");
            assert!(fast.dk.max_abs_diff(&std.dk) < 1e-4, "dk vs standard: {ctx}");
            assert!(fast.dv.max_abs_diff(&std.dv) < 1e-4, "dv vs standard: {ctx}");
        });
    }

    #[test]
    fn backward_grads_match_finite_difference() {
        // Direct check against the forward itself: d(sum O)/dx by central
        // differences, causal + padding active.
        let (n, d) = (6usize, 4usize);
        let (q, k, v) = qkv(n, d, 11);
        let cfg = AttnConfig { causal: true, kv_len: Some(5), ..Default::default() };
        let blocks = Blocks::explicit(2, 3);
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(2), &mut Hbm::new());
        let dout = Tensor::full(&[n, d], 1.0);
        let g = flash2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::scoped(2), &mut Hbm::new(),
        );
        let ex1 = Exec::scoped(1);
        let f = |q_: &Tensor, k_: &Tensor, v_: &Tensor| -> f32 {
            flash2_forward(q_, k_, v_, &cfg, blocks, &ex1, &mut Hbm::new()).o.data.iter().sum()
        };
        let eps = 1e-3f32;
        for (which, (x, gx)) in [(0, (&q, &g.dq)), (1, (&k, &g.dk)), (2, (&v, &g.dv))] {
            for idx in [0usize, 7, 17, 23] {
                let mut xp = x.clone();
                xp.data[idx] += eps;
                let mut xm = x.clone();
                xm.data[idx] -= eps;
                let (fp, fm) = match which {
                    0 => (f(&xp, &k, &v), f(&xm, &k, &v)),
                    1 => (f(&q, &xp, &v), f(&q, &xm, &v)),
                    _ => (f(&q, &k, &xp), f(&q, &k, &xm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                let an = gx.data[idx];
                assert!(
                    (fd - an).abs() < 2e-2 + 0.05 * an.abs(),
                    "which={which} idx={idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn backward_deterministic_across_worker_counts() {
        // Mirrors the forward test: per-block arithmetic is partition-
        // independent, so all three gradients must be bitwise identical
        // for any worker count.
        let (q, k, v) = qkv(64, 16, 13);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(8, 16);
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
        let mut rng = SplitMix64::new(14);
        let dout = Tensor::randn(&[64, 16], &mut rng, 1.0);
        let base = flash2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::scoped(1), &mut Hbm::new(),
        );
        for workers in [2usize, 3, 4, 8, 64] {
            let ex = Exec::scoped(workers);
            let multi = flash2_backward(
                &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &ex, &mut Hbm::new(),
            );
            assert_eq!(base.dq.data, multi.dq.data, "dQ not bitwise equal at workers={workers}");
            assert_eq!(base.dk.data, multi.dk.data, "dK not bitwise equal at workers={workers}");
            assert_eq!(base.dv.data, multi.dv.data, "dV not bitwise equal at workers={workers}");
        }
    }

    #[test]
    fn backward_rectangular_kv_matches_dense_oracle() {
        // Rectangular K/V (n_k != n) — the sharded layout — for both the
        // new fast backward and the (previously square-only) Algorithm 4
        // reference, against a dense oracle.
        let mut rng = SplitMix64::new(15);
        let q = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let k = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let v = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let dout = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let cfg = AttnConfig { kv_len: Some(33), tau: Some(0.25), ..Default::default() };
        let blocks = Blocks::explicit(8, 8);
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(3), &mut Hbm::new());
        let (dq_o, dk_o, dv_o) = dense_backward_oracle(&q, &k, &v, &dout, &cfg);
        let fast = flash2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::scoped(3), &mut Hbm::new(),
        );
        assert!(fast.dq.max_abs_diff(&dq_o) < 1e-4, "flash2 dq {}", fast.dq.max_abs_diff(&dq_o));
        assert!(fast.dk.max_abs_diff(&dk_o) < 1e-4, "flash2 dk {}", fast.dk.max_abs_diff(&dk_o));
        assert!(fast.dv.max_abs_diff(&dv_o) < 1e-4, "flash2 dv {}", fast.dv.max_abs_diff(&dv_o));
        let slow = flash_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &mut Hbm::new(),
        );
        assert!(slow.dq.max_abs_diff(&dq_o) < 1e-4, "flash dq {}", slow.dq.max_abs_diff(&dq_o));
        assert!(slow.dk.max_abs_diff(&dk_o) < 1e-4, "flash dk {}", slow.dk.max_abs_diff(&dk_o));
        assert!(slow.dv.max_abs_diff(&dv_o) < 1e-4, "flash dv {}", slow.dv.max_abs_diff(&dv_o));
    }

    #[test]
    fn fully_masked_rows_zero_output_zero_grads_no_nan() {
        // kv_len = 0: every row is fully masked. Forward must emit zero
        // rows with lse = -inf (not NaN, not a uniform average of V);
        // backward must return all-zero, finite gradients.
        let (q, k, v) = qkv(16, 4, 16);
        let cfg = AttnConfig { kv_len: Some(0), ..Default::default() };
        let blocks = Blocks::explicit(4, 4);
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(2), &mut Hbm::new());
        assert!(fwd.o.data.iter().all(|&x| x == 0.0), "O must be zero for masked rows");
        assert!(fwd.lse.iter().all(|&x| x == f32::NEG_INFINITY), "lse must be -inf");
        let mut rng = SplitMix64::new(17);
        let dout = Tensor::randn(&[16, 4], &mut rng, 1.0);
        let g = flash2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::scoped(2), &mut Hbm::new(),
        );
        for (name, t) in [("dq", &g.dq), ("dk", &g.dk), ("dv", &g.dv)] {
            assert!(t.data.iter().all(|&x| x == 0.0), "{name} must be zero");
        }
        // Partially-masked workload stays NaN-free with dead rows present:
        // causal + kv_len=1 leaves only column 0 live.
        let cfg = AttnConfig { causal: true, kv_len: Some(1), ..Default::default() };
        let fwd = flash2_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(2), &mut Hbm::new());
        assert!(fwd.o.data.iter().all(|x| x.is_finite()));
        let g = flash2_backward(
            &q, &k, &v, &fwd.o, &dout, fwd.stats(), &cfg, blocks, &Exec::scoped(2), &mut Hbm::new(),
        );
        assert!(g.dq.data.iter().chain(&g.dk.data).chain(&g.dv.data).all(|x| x.is_finite()));
    }
}
