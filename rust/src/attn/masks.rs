//! Masking and block-sparsity patterns — Rust mirrors of the kernel-side
//! helpers (causal / key-padding biases, butterfly + local-global block
//! masks, kernel-identical dropout).

use crate::util::rng::kernel_dropout_keep;

pub const NEG_INF: f32 = -1e30;

/// Apply the fused mask of Algorithm 2 line 11 to a scores entry.
///
/// `col` and `kv_len` are **global** key coordinates: a kernel working
/// on a key shard passes `cfg.kv_offset + local_col` and the global
/// padding limit (`AttnConfig::kv_limit`), so the decision is identical
/// to the unsharded kernel's for the same attention entry.
#[inline]
pub fn masked_score(s: f32, row: usize, col: usize, causal: bool, kv_len: usize) -> f32 {
    if (causal && col > row) || col >= kv_len {
        NEG_INF
    } else {
        s
    }
}

/// Dropout scale for attention entry (row, col): 0 if dropped, 1/(1-p) if
/// kept — identical stream to the Pallas kernels (see util::rng).
///
/// The stream is a pure function of `(bh, row, col, n, seed)` where
/// `col` is the **global** key column and `n` is the **query-row count
/// of the whole (unsharded) problem** — the counter stride, NOT the
/// local key count of whatever K/V slice the caller holds. Every call
/// site passes `q.rows()` and `cfg.kv_offset + local_col` (audited:
/// flash, flash2 fwd + both bwd phases, standard), which is what pins a
/// shard's keep/drop pattern to the unsharded kernel's.
#[inline]
pub fn dropout_scale(
    bh: u32,
    row: usize,
    col: usize,
    n: usize,
    seed: u32,
    p_drop: f32,
) -> f32 {
    if p_drop <= 0.0 {
        1.0
    } else if kernel_dropout_keep(bh, row as u32, col as u32, n as u32, seed, p_drop) {
        1.0 / (1.0 - p_drop)
    } else {
        0.0
    }
}

/// Block-sparsity mask M in {0,1}^{t_r x t_c} (Section 3.3).
///
/// The grid is rectangular in general: `t_r` derives from the query
/// count and `t_c` from the **key** count (`kv_len` of the workload),
/// so cross-attention and sharded layouts index it directly. Kernels
/// interpret columns as *global* key tiles — see `attn::block_sparse`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockMask {
    pub t_r: usize,
    pub t_c: usize,
    pub bits: Vec<u8>,
}

impl BlockMask {
    pub fn dense(t_r: usize, t_c: usize) -> BlockMask {
        BlockMask { t_r, t_c, bits: vec![1; t_r * t_c] }
    }

    pub fn zeros(t_r: usize, t_c: usize) -> BlockMask {
        BlockMask { t_r, t_c, bits: vec![0; t_r * t_c] }
    }

    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.t_c + j] != 0
    }

    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.bits[i * self.t_c + j] = v as u8;
    }

    /// Fixed butterfly pattern (Pixelated Butterfly [17]) — diagonal plus
    /// power-of-two off-diagonals. Mirrors `butterfly_mask` in
    /// python/compile/kernels/block_sparse.py. Degenerate grids (zero
    /// rows or columns) return the empty mask — `t_c == 0` used to
    /// underflow the `i.min(t_c - 1)` diagonal clamp.
    pub fn butterfly(t_r: usize, t_c: usize) -> BlockMask {
        let mut m = BlockMask::zeros(t_r, t_c);
        if t_r == 0 || t_c == 0 {
            return m;
        }
        for i in 0..t_r {
            m.set(i, i.min(t_c - 1), true);
            let mut stride = 1usize;
            while stride < t_r.max(t_c) {
                if i >= stride && i - stride < t_c {
                    m.set(i, i - stride, true);
                }
                if i + stride < t_c {
                    m.set(i, i + stride, true);
                }
                stride *= 2;
            }
        }
        m
    }

    /// Sliding-window + global blocks (Longformer/BigBird shape).
    pub fn local_global(t_r: usize, t_c: usize, window: usize, n_global: usize) -> BlockMask {
        let mut m = BlockMask::zeros(t_r, t_c);
        for i in 0..t_r {
            let lo = i.saturating_sub(window);
            let hi = (i + window + 1).min(t_c);
            for j in lo..hi {
                m.set(i, j, true);
            }
            for j in 0..n_global.min(t_c) {
                m.set(i, j, true);
            }
        }
        for i in 0..n_global.min(t_r) {
            for j in 0..t_c {
                m.set(i, j, true);
            }
        }
        m
    }

    /// s — fraction of nonzero blocks (Proposition 4).
    pub fn sparsity(&self) -> f64 {
        self.bits.iter().filter(|&&b| b != 0).count() as f64 / self.bits.len() as f64
    }

    pub fn nonzero_blocks(&self) -> usize {
        self.bits.iter().filter(|&&b| b != 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_score_causal() {
        assert_eq!(masked_score(1.0, 3, 4, true, 10), NEG_INF);
        assert_eq!(masked_score(1.0, 4, 4, true, 10), 1.0);
        assert_eq!(masked_score(1.0, 5, 4, true, 10), 1.0);
    }

    #[test]
    fn masked_score_padding() {
        assert_eq!(masked_score(1.0, 0, 7, false, 7), NEG_INF);
        assert_eq!(masked_score(1.0, 0, 6, false, 7), 1.0);
    }

    #[test]
    fn butterfly_has_diagonal() {
        let m = BlockMask::butterfly(16, 16);
        for i in 0..16 {
            assert!(m.get(i, i));
        }
    }

    #[test]
    fn butterfly_sparsity_decreases() {
        let s8 = BlockMask::butterfly(8, 8).sparsity();
        let s64 = BlockMask::butterfly(64, 64).sparsity();
        assert!(s8 > s64, "{s8} vs {s64}");
    }

    #[test]
    fn butterfly_matches_python_8x8() {
        // Cross-checked against python butterfly_mask(8, 8).
        let m = BlockMask::butterfly(8, 8);
        let expected_row0 = [1, 1, 1, 0, 1, 0, 0, 0];
        for (j, &e) in expected_row0.iter().enumerate() {
            assert_eq!(m.get(0, j) as u8, e, "col {j}");
        }
    }

    #[test]
    fn local_global_window() {
        let m = BlockMask::local_global(8, 8, 1, 1);
        assert!(m.get(4, 3) && m.get(4, 4) && m.get(4, 5));
        assert!(!m.get(4, 6));
        assert!(m.get(4, 0) && m.get(0, 7));
    }

    #[test]
    fn dense_sparsity_is_one() {
        assert_eq!(BlockMask::dense(4, 4).sparsity(), 1.0);
    }

    #[test]
    fn dropout_scale_zero_p_is_identity() {
        assert_eq!(dropout_scale(0, 1, 2, 16, 0, 0.0), 1.0);
    }

    #[test]
    fn butterfly_degenerate_shapes_do_not_panic() {
        // t_c == 0 used to underflow `i.min(t_c - 1)` and index an empty
        // bit vector; all four degenerate corners must just be empty.
        for (t_r, t_c) in [(0usize, 0usize), (0, 4), (4, 0), (1, 1)] {
            let m = BlockMask::butterfly(t_r, t_c);
            assert_eq!((m.t_r, m.t_c), (t_r, t_c));
            assert_eq!(m.bits.len(), t_r * t_c);
        }
        assert!(BlockMask::butterfly(1, 1).get(0, 0));
        // local_global on the same corners stays well-defined too.
        for (t_r, t_c) in [(0usize, 0usize), (0, 4), (4, 0)] {
            let m = BlockMask::local_global(t_r, t_c, 1, 1);
            assert_eq!(m.bits.len(), t_r * t_c);
        }
    }

    #[test]
    fn butterfly_tall_and_wide_grids_stay_in_bounds() {
        // Tall grids clamp the diagonal to the last column (python
        // mirror semantics); every row keeps at least one live block and
        // no write lands out of bounds (get() would panic if one did).
        let tall = BlockMask::butterfly(9, 3);
        for i in 0..9 {
            assert!(tall.get(i, i.min(2)), "row {i} lost its diagonal block");
        }
        let wide = BlockMask::butterfly(3, 9);
        for i in 0..3 {
            assert!(wide.get(i, i));
        }
        // The stride bands stay within [0, t_c) on both shapes.
        assert_eq!(tall.bits.len(), 27);
        assert_eq!(wide.bits.len(), 27);
        assert!(tall.nonzero_blocks() > 0 && wide.nonzero_blocks() > 0);
    }

    #[test]
    fn dense_and_local_global_rectangular_grids() {
        // Rectangular K/V geometry: t_c derives from the key count, so
        // tall (t_r > t_c) and wide (t_r < t_c) grids must index in
        // bounds with sane patterns on every row.
        let tall = BlockMask::local_global(9, 3, 1, 1);
        assert_eq!(tall.bits.len(), 27);
        for i in 0..9 {
            assert!(tall.get(i, 0), "row {i} lost its global column");
        }
        assert!(tall.get(2, 1) && tall.get(2, 2)); // window clamped to t_c
        let wide = BlockMask::local_global(3, 9, 1, 1);
        assert_eq!(wide.bits.len(), 27);
        assert!(wide.get(0, 8), "global row must span the wide grid");
        assert!(wide.get(2, 1) && wide.get(2, 2) && wide.get(2, 3));
        assert!(!wide.get(2, 5), "window must not leak past w+1");
        // Dense covers any rectangle and reports full density.
        let dense = BlockMask::dense(2, 7);
        assert_eq!(dense.nonzero_blocks(), 14);
        assert_eq!(dense.sparsity(), 1.0);
    }

    #[test]
    fn dropout_stream_is_global_and_pinned() {
        // The stream is a pure function of (bh, row, GLOBAL col, n,
        // seed): a shard at key offset `lo` passing `lo + local_col`
        // reads exactly the unsharded kernel's columns [lo, hi).
        let (n, seed, p) = (16usize, 9u32, 0.5f32);
        let full: Vec<f32> = (0..n).map(|c| dropout_scale(3, 5, c, n, seed, p)).collect();
        for lo in [0usize, 4, 7] {
            for (cl, &expect) in full[lo..].iter().enumerate() {
                assert_eq!(dropout_scale(3, 5, lo + cl, n, seed, p), expect);
            }
        }
        // Regression pin: the exact keep/drop pattern of the unsharded
        // kernel for two (bh, row, n, seed, p) tuples. Any change to the
        // counter layout — e.g. using a local key count as the stride —
        // fails these literals loudly.
        let keeps: Vec<bool> =
            (0..8).map(|c| dropout_scale(0, 0, c, 8, 9, 0.5) != 0.0).collect();
        assert_eq!(keeps, [true, true, false, true, false, false, false, false]);
        let keeps2: Vec<bool> =
            (0..10).map(|c| dropout_scale(1, 3, c, 16, 7, 0.3) != 0.0).collect();
        assert_eq!(
            keeps2,
            [true, true, true, true, false, true, true, true, true, true]
        );
    }
}
