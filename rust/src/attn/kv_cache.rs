//! Paged KV cache for the serving tier: K/V history stored in
//! fixed-geometry tiles, with the TGI-style ragged-batch lifecycle.
//!
//! A decode step reads one (or a few) query rows against a long KV
//! history; the history grows by one row per generated token and
//! requests join/leave the batch continuously. Storing K/V contiguously
//! per request would force a full reallocation+copy per appended token,
//! so — as in TGI's `flash_causal_lm.py` ragged batches and vLLM-style
//! paged attention — the cache stores rows in **pages of `b_c` rows**,
//! where `b_c` is the kernel's column-tile height (the `BlockMask` tile
//! geometry): page `p` of a request holds its key rows
//! `[p·b_c, (p+1)·b_c)`, so a page *is* a column tile and
//! `attn::flash2::flash2_decode` spans map 1:1 onto page ranges.
//!
//! The batch lifecycle mirrors TGI's `filter`/`concatenate`: requests
//! are appended per decode step, dropped (with their pages) when they
//! finish via [`KvBatch::filter`], and two batches join via
//! [`KvBatch::concatenate`] — all three preserve exact tile contents
//! (property-tested in `rust/tests/kv_cache.rs`).
//!
//! HBM accounting: writing rows into the cache and reading tiles back
//! out go through **counted accessors** ([`RequestCache::append_kv`],
//! [`RequestCache::k_tile`], [`RequestCache::v_tile`]) — lint R5
//! applies to this file, so raw indexing of the K/V buffers outside the
//! sanctioned accessors is a finding. `filter`/`concatenate` are
//! metadata moves (page ownership transfers, no element traffic), which
//! is exactly why the paged layout wins: finishing requests cost zero
//! HBM. The decode kernel itself counts its K/V streaming analytically
//! (`sim::cost::flash2_decode`); the uncounted [`RequestCache::snapshot_k`]
//! / [`RequestCache::snapshot_v`] marshals exist only to hand the pool's
//! `'static` closures an owned bit-exact copy, the same convention as
//! `attn::batched`'s `OwnedSlice`.

use crate::sim::hbm::Hbm;

/// One fixed-geometry page: up to `b_c` K rows and V rows, allocated at
/// full capacity so appends never reallocate mid-page.
#[derive(Clone, Debug, PartialEq)]
struct KvPage {
    k: Vec<f32>, // [b_c, d], rows [0, rows) valid
    v: Vec<f32>, // [b_c, d], rows [0, rows) valid
    rows: usize,
}

/// The paged K/V history of ONE request. Pages are the kernel's column
/// tiles: every page except possibly the last holds exactly `b_c` rows.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestCache {
    b_c: usize,
    d: usize,
    pages: Vec<KvPage>,
    len: usize,
}

impl RequestCache {
    pub fn new(b_c: usize, d: usize) -> RequestCache {
        assert!(b_c >= 1 && d >= 1, "RequestCache: degenerate tile geometry");
        RequestCache { b_c, d, pages: Vec::new(), len: 0 }
    }

    /// Total K/V rows (tokens) cached.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Page/tile count: `len.div_ceil(b_c)`.
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    /// Valid rows of page `p` (only the last page may be partial).
    pub fn page_rows(&self, p: usize) -> usize {
        self.pages[p].rows
    }

    /// Tile height — the kernel's `Blocks::b_c`.
    pub fn b_c(&self) -> usize {
        self.b_c
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Append `rows` K/V rows (`k_rows`/`v_rows`: [rows, d], row-major)
    /// to the history, filling the last partial page first, then
    /// allocating fresh pages. Counted: every appended element is
    /// written to HBM exactly once (2·rows·d stores), and nothing
    /// already cached moves — the paged layout's append is O(new rows),
    /// never O(history).
    pub fn append_kv(&mut self, k_rows: &[f32], v_rows: &[f32], rows: usize, hbm: &mut Hbm) {
        let d = self.d;
        assert_eq!(k_rows.len(), rows * d, "append_kv: K row slice shape mismatch");
        assert_eq!(v_rows.len(), rows * d, "append_kv: V row slice shape mismatch");
        let mut done = 0usize;
        while done < rows {
            if self.len % self.b_c == 0 {
                // Last page full (or cache empty): open a fresh page at
                // full capacity so later in-page appends never move rows.
                self.pages.push(KvPage {
                    k: vec![0.0; self.b_c * d],
                    v: vec![0.0; self.b_c * d],
                    rows: 0,
                });
            }
            let page = self.pages.last_mut().expect("append_kv: page just ensured");
            let take = (self.b_c - page.rows).min(rows - done);
            let dst = page.rows * d;
            page.k[dst..dst + take * d].copy_from_slice(&k_rows[done * d..(done + take) * d]);
            page.v[dst..dst + take * d].copy_from_slice(&v_rows[done * d..(done + take) * d]);
            page.rows += take;
            self.len += take;
            done += take;
            hbm.store(2 * take * d);
        }
    }

    /// Counted read of K tile/page `t`: the page's valid rows stream
    /// through SRAM once (`page_rows(t)·d` loads). Returns the
    /// contiguous [rows, d] slice — pages ARE column tiles, so this is
    /// the decode kernel's K_j.
    pub fn k_tile(&self, t: usize, hbm: &mut Hbm) -> &[f32] {
        let page = &self.pages[t];
        hbm.load(page.rows * self.d);
        &page.k[..page.rows * self.d]
    }

    /// Counted read of V tile/page `t` — see [`RequestCache::k_tile`].
    pub fn v_tile(&self, t: usize, hbm: &mut Hbm) -> &[f32] {
        let page = &self.pages[t];
        hbm.load(page.rows * self.d);
        &page.v[..page.rows * self.d]
    }

    /// Uncounted flat copy of the valid K rows ([len, d]) — the owned
    /// marshal for the pool's `'static` closures. Bit-exact; the decode
    /// kernel's analytic per-tile counts are the HBM model for reading
    /// these rows, so copying here must NOT count (it would double-bill
    /// every tile).
    pub fn snapshot_k(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len * self.d);
        for page in &self.pages {
            out.extend_from_slice(&page.k[..page.rows * self.d]);
        }
        out
    }

    /// Uncounted flat copy of the valid V rows — see
    /// [`RequestCache::snapshot_k`].
    pub fn snapshot_v(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len * self.d);
        for page in &self.pages {
            out.extend_from_slice(&page.v[..page.rows * self.d]);
        }
        out
    }
}

/// A ragged batch of per-request caches — the TGI
/// `filter`/`concatenate` lifecycle. Entry order is insertion order and
/// every operation is a deterministic function of it (plain `Vec`
/// scans, no hashing), so the serving loop's schedule is reproducible.
#[derive(Clone, Debug, PartialEq)]
pub struct KvBatch {
    b_c: usize,
    d: usize,
    entries: Vec<(u64, RequestCache)>,
}

impl KvBatch {
    pub fn new(b_c: usize, d: usize) -> KvBatch {
        KvBatch { b_c, d, entries: Vec::new() }
    }

    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Request ids in batch order.
    pub fn ids(&self) -> Vec<u64> {
        self.entries.iter().map(|(id, _)| *id).collect()
    }

    /// Total cached tokens across all requests — the quantity the
    /// admission loop budgets.
    pub fn total_tokens(&self) -> usize {
        self.entries.iter().map(|(_, c)| c.len()).sum()
    }

    /// Join a new request with an empty cache. Ids must be unique.
    pub fn admit(&mut self, id: u64) {
        assert!(
            self.entries.iter().all(|(e, _)| *e != id),
            "KvBatch::admit: duplicate request id {id}"
        );
        self.entries.push((id, RequestCache::new(self.b_c, self.d)));
    }

    pub fn get(&self, id: u64) -> Option<&RequestCache> {
        self.entries.iter().find(|(e, _)| *e == id).map(|(_, c)| c)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut RequestCache> {
        self.entries.iter_mut().find(|(e, _)| *e == id).map(|(_, c)| c)
    }

    /// Counted append to one request's history — see
    /// [`RequestCache::append_kv`].
    pub fn append_kv(&mut self, id: u64, k_rows: &[f32], v_rows: &[f32], rows: usize, hbm: &mut Hbm) {
        self.get_mut(id)
            .unwrap_or_else(|| panic!("KvBatch::append_kv: unknown request id {id}"))
            .append_kv(k_rows, v_rows, rows, hbm);
    }

    /// TGI `filter`: the batch after dropping every request not in
    /// `keep`, preserving batch order. A metadata move — page ownership
    /// transfers, no element is read or written, so finishing requests
    /// cost zero HBM traffic (asserted by the never-read property test).
    pub fn filter(mut self, keep: &[u64]) -> KvBatch {
        self.entries.retain(|(id, _)| keep.contains(id));
        self
    }

    /// TGI `concatenate`: join two batches (e.g. the running batch and
    /// a freshly prefilled one), preserving order: all of `a`, then all
    /// of `b`. Metadata-only, like [`KvBatch::filter`]; geometries must
    /// match and ids stay unique.
    pub fn concatenate(a: KvBatch, b: KvBatch) -> KvBatch {
        assert_eq!((a.b_c, a.d), (b.b_c, b.d), "KvBatch::concatenate: geometry mismatch");
        let mut out = a;
        for (id, cache) in b.entries {
            assert!(
                out.entries.iter().all(|(e, _)| *e != id),
                "KvBatch::concatenate: duplicate request id {id}"
            );
            out.entries.push((id, cache));
        }
        out
    }
}
