//! Batched multi-head scheduler for the fast kernel pair — work
//! partitioning in the FlashAttention-2 (Dao, 2023) sense: most of the
//! practical speedup comes not from kernel math but from scheduling over
//! batch × heads × row blocks so every worker stays occupied even when a
//! single slice is small.
//!
//! Until this module, every hot path invoked `attn::flash2` once per
//! (batch, head) slice, paying a worker-pool spin-up per slice and
//! idling workers whenever one slice had fewer row blocks than threads.
//! The entry points here flatten **all** batch·head·row-block (and, in
//! the backward, batch·head·column-block) work items into a single
//! dynamically-drained pool — the [`Exec`](super::exec::Exec) handle's
//! persistent worker pool in production, or a per-call scope under
//! [`Exec::scoped`](super::exec::Exec::scoped):
//!
//! * [`flash2_forward_batched`] / [`flash2_backward_batched`] — the
//!   `[batch, heads, n, d]` entry points; the trainer preflight, the serve
//!   IO model, `attention_backward_batched` and the perf benches route
//!   through these.
//! * [`flash2_forward_many`] / [`flash2_backward_many`] — the
//!   shape-heterogeneous core (each slice carries its own q/k/v and
//!   [`AttnConfig`], including a per-shard `kv_offset`), which also
//!   schedules the sequence-parallel tree schedule's per-shard partials
//!   (`attn::distributed::shard_partials`).
//!
//! Every entry point takes the [`Exec`](super::exec::Exec) execution
//! handle (workers + fault plan + guardrail flag) and returns the output
//! together with the run's [`FaultReport`], or a typed [`AttnError`]
//! after a work item exhausts its retry budget. The pre-`Exec`
//! `(workers, plan)`-taking `*_checked` twins were removed after one
//! deprecation cycle; build the same behaviour with
//! `Exec::scoped(workers).with_plan(plan).validated()`.
//!
//! Two guarantees, both asserted by the tests below:
//!
//! * **Bitwise parity with the per-slice loop, for any worker count and
//!   either pool mode.** A work item is one (slice, row/column block)
//!   pair owning its output windows outright, dispatched through exactly
//!   the per-slice kernels' block sweeps (`flash2::row_block_sweep` and
//!   friends), and block arithmetic is self-contained — so output is
//!   bitwise identical to calling the per-slice kernel slice by slice,
//!   regardless of worker count or the dynamic claim order. Committed
//!   windows are stitched back in item-index order on the calling
//!   thread; workers race for items, never for output slots.
//! * **Unchanged per-slice HBM traffic.** Batching reorganises *when*
//!   work runs, never what moves: per the paper's per-slice IO analysis
//!   the instrumented counters must (and do) sum to exactly
//!   slice-count × the per-slice counts — the closed forms
//!   `sim::cost::flash2_fwd_batched` / `flash2_bwd_batched` are asserted
//!   access-for-access in `rust/tests/io_complexity.rs`.
//!
//! Dropout streams stay per-slice: slice `s` runs with
//! `bh_index = cfg.bh_index + s`, exactly what the per-slice loop did.

use std::sync::Arc;

use super::block_sparse::{
    check_mask_geometry, mask_tile_base, sparse_dq_row_sweep, sparse_row_block_sweep,
};
use super::exec::Exec;
use super::faults::{AttnError, FaultPlan, FaultReport, FaultSite, PoolItem};
use super::flash::Blocks;
use super::flash2::{
    dkv_col_sweep, dkv_col_sweep_filtered, dq_row_sweep, row_block_sweep, Flash2Output,
};
use super::masks::BlockMask;
use super::{AttnConfig, AttnGrads, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::{dot4, Tensor};

/// One independent forward slice for the many-slice scheduler: flat
/// row-major q: [n, d] and k, v: [n_k, d], plus the slice's own config
/// (the sharded driver sets `kv_offset` per shard so every decision is
/// made in global key coordinates; the batched entry points advance
/// `bh_index` per slice).
pub struct AttnSlice<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub n: usize,
    pub n_k: usize,
    pub d: usize,
    pub cfg: AttnConfig,
}

/// One independent backward slice: the forward's inputs and outputs plus
/// dO and the forward's logsumexp row.
pub struct AttnGradSlice<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub o: &'a [f32],
    pub dout: &'a [f32],
    pub lse: &'a [f32],
    pub n: usize,
    pub n_k: usize,
    pub d: usize,
    pub cfg: AttnConfig,
}

/// Softmax statistics for a batched workload: one logsumexp row per
/// (batch, head) slice, stored flat as [slices · n].
#[derive(Clone, Debug)]
pub struct BatchedAttnStats {
    /// Query rows per slice.
    pub n: usize,
    pub lse: Vec<f32>,
}

impl BatchedAttnStats {
    pub fn slices(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.lse.len() / self.n
        }
    }

    /// Borrow slice `s`'s statistics in the per-slice representation.
    pub fn slice(&self, s: usize) -> AttnStats<'_> {
        AttnStats::Lse(&self.lse[s * self.n..(s + 1) * self.n])
    }
}

/// Forward outputs of the batched fast kernel: O shaped
/// [batch, heads, n, d] plus one logsumexp row per slice.
#[derive(Clone, Debug)]
pub struct BatchedFlash2Output {
    pub o: Tensor,
    pub stats: BatchedAttnStats,
}

/// Rows covered by row/column block `b` of size `bsz` over `total` rows.
pub(crate) fn block_rows(b: usize, bsz: usize, total: usize) -> usize {
    ((b + 1) * bsz).min(total) - b * bsz
}

/// A strictly-finite window scan (gradient and O windows).
fn all_finite(xs: &[f32]) -> bool {
    xs.iter().all(|x| x.is_finite())
}

/// A logsumexp window scan: finite or *exactly* −∞ (the defined
/// all-masked value) — anything else (NaN, +∞) trips the guardrail.
fn lse_defined(xs: &[f32]) -> bool {
    xs.iter().all(|&x| x.is_finite() || x == f32::NEG_INFINITY)
}

/// One (slice, row block) forward work item, owning its disjoint O and
/// logsumexp windows outright (the deterministic item → output-slot
/// mapping the persistent pool relies on: windows are stitched back in
/// item order after the run, so claim order can never touch placement).
/// Shared by the dense/sparse batched schedulers and the ring schedule
/// (which has a single logical slice, `s = 0`).
pub(crate) struct FwdItem {
    pub s: usize,
    pub rb: usize,
    pub o_win: Vec<f32>,
    pub lse_win: Vec<f32>,
}

impl PoolItem for FwdItem {
    fn id(&self) -> (usize, usize) {
        (self.s, self.rb)
    }
    fn reset(&mut self) {
        self.o_win.fill(0.0);
        self.lse_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        all_finite(&self.o_win) && lse_defined(&self.lse_win)
    }
    fn poison(&mut self) {
        self.o_win.fill(f32::NAN);
        self.lse_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        use crate::attn::audit::SlotClaim;
        vec![SlotClaim::of("o", &self.o_win), SlotClaim::of("lse", &self.lse_win)]
    }
}

/// One (slice, row block) dQ work item.
pub(crate) struct DqItem {
    pub s: usize,
    pub rb: usize,
    pub dq_win: Vec<f32>,
}

impl PoolItem for DqItem {
    fn id(&self) -> (usize, usize) {
        (self.s, self.rb)
    }
    fn reset(&mut self) {
        self.dq_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        all_finite(&self.dq_win)
    }
    fn poison(&mut self) {
        self.dq_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        vec![crate::attn::audit::SlotClaim::of("dq", &self.dq_win)]
    }
}

/// One (slice, column block) dK/dV work item.
pub(crate) struct DkvItem {
    pub s: usize,
    pub cb: usize,
    pub dk_win: Vec<f32>,
    pub dv_win: Vec<f32>,
}

impl PoolItem for DkvItem {
    fn id(&self) -> (usize, usize) {
        (self.s, self.cb)
    }
    fn reset(&mut self) {
        self.dk_win.fill(0.0);
        self.dv_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        all_finite(&self.dk_win) && all_finite(&self.dv_win)
    }
    fn poison(&mut self) {
        self.dk_win.fill(f32::NAN);
        self.dv_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        use crate::attn::audit::SlotClaim;
        vec![SlotClaim::of("dk", &self.dk_win), SlotClaim::of("dv", &self.dv_win)]
    }
}

/// A slice's inputs, owned — the persistent pool's work closures must be
/// `'static`, so each run snapshots the slice data once (an O(input)
/// copy against O(n·n_k·d) block arithmetic; f32 copies are bit-exact,
/// so parity and traffic accounting are untouched — HBM counts are
/// analytic, not measured).
struct OwnedSlice {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    n: usize,
    n_k: usize,
    d: usize,
    cfg: AttnConfig,
}

/// A backward slice's inputs, owned, with the phase-0 D row folded in
/// (dO and O themselves are only needed by phase 0, which runs on the
/// calling thread).
struct OwnedGradSlice {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    dout: Vec<f32>,
    lse: Vec<f32>,
    d_vec: Vec<f32>,
    n: usize,
    n_k: usize,
    d: usize,
    cfg: AttnConfig,
}

/// Fast exact forward over many independent slices through ONE worker
/// pool: every (slice, row block) pair becomes a work item. Outputs (and
/// HBM totals) are bitwise identical to running
/// [`super::flash2::flash2_forward`] per slice, for any worker count and
/// either pool mode of `exec`.
pub fn flash2_forward_many(
    slices: &[AttnSlice<'_>],
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(Vec<Flash2Output>, FaultReport), AttnError> {
    forward_many_sited(slices, blocks, exec, hbm, FaultSite::BatchedFwd)
}

/// Site-parameterised core: the tree schedule routes its per-shard
/// partials through here under [`FaultSite::TreePartial`].
pub(crate) fn forward_many_sited(
    slices: &[AttnSlice<'_>],
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
    site: FaultSite,
) -> Result<(Vec<Flash2Output>, FaultReport), AttnError> {
    for (s, sl) in slices.iter().enumerate() {
        assert_eq!(sl.q.len(), sl.n * sl.d, "slice {s}: Q shape mismatch");
        assert_eq!(sl.k.len(), sl.n_k * sl.d, "slice {s}: K shape mismatch");
        assert_eq!(sl.v.len(), sl.n_k * sl.d, "slice {s}: V shape mismatch");
    }
    let mut outs: Vec<Flash2Output> = slices
        .iter()
        .map(|sl| {
            let mut lse = vec![0.0f32; sl.n];
            if sl.n_k == 0 {
                // No keys: same defined all-masked semantics as the
                // per-slice kernel's early return (zero rows, lse = -inf).
                lse.fill(f32::NEG_INFINITY);
            }
            Flash2Output { o: Tensor::zeros(&[sl.n, sl.d]), lse }
        })
        .collect();

    let mut items: Vec<FwdItem> = Vec::new();
    for (s, sl) in slices.iter().enumerate() {
        if sl.n_k == 0 {
            continue;
        }
        for rb in 0..sl.n.div_ceil(blocks.b_r) {
            let rows = block_rows(rb, blocks.b_r, sl.n);
            items.push(FwdItem { s, rb, o_win: vec![0.0; rows * sl.d], lse_win: vec![0.0; rows] });
        }
    }

    let data: Vec<OwnedSlice> = slices
        .iter()
        .map(|sl| OwnedSlice {
            q: sl.q.to_vec(),
            k: sl.k.to_vec(),
            v: sl.v.to_vec(),
            n: sl.n,
            n_k: sl.n_k,
            d: sl.d,
            cfg: sl.cfg.clone(),
        })
        .collect();
    let (done, report) = exec.run(items, site, hbm, move |it: &mut FwdItem| {
        let sl = &data[it.s];
        let tau = sl.cfg.tau_for(sl.d);
        let kv_limit = sl.cfg.kv_limit(sl.n_k);
        row_block_sweep(
            &sl.q, &sl.k, &sl.v, sl.n, sl.n_k, sl.d, &sl.cfg, blocks, tau, kv_limit, it.rb,
            it.rb + 1, &mut it.o_win, &mut it.lse_win,
        )
    })?;
    for it in done {
        let d = slices[it.s].d;
        let r0 = it.rb * blocks.b_r;
        let out = &mut outs[it.s];
        out.o.data[r0 * d..r0 * d + it.o_win.len()].copy_from_slice(&it.o_win);
        out.lse[r0..r0 + it.lse_win.len()].copy_from_slice(&it.lse_win);
    }

    Ok((outs, report))
}

/// Fast exact backward over many independent slices through one worker
/// pool per phase: the per-slice D epilogue runs inline, then every
/// (slice, row block) dQ item and every (slice, column block) dK/dV item
/// is scheduled dynamically. Bitwise identical to running
/// [`super::flash2::flash2_backward`] per slice, for any worker count
/// and either pool mode of `exec`.
pub fn flash2_backward_many(
    slices: &[AttnGradSlice<'_>],
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(Vec<AttnGrads>, FaultReport), AttnError> {
    for (s, sl) in slices.iter().enumerate() {
        assert_eq!(sl.q.len(), sl.n * sl.d, "slice {s}: Q shape mismatch");
        assert_eq!(sl.k.len(), sl.n_k * sl.d, "slice {s}: K shape mismatch");
        assert_eq!(sl.v.len(), sl.n_k * sl.d, "slice {s}: V shape mismatch");
        assert_eq!(sl.o.len(), sl.n * sl.d, "slice {s}: O shape mismatch");
        assert_eq!(sl.dout.len(), sl.n * sl.d, "slice {s}: dO shape mismatch");
        assert_eq!(sl.lse.len(), sl.n, "slice {s}: stats length mismatch");
    }
    let mut grads: Vec<AttnGrads> = slices
        .iter()
        .map(|sl| AttnGrads {
            dq: Tensor::zeros(&[sl.n, sl.d]),
            dk: Tensor::zeros(&[sl.n_k, sl.d]),
            dv: Tensor::zeros(&[sl.n_k, sl.d]),
        })
        .collect();

    // Phase 0, per slice: D_i = rowsum(dO ∘ O) in one epilogue pass each —
    // the same accounting as the per-slice kernel (dO/O loaded once, D
    // stored once). O(slices·n·d) work, so it stays on this thread; slices
    // with no rows or no keys are skipped exactly like the per-slice
    // kernel's early return (no traffic, zero gradients).
    let d_vecs: Vec<Vec<f32>> = slices
        .iter()
        .map(|sl| {
            if sl.n == 0 || sl.n_k == 0 {
                return Vec::new();
            }
            hbm.load(2 * sl.n * sl.d);
            let dv: Vec<f32> = (0..sl.n)
                .map(|r| dot4(&sl.dout[r * sl.d..(r + 1) * sl.d], &sl.o[r * sl.d..(r + 1) * sl.d]))
                .collect();
            hbm.store(sl.n);
            dv
        })
        .collect();

    let mut dq_items: Vec<DqItem> = Vec::new();
    let mut dkv_items: Vec<DkvItem> = Vec::new();
    for (s, sl) in slices.iter().enumerate() {
        if sl.n == 0 || sl.n_k == 0 {
            continue;
        }
        for rb in 0..sl.n.div_ceil(blocks.b_r) {
            let rows = block_rows(rb, blocks.b_r, sl.n);
            dq_items.push(DqItem { s, rb, dq_win: vec![0.0; rows * sl.d] });
        }
        for cb in 0..sl.n_k.div_ceil(blocks.b_c) {
            let cols = block_rows(cb, blocks.b_c, sl.n_k);
            dkv_items.push(DkvItem {
                s,
                cb,
                dk_win: vec![0.0; cols * sl.d],
                dv_win: vec![0.0; cols * sl.d],
            });
        }
    }

    // One owned snapshot shared by both phases' work closures.
    let data: Arc<Vec<OwnedGradSlice>> = Arc::new(
        slices
            .iter()
            .zip(d_vecs)
            .map(|(sl, d_vec)| OwnedGradSlice {
                q: sl.q.to_vec(),
                k: sl.k.to_vec(),
                v: sl.v.to_vec(),
                dout: sl.dout.to_vec(),
                lse: sl.lse.to_vec(),
                d_vec,
                n: sl.n,
                n_k: sl.n_k,
                d: sl.d,
                cfg: sl.cfg.clone(),
            })
            .collect(),
    );

    // Phase 1: all slices' dQ row blocks through one pool.
    let dq_data = Arc::clone(&data);
    let (dq_done, mut report) =
        exec.run(dq_items, FaultSite::BatchedDq, hbm, move |it: &mut DqItem| {
            let sl = &dq_data[it.s];
            let tau = sl.cfg.tau_for(sl.d);
            let kv_limit = sl.cfg.kv_limit(sl.n_k);
            dq_row_sweep(
                &sl.q, &sl.k, &sl.v, &sl.dout, &sl.lse, &sl.d_vec, sl.n, sl.n_k, sl.d, &sl.cfg,
                blocks, tau, kv_limit, it.rb, it.rb + 1, &mut it.dq_win,
            )
        })?;
    for it in dq_done {
        let d = slices[it.s].d;
        let r0 = it.rb * blocks.b_r;
        grads[it.s].dq.data[r0 * d..r0 * d + it.dq_win.len()].copy_from_slice(&it.dq_win);
    }

    // Phase 2: all slices' dK/dV column blocks through one pool.
    let (dkv_done, dkv_report) =
        exec.run(dkv_items, FaultSite::BatchedDkv, hbm, move |it: &mut DkvItem| {
            let sl = &data[it.s];
            let tau = sl.cfg.tau_for(sl.d);
            let kv_limit = sl.cfg.kv_limit(sl.n_k);
            dkv_col_sweep(
                &sl.q, &sl.k, &sl.v, &sl.dout, &sl.lse, &sl.d_vec, sl.n, sl.n_k, sl.d, &sl.cfg,
                blocks, tau, kv_limit, it.cb, it.cb + 1, &mut it.dk_win, &mut it.dv_win,
            )
        })?;
    for it in dkv_done {
        let d = slices[it.s].d;
        let c0 = it.cb * blocks.b_c;
        let g = &mut grads[it.s];
        g.dk.data[c0 * d..c0 * d + it.dk_win.len()].copy_from_slice(&it.dk_win);
        g.dv.data[c0 * d..c0 * d + it.dv_win.len()].copy_from_slice(&it.dv_win);
    }
    report.merge(&dkv_report);

    Ok((grads, report))
}

/// Check and decompose a [batch, heads, rows, d] tensor.
fn dims4(t: &Tensor, what: &str) -> (usize, usize, usize, usize) {
    assert_eq!(t.rank(), 4, "{what} must be [batch, heads, rows, d]");
    (t.shape[0], t.shape[1], t.shape[2], t.shape[3])
}

/// Copy (batch·head) slice `s` of a rank-4 tensor out as an [n, d] matrix
/// (tests and the reference kernels' per-slice fallback paths).
pub fn bh_slice(t: &Tensor, s: usize) -> Tensor {
    let (_, _, n, d) = dims4(t, "bh_slice input");
    Tensor::from_vec(&[n, d], t.data[s * n * d..(s + 1) * n * d].to_vec())
}

/// Batched multi-head fast forward. q: [batch, heads, n, d];
/// k, v: [batch, heads, n_k, d] (rectangular K/V serves cross-attention
/// and sharded layouts). All batch·head·row-block work items run in one
/// pool on `exec`; the result is bitwise independent of the worker count
/// and pool mode, and bitwise identical to the per-slice loop it
/// replaces. Slice `s` runs with `bh_index = cfg.bh_index + s`, so
/// dropout streams match the per-slice convention. A typed [`AttnError`]
/// names the (batch, head) slice and q row block of an item that
/// exhausted its retry budget.
pub fn flash2_forward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(BatchedFlash2Output, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "flash2_forward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "flash2_forward_batched K");
    assert_eq!((bk, hk, dk), (b, h, d), "flash2_forward_batched: K batch/heads/feature mismatch");
    assert_eq!(v.shape, k.shape, "flash2_forward_batched: V shape mismatch");
    let slices: Vec<AttnSlice<'_>> = (0..b * h)
        .map(|s| AttnSlice {
            q: &q.data[s * n * d..(s + 1) * n * d],
            k: &k.data[s * n_k * d..(s + 1) * n_k * d],
            v: &v.data[s * n_k * d..(s + 1) * n_k * d],
            n,
            n_k,
            d,
            cfg: AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() },
        })
        .collect();
    let (outs, report) = forward_many_sited(&slices, blocks, exec, hbm, FaultSite::BatchedFwd)
        .map_err(|e| e.located(h))?;
    let mut o = Tensor::zeros(&[b, h, n, d]);
    let mut lse = Vec::with_capacity(b * h * n);
    for (s, out) in outs.into_iter().enumerate() {
        o.data[s * n * d..(s + 1) * n * d].copy_from_slice(&out.o.data);
        lse.extend_from_slice(&out.lse);
    }
    Ok((BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } }, report))
}

/// Batched multi-head fast backward: the gradient counterpart of
/// [`flash2_forward_batched`], with every batch·head·block work item of
/// each phase in one pool on `exec`. `stats` holds one logsumexp row per
/// slice (the batched forward's output). Returns [batch, heads, …, d]
/// gradients; bitwise identical to the per-slice loop for any worker
/// count and pool mode. Typed-error provenance names the (batch, head)
/// slice and the row (dQ) or column (dK/dV) block.
#[allow(clippy::too_many_arguments)]
pub fn flash2_backward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "flash2_backward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "flash2_backward_batched K");
    assert_eq!((bk, hk, dk), (b, h, d), "flash2_backward_batched: K batch/heads/feature mismatch");
    assert_eq!(v.shape, k.shape, "flash2_backward_batched: V shape mismatch");
    assert_eq!(o.shape, q.shape, "flash2_backward_batched: O shape mismatch");
    assert_eq!(dout.shape, q.shape, "flash2_backward_batched: dO shape mismatch");
    assert_eq!(stats.n, n, "flash2_backward_batched: stats row-count mismatch");
    assert_eq!(stats.lse.len(), b * h * n, "flash2_backward_batched: stats slice-count mismatch");
    let slices: Vec<AttnGradSlice<'_>> = (0..b * h)
        .map(|s| AttnGradSlice {
            q: &q.data[s * n * d..(s + 1) * n * d],
            k: &k.data[s * n_k * d..(s + 1) * n_k * d],
            v: &v.data[s * n_k * d..(s + 1) * n_k * d],
            o: &o.data[s * n * d..(s + 1) * n * d],
            dout: &dout.data[s * n * d..(s + 1) * n * d],
            lse: &stats.lse[s * n..(s + 1) * n],
            n,
            n_k,
            d,
            cfg: AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() },
        })
        .collect();
    let (per_slice, report) =
        flash2_backward_many(&slices, blocks, exec, hbm).map_err(|e| e.located(h))?;
    let mut dq4 = Tensor::zeros(&[b, h, n, d]);
    let mut dk4 = Tensor::zeros(&[b, h, n_k, d]);
    let mut dv4 = Tensor::zeros(&[b, h, n_k, d]);
    for (s, g) in per_slice.into_iter().enumerate() {
        dq4.data[s * n * d..(s + 1) * n * d].copy_from_slice(&g.dq.data);
        dk4.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dk.data);
        dv4.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dv.data);
    }
    Ok((AttnGrads { dq: dq4, dk: dk4, dv: dv4 }, report))
}

/// Resolve the mask for slice `s` of a [batch, heads, …] workload.
/// Masks may be shared (one mask), per-head (`heads` masks, shared
/// across the batch — the common multi-head-sparsity layout), or fully
/// per-slice (`batch · heads` masks).
fn mask_for<'m>(masks: &'m [BlockMask], heads: usize, slices: usize, s: usize) -> &'m BlockMask {
    match masks.len() {
        1 => &masks[0],
        l if l == heads => &masks[s % heads],
        l if l == slices => &masks[s],
        l => panic!(
            "block_sparse2 batched: {l} masks for {slices} slices ({heads} heads); \
             pass 1, heads, or batch*heads masks"
        ),
    }
}

/// The sparse schedulers' owned per-run snapshot, shared between phases.
struct SparseBatch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    masks: Vec<BlockMask>,
    per_cfg: Vec<AttnConfig>,
    n: usize,
    n_k: usize,
    d: usize,
    h: usize,
    slices: usize,
    tile_base: usize,
}

impl SparseBatch {
    fn qs(&self, s: usize) -> &[f32] {
        &self.q[s * self.n * self.d..(s + 1) * self.n * self.d]
    }
    fn ks(&self, s: usize) -> &[f32] {
        &self.k[s * self.n_k * self.d..(s + 1) * self.n_k * self.d]
    }
    fn vs(&self, s: usize) -> &[f32] {
        &self.v[s * self.n_k * self.d..(s + 1) * self.n_k * self.d]
    }
    fn mask(&self, s: usize) -> &BlockMask {
        mask_for(&self.masks, self.h, self.slices, s)
    }
}

/// Batched multi-head fast **block-sparse** forward: the sparse
/// counterpart of [`flash2_forward_batched`]. q: [batch, heads, n, d];
/// k, v: [batch, heads, n_k, d]. Every batch·head·row-block work item
/// runs through one dynamically-drained pool on `exec`, dispatching the
/// identical per-block sparse sweep
/// (`attn::block_sparse::sparse_row_block_sweep`), so output is bitwise
/// identical to the per-slice loop for any worker count and pool mode.
/// Per-head masks are allowed (see [`mask_for`]); slice `s` runs with
/// `bh_index = cfg.bh_index + s`.
pub fn block_sparse2_forward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(BatchedFlash2Output, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "block_sparse2_forward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "block_sparse2_forward_batched K");
    assert_eq!(
        (bk, hk, dk),
        (b, h, d),
        "block_sparse2_forward_batched: K batch/heads/feature mismatch"
    );
    assert_eq!(v.shape, k.shape, "block_sparse2_forward_batched: V shape mismatch");
    let slices = b * h;
    let mut o = Tensor::zeros(&[b, h, n, d]);
    let mut lse = vec![0.0f32; slices * n];
    if n == 0 || n_k == 0 {
        // No keys: the per-slice kernel's defined all-masked semantics.
        lse.fill(f32::NEG_INFINITY);
        let out = BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } };
        return Ok((out, FaultReport::default()));
    }
    let tile_base = mask_tile_base(cfg.kv_offset, blocks.b_c);
    let t_r = n.div_ceil(blocks.b_r);
    let t_c = n_k.div_ceil(blocks.b_c);
    for s in 0..slices {
        check_mask_geometry(mask_for(masks, h, slices, s), t_r, tile_base, t_c);
    }
    let per_cfg: Vec<AttnConfig> = (0..slices)
        .map(|s| AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() })
        .collect();

    let items: Vec<FwdItem> = (0..slices * t_r)
        .map(|idx| {
            let rb = idx % t_r;
            let rows = block_rows(rb, blocks.b_r, n);
            FwdItem { s: idx / t_r, rb, o_win: vec![0.0; rows * d], lse_win: vec![0.0; rows] }
        })
        .collect();

    let data = SparseBatch {
        q: q.data.clone(),
        k: k.data.clone(),
        v: v.data.clone(),
        masks: masks.to_vec(),
        per_cfg,
        n,
        n_k,
        d,
        h,
        slices,
        tile_base,
    };
    let (done, report) = exec
        .run(items, FaultSite::SparseFwd, hbm, move |it: &mut FwdItem| {
            let cfg_s = &data.per_cfg[it.s];
            sparse_row_block_sweep(
                data.qs(it.s),
                data.ks(it.s),
                data.vs(it.s),
                data.n,
                data.n_k,
                data.d,
                data.mask(it.s),
                data.tile_base,
                cfg_s,
                blocks,
                cfg_s.tau_for(data.d),
                cfg_s.kv_limit(data.n_k),
                it.rb,
                it.rb + 1,
                &mut it.o_win,
                &mut it.lse_win,
            )
        })
        .map_err(|e| e.located(h))?;
    for it in done {
        let r0 = it.rb * blocks.b_r;
        let base = it.s * n * d + r0 * d;
        o.data[base..base + it.o_win.len()].copy_from_slice(&it.o_win);
        lse[it.s * n + r0..it.s * n + r0 + it.lse_win.len()].copy_from_slice(&it.lse_win);
    }

    Ok((BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } }, report))
}

/// Batched multi-head fast block-sparse backward: the sparse
/// counterpart of [`flash2_backward_batched`] — per-slice D epilogues,
/// then every batch·head·row-block dQ item and batch·head·column-block
/// dK/dV item through one pool per phase on `exec`, each skipping its
/// mask's zero blocks. Bitwise identical to the per-slice
/// `attn::block_sparse::block_sparse2_backward` loop for any worker
/// count and pool mode.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse2_backward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    exec: &Exec,
    hbm: &mut Hbm,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "block_sparse2_backward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "block_sparse2_backward_batched K");
    assert_eq!(
        (bk, hk, dk),
        (b, h, d),
        "block_sparse2_backward_batched: K batch/heads/feature mismatch"
    );
    assert_eq!(v.shape, k.shape, "block_sparse2_backward_batched: V shape mismatch");
    assert_eq!(o.shape, q.shape, "block_sparse2_backward_batched: O shape mismatch");
    assert_eq!(dout.shape, q.shape, "block_sparse2_backward_batched: dO shape mismatch");
    assert_eq!(stats.n, n, "block_sparse2_backward_batched: stats row-count mismatch");
    assert_eq!(
        stats.lse.len(),
        b * h * n,
        "block_sparse2_backward_batched: stats slice-count mismatch"
    );
    let slices = b * h;
    let mut dq4 = Tensor::zeros(&[b, h, n, d]);
    let mut dk4 = Tensor::zeros(&[b, h, n_k, d]);
    let mut dv4 = Tensor::zeros(&[b, h, n_k, d]);
    if n == 0 || n_k == 0 {
        return Ok((AttnGrads { dq: dq4, dk: dk4, dv: dv4 }, FaultReport::default()));
    }
    let tile_base = mask_tile_base(cfg.kv_offset, blocks.b_c);
    let t_r = n.div_ceil(blocks.b_r);
    let t_c = n_k.div_ceil(blocks.b_c);
    for s in 0..slices {
        check_mask_geometry(mask_for(masks, h, slices, s), t_r, tile_base, t_c);
    }
    let per_cfg: Vec<AttnConfig> = (0..slices)
        .map(|s| AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() })
        .collect();

    // Phase 0, per slice: D_i = rowsum(dO ∘ O), one epilogue pass each —
    // identical accounting to the per-slice kernel.
    let d_vecs: Vec<Vec<f32>> = (0..slices)
        .map(|s| {
            hbm.load(2 * n * d);
            let base = s * n * d;
            let dv: Vec<f32> = (0..n)
                .map(|r| {
                    dot4(
                        &dout.data[base + r * d..base + (r + 1) * d],
                        &o.data[base + r * d..base + (r + 1) * d],
                    )
                })
                .collect();
            hbm.store(n);
            dv
        })
        .collect();

    let dq_items: Vec<DqItem> = (0..slices * t_r)
        .map(|idx| {
            let rb = idx % t_r;
            DqItem { s: idx / t_r, rb, dq_win: vec![0.0; block_rows(rb, blocks.b_r, n) * d] }
        })
        .collect();
    let dkv_items: Vec<DkvItem> = (0..slices * t_c)
        .map(|idx| {
            let cb = idx % t_c;
            let cols = block_rows(cb, blocks.b_c, n_k);
            DkvItem { s: idx / t_c, cb, dk_win: vec![0.0; cols * d], dv_win: vec![0.0; cols * d] }
        })
        .collect();

    struct SparseBwd {
        batch: SparseBatch,
        dout: Vec<f32>,
        lse: Vec<f32>,
        d_vecs: Vec<Vec<f32>>,
    }
    let data = Arc::new(SparseBwd {
        batch: SparseBatch {
            q: q.data.clone(),
            k: k.data.clone(),
            v: v.data.clone(),
            masks: masks.to_vec(),
            per_cfg,
            n,
            n_k,
            d,
            h,
            slices,
            tile_base,
        },
        dout: dout.data.clone(),
        lse: stats.lse.clone(),
        d_vecs,
    });

    // Phase 1: all slices' dQ row blocks through one pool.
    let dq_data = Arc::clone(&data);
    let (dq_done, mut report) = exec
        .run(dq_items, FaultSite::SparseDq, hbm, move |it: &mut DqItem| {
            let bt = &dq_data.batch;
            let cfg_s = &bt.per_cfg[it.s];
            sparse_dq_row_sweep(
                bt.qs(it.s),
                bt.ks(it.s),
                bt.vs(it.s),
                &dq_data.dout[it.s * bt.n * bt.d..(it.s + 1) * bt.n * bt.d],
                &dq_data.lse[it.s * bt.n..(it.s + 1) * bt.n],
                &dq_data.d_vecs[it.s],
                bt.n,
                bt.n_k,
                bt.d,
                bt.mask(it.s),
                bt.tile_base,
                cfg_s,
                blocks,
                cfg_s.tau_for(bt.d),
                cfg_s.kv_limit(bt.n_k),
                it.rb,
                it.rb + 1,
                &mut it.dq_win,
            )
        })
        .map_err(|e| e.located(h))?;
    for it in dq_done {
        let base = it.s * n * d + it.rb * blocks.b_r * d;
        dq4.data[base..base + it.dq_win.len()].copy_from_slice(&it.dq_win);
    }

    // Phase 2: all slices' dK/dV column blocks through one pool.
    let (dkv_done, dkv_report) = exec
        .run(dkv_items, FaultSite::SparseDkv, hbm, move |it: &mut DkvItem| {
            let bt = &data.batch;
            let cfg_s = &bt.per_cfg[it.s];
            let mask = bt.mask(it.s);
            dkv_col_sweep_filtered(
                bt.qs(it.s),
                bt.ks(it.s),
                bt.vs(it.s),
                &data.dout[it.s * bt.n * bt.d..(it.s + 1) * bt.n * bt.d],
                &data.lse[it.s * bt.n..(it.s + 1) * bt.n],
                &data.d_vecs[it.s],
                bt.n,
                bt.n_k,
                bt.d,
                cfg_s,
                blocks,
                cfg_s.tau_for(bt.d),
                cfg_s.kv_limit(bt.n_k),
                it.cb,
                it.cb + 1,
                &mut it.dk_win,
                &mut it.dv_win,
                |i, j| mask.get(i, bt.tile_base + j),
            )
        })
        .map_err(|e| e.located(h))?;
    for it in dkv_done {
        let base = it.s * n_k * d + it.cb * blocks.b_c * d;
        dk4.data[base..base + it.dk_win.len()].copy_from_slice(&it.dk_win);
        dv4.data[base..base + it.dv_win.len()].copy_from_slice(&it.dv_win);
    }
    report.merge(&dkv_report);

    Ok((AttnGrads { dq: dq4, dk: dk4, dv: dv4 }, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash2::{flash2_backward, flash2_forward};
    use crate::attn::{attention_backward_batched, BackwardKernel};
    use crate::util::prop::{choose, for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn rand4(shape: &[usize], rng: &mut SplitMix64) -> Tensor {
        Tensor::randn(shape, rng, 1.0)
    }

    /// Reference: the per-slice loop the batched entry points replace.
    fn per_slice_forward(
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        cfg: &AttnConfig,
        blocks: Blocks,
        exec: &Exec,
        hbm: &mut Hbm,
    ) -> BatchedFlash2Output {
        let (b, h, n, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
        let mut o = Tensor::zeros(&[b, h, n, d]);
        let mut lse = Vec::new();
        for s in 0..b * h {
            let cfg_s = AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() };
            let (qs, ks, vs) = (bh_slice(q, s), bh_slice(k, s), bh_slice(v, s));
            let f = flash2_forward(&qs, &ks, &vs, &cfg_s, blocks, exec, hbm);
            o.data[s * n * d..(s + 1) * n * d].copy_from_slice(&f.o.data);
            lse.extend_from_slice(&f.lse);
        }
        BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } }
    }

    #[test]
    fn batched_forward_bitwise_matches_per_slice_loop() {
        // The ISSUE grid: batch × heads × (n, n_k) rectangular × causal ×
        // kv_len × dropout × blocks × workers. Parity must be bitwise —
        // the scheduler reuses the identical per-block sweeps — and must
        // hold on both the persistent pool and per-call scopes.
        for_each_case("batched_fwd_parity", 20, |rng| {
            let b = usize_in(rng, 1, 3);
            let h = usize_in(rng, 1, 3);
            let n = usize_in(rng, 2, 32);
            let n_k = if rng.next_f32() < 0.5 { n } else { usize_in(rng, 1, 40) };
            let d = *choose(rng, &[2usize, 4, 8]);
            let blocks = Blocks::explicit(usize_in(rng, 1, n), usize_in(rng, 1, n_k));
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let exec =
                if rng.next_f32() < 0.5 { Exec::new(workers) } else { Exec::scoped(workers) };
            let q = rand4(&[b, h, n, d], rng);
            let k = rand4(&[b, h, n_k, d], rng);
            let v = rand4(&[b, h, n_k, d], rng);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let ctx = format!(
                "b={b} h={h} n={n} n_k={n_k} d={d} blocks=({},{}) causal={causal} \
                 kv_len={kv_len:?} p={dropout_p} w={workers} scoped={}",
                blocks.b_r,
                blocks.b_c,
                exec.is_scoped()
            );
            let loop_out =
                per_slice_forward(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
            let (batched, _) =
                flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut Hbm::new()).unwrap();
            assert_eq!(batched.o.data, loop_out.o.data, "O not bitwise equal: {ctx}");
            assert_eq!(batched.stats.lse, loop_out.stats.lse, "lse not bitwise equal: {ctx}");
        });
    }

    #[test]
    fn batched_backward_bitwise_matches_per_slice_loop() {
        for_each_case("batched_bwd_parity", 20, |rng| {
            let b = usize_in(rng, 1, 3);
            let h = usize_in(rng, 1, 3);
            let n = usize_in(rng, 2, 28);
            let n_k = if rng.next_f32() < 0.5 { n } else { usize_in(rng, 1, 36) };
            let d = *choose(rng, &[2usize, 4, 8]);
            let blocks = Blocks::explicit(usize_in(rng, 1, n), usize_in(rng, 1, n_k));
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let exec =
                if rng.next_f32() < 0.5 { Exec::new(workers) } else { Exec::scoped(workers) };
            let q = rand4(&[b, h, n, d], rng);
            let k = rand4(&[b, h, n_k, d], rng);
            let v = rand4(&[b, h, n_k, d], rng);
            let dout = rand4(&[b, h, n, d], rng);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let ctx = format!(
                "b={b} h={h} n={n} n_k={n_k} d={d} blocks=({},{}) causal={causal} \
                 kv_len={kv_len:?} p={dropout_p} w={workers} scoped={}",
                blocks.b_r,
                blocks.b_c,
                exec.is_scoped()
            );
            let (fwd, _) =
                flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut Hbm::new()).unwrap();
            let (batched, _) = flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &exec, &mut Hbm::new(),
            )
            .unwrap();
            // Per-slice loop on identical inputs.
            let (mut dq, mut dk, mut dv) = (
                Tensor::zeros(&[b, h, n, d]),
                Tensor::zeros(&[b, h, n_k, d]),
                Tensor::zeros(&[b, h, n_k, d]),
            );
            for s in 0..b * h {
                let cfg_s = AttnConfig { bh_index: s as u32, ..cfg.clone() };
                let (qs, ks, vs) = (bh_slice(&q, s), bh_slice(&k, s), bh_slice(&v, s));
                let os = bh_slice(&fwd.o, s);
                let dos = bh_slice(&dout, s);
                let g = flash2_backward(
                    &qs,
                    &ks,
                    &vs,
                    &os,
                    &dos,
                    fwd.stats.slice(s),
                    &cfg_s,
                    blocks,
                    &Exec::scoped(1),
                    &mut Hbm::new(),
                );
                dq.data[s * n * d..(s + 1) * n * d].copy_from_slice(&g.dq.data);
                dk.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dk.data);
                dv.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dv.data);
            }
            assert_eq!(batched.dq.data, dq.data, "dQ not bitwise equal: {ctx}");
            assert_eq!(batched.dk.data, dk.data, "dK not bitwise equal: {ctx}");
            assert_eq!(batched.dv.data, dv.data, "dV not bitwise equal: {ctx}");
        });
    }

    #[test]
    fn batched_deterministic_and_traffic_invariant_across_worker_counts() {
        // Output bitwise identical AND instrumented HBM totals identical
        // for any worker count and either pool mode — scheduling must
        // change neither numerics nor modeled traffic.
        let mut rng = SplitMix64::new(31);
        let (b, h, n, d) = (2usize, 3usize, 40usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(8, 8);
        let mut h1 = Hbm::new();
        let (base, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &Exec::scoped(1), &mut h1).unwrap();
        let mut hb1 = Hbm::new();
        let (gbase, _) = flash2_backward_batched(
            &q,
            &k,
            &v,
            &base.o,
            &dout,
            &base.stats,
            &cfg,
            blocks,
            &Exec::scoped(1),
            &mut hb1,
        )
        .unwrap();
        for workers in [2usize, 3, 5, 8, 64] {
            for exec in [Exec::new(workers), Exec::scoped(workers)] {
                let mode = if exec.is_scoped() { "scoped" } else { "persistent" };
                let mut hw = Hbm::new();
                let (multi, _) =
                    flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut hw).unwrap();
                assert_eq!(base.o.data, multi.o.data, "O at {mode} workers={workers}");
                assert_eq!(base.stats.lse, multi.stats.lse, "lse at {mode} workers={workers}");
                assert_eq!(
                    (h1.loads, h1.stores),
                    (hw.loads, hw.stores),
                    "fwd hbm at {mode} w={workers}"
                );
                let mut hbw = Hbm::new();
                let (g, _) = flash2_backward_batched(
                    &q, &k, &v, &base.o, &dout, &base.stats, &cfg, blocks, &exec, &mut hbw,
                )
                .unwrap();
                assert_eq!(gbase.dq.data, g.dq.data, "dQ at {mode} workers={workers}");
                assert_eq!(gbase.dk.data, g.dk.data, "dK at {mode} workers={workers}");
                assert_eq!(gbase.dv.data, g.dv.data, "dV at {mode} workers={workers}");
                assert_eq!(
                    (hb1.loads, hb1.stores),
                    (hbw.loads, hbw.stores),
                    "bwd hbm at {mode} w={workers}"
                );
            }
        }
    }

    #[test]
    fn batched_backward_grads_match_finite_difference() {
        // FD check straight through the batched pair: d(sum O)/dx by
        // central differences on a [2, 2, n, d] causal+padded workload.
        let mut rng = SplitMix64::new(33);
        let (b, h, n, d) = (2usize, 2usize, 6usize, 4usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig::new().causal().kv_len(5);
        let blocks = Blocks::explicit(2, 3);
        let exec = Exec::new(2);
        let (fwd, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut Hbm::new()).unwrap();
        let dout = Tensor::full(&[b, h, n, d], 1.0);
        let (g, _) = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &exec, &mut Hbm::new(),
        )
        .unwrap();
        let f = |q_: &Tensor, k_: &Tensor, v_: &Tensor| -> f32 {
            flash2_forward_batched(q_, k_, v_, &cfg, blocks, &Exec::new(1), &mut Hbm::new())
                .unwrap()
                .0
                .o
                .data
                .iter()
                .sum()
        };
        let eps = 1e-3f32;
        // Indices spread across all four slices.
        for (which, (x, gx)) in [(0, (&q, &g.dq)), (1, (&k, &g.dk)), (2, (&v, &g.dv))] {
            for idx in [0usize, 13, 29, 41, 57, 73, 89] {
                let mut xp = x.clone();
                xp.data[idx] += eps;
                let mut xm = x.clone();
                xm.data[idx] -= eps;
                let (fp, fm) = match which {
                    0 => (f(&xp, &k, &v), f(&xm, &k, &v)),
                    1 => (f(&q, &xp, &v), f(&q, &xm, &v)),
                    _ => (f(&q, &k, &xp), f(&q, &k, &xm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                let an = gx.data[idx];
                assert!(
                    (fd - an).abs() < 2e-2 + 0.05 * an.abs(),
                    "which={which} idx={idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn entry_point_reference_kernels_agree_with_batched_fast_path() {
        // attention_backward_batched: every BackwardKernel role accepts
        // the [batch, heads, n, d] layout and they agree numerically —
        // gradient producers pick a policy role, not a layout.
        let mut rng = SplitMix64::new(35);
        let (b, h, n, d) = (2usize, 2usize, 16usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(4, 4);
        let exec = Exec::new(3);
        let (fwd, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut Hbm::new()).unwrap();
        let grads: Vec<AttnGrads> = [
            BackwardKernel::Standard,
            BackwardKernel::Flash,
            BackwardKernel::Flash2 { exec: &exec },
        ]
        .into_iter()
        .map(|kernel| {
            attention_backward_batched(
                kernel, &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &mut Hbm::new(),
            )
        })
        .collect();
        for g in &grads[1..] {
            assert!(grads[0].dq.max_abs_diff(&g.dq) < 1e-4);
            assert!(grads[0].dk.max_abs_diff(&g.dk) < 1e-4);
            assert!(grads[0].dv.max_abs_diff(&g.dv) < 1e-4);
        }
        assert_eq!(grads[2].dq.shape, vec![b, h, n, d]);
    }

    #[test]
    fn many_entry_handles_heterogeneous_slices() {
        // The sharded-driver shape: slices with different key counts and
        // per-slice kv_len remaps in one pool, bitwise equal to per-slice
        // calls.
        let mut rng = SplitMix64::new(37);
        let q = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let k = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let v = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let blocks = Blocks::explicit(8, 8);
        let ranges = [(0usize, 12usize, Some(12usize)), (12, 20, Some(8)), (20, 40, Some(1))];
        let slices: Vec<AttnSlice<'_>> = ranges
            .iter()
            .map(|&(lo, hi, kv)| AttnSlice {
                q: &q.data[..],
                k: &k.data[lo * 8..hi * 8],
                v: &v.data[lo * 8..hi * 8],
                n: 24,
                n_k: hi - lo,
                d: 8,
                cfg: AttnConfig::new().kv_len(kv.unwrap()),
            })
            .collect();
        let (outs, _) = flash2_forward_many(&slices, blocks, &Exec::new(3), &mut Hbm::new())
            .unwrap();
        for (i, (&(lo, hi, kv), out)) in ranges.iter().zip(&outs).enumerate() {
            let ks = k.slice_rows(lo, hi);
            let vs = v.slice_rows(lo, hi);
            let cfg = AttnConfig { kv_len: kv, ..Default::default() };
            let reference =
                flash2_forward(&q, &ks, &vs, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
            assert_eq!(out.o.data, reference.o.data, "shard {i} O");
            assert_eq!(out.lse, reference.lse, "shard {i} lse");
        }
    }

    #[test]
    fn no_keys_slice_keeps_all_masked_semantics() {
        // n_k = 0 (an empty shard / fully-dead slice) must reproduce the
        // per-slice kernel's defined semantics with no NaN anywhere.
        let mut rng = SplitMix64::new(39);
        let (b, h, n, d) = (1usize, 2usize, 8usize, 4usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = Tensor::zeros(&[b, h, 0, d]);
        let v = Tensor::zeros(&[b, h, 0, d]);
        let blocks = Blocks::explicit(4, 4);
        let exec = Exec::new(2);
        let cfg = AttnConfig::default();
        let (fwd, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut Hbm::new()).unwrap();
        assert!(fwd.o.data.iter().all(|&x| x == 0.0));
        assert!(fwd.stats.lse.iter().all(|&x| x == f32::NEG_INFINITY));
        let dout = Tensor::full(&[b, h, n, d], 1.0);
        let (g, _) = flash2_backward_batched(
            &q,
            &k,
            &v,
            &fwd.o,
            &dout,
            &fwd.stats,
            &cfg,
            blocks,
            &exec,
            &mut Hbm::new(),
        )
        .unwrap();
        assert!(g.dq.data.iter().all(|&x| x == 0.0));
        assert_eq!(g.dk.numel(), 0);
        assert_eq!(g.dv.numel(), 0);
    }

    #[test]
    fn batched_hbm_equals_sum_of_per_slice_counts() {
        // The tentpole IO invariant: batching must not change per-slice
        // traffic, so totals are exactly slices × the per-slice count.
        let mut rng = SplitMix64::new(41);
        let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let exec = Exec::new(3);
        let mut h_batched = Hbm::new();
        let (fwd, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &exec, &mut h_batched).unwrap();
        let mut h_slice = Hbm::new();
        let qs = bh_slice(&q, 0);
        let ks = bh_slice(&k, 0);
        let vs = bh_slice(&v, 0);
        flash2_forward(&qs, &ks, &vs, &cfg, blocks, &Exec::scoped(1), &mut h_slice);
        assert_eq!(h_batched.loads, 4 * h_slice.loads);
        assert_eq!(h_batched.stores, 4 * h_slice.stores);
        // Backward too.
        let dout = rand4(&[b, h, n, d], &mut rng);
        let mut hb_batched = Hbm::new();
        flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &exec, &mut hb_batched,
        )
        .unwrap();
        let f = flash2_forward(&qs, &ks, &vs, &cfg, blocks, &Exec::scoped(1), &mut Hbm::new());
        let mut hb_slice = Hbm::new();
        let dos = bh_slice(&dout, 0);
        flash2_backward(
            &qs,
            &ks,
            &vs,
            &f.o,
            &dos,
            f.stats(),
            &cfg,
            blocks,
            &Exec::scoped(1),
            &mut hb_slice,
        );
        assert_eq!(hb_batched.loads, 4 * hb_slice.loads);
        assert_eq!(hb_batched.stores, 4 * hb_slice.stores);
    }

    #[test]
    fn sparse_batched_bitwise_matches_per_slice_loop() {
        // The sparse scheduler contract, per-head masks included: a
        // [b, h, n, d] workload through block_sparse2_forward_batched /
        // _backward_batched must be BITWISE equal to the per-slice
        // block_sparse2 loop, for any worker count and pool mode.
        use crate::attn::block_sparse::{block_sparse2_backward, block_sparse2_forward};
        for_each_case("sparse_batched_parity", 12, |rng| {
            let b = usize_in(rng, 1, 2);
            let h = usize_in(rng, 1, 3);
            let n = 8 * usize_in(rng, 1, 4);
            let n_k = 8 * usize_in(rng, 1, 5);
            let d = *choose(rng, &[2usize, 4, 8]);
            let blocks = Blocks::explicit(8, 8);
            let (t_r, t_c) = (n / 8, n_k / 8);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let exec =
                if rng.next_f32() < 0.5 { Exec::new(workers) } else { Exec::scoped(workers) };
            // Per-head masks (shared across the batch): butterfly for
            // even heads, local_global for odd.
            let masks: Vec<BlockMask> = (0..h)
                .map(|hh| {
                    if hh % 2 == 0 {
                        BlockMask::butterfly(t_r, t_c)
                    } else {
                        BlockMask::local_global(t_r, t_c, 1, 1)
                    }
                })
                .collect();
            let q = rand4(&[b, h, n, d], rng);
            let k = rand4(&[b, h, n_k, d], rng);
            let v = rand4(&[b, h, n_k, d], rng);
            let dout = rand4(&[b, h, n, d], rng);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let ctx = format!(
                "b={b} h={h} n={n} n_k={n_k} d={d} causal={causal} kv_len={kv_len:?} \
                 p={dropout_p} w={workers} scoped={}",
                exec.is_scoped()
            );
            let (bfwd, _) = block_sparse2_forward_batched(
                &q, &k, &v, &masks, &cfg, blocks, &exec, &mut Hbm::new(),
            )
            .unwrap();
            let (bg, _) = block_sparse2_backward_batched(
                &q, &k, &v, &bfwd.o, &dout, &bfwd.stats, &masks, &cfg, blocks, &exec,
                &mut Hbm::new(),
            )
            .unwrap();
            for s in 0..b * h {
                let cfg_s = AttnConfig { bh_index: s as u32, ..cfg.clone() };
                let mask = &masks[s % h];
                let (qs, ks, vs) = (bh_slice(&q, s), bh_slice(&k, s), bh_slice(&v, s));
                let f = block_sparse2_forward(
                    &qs, &ks, &vs, mask, &cfg_s, blocks, &Exec::scoped(1), &mut Hbm::new(),
                );
                assert_eq!(
                    &bfwd.o.data[s * n * d..(s + 1) * n * d],
                    &f.o.data[..],
                    "O slice {s}: {ctx}"
                );
                assert_eq!(&bfwd.stats.lse[s * n..(s + 1) * n], &f.lse[..], "lse {s}: {ctx}");
                let g = block_sparse2_backward(
                    &qs,
                    &ks,
                    &vs,
                    &f.o,
                    &bh_slice(&dout, s),
                    f.stats(),
                    mask,
                    &cfg_s,
                    blocks,
                    &Exec::scoped(1),
                    &mut Hbm::new(),
                );
                assert_eq!(
                    &bg.dq.data[s * n * d..(s + 1) * n * d],
                    &g.dq.data[..],
                    "dQ slice {s}: {ctx}"
                );
                assert_eq!(
                    &bg.dk.data[s * n_k * d..(s + 1) * n_k * d],
                    &g.dk.data[..],
                    "dK slice {s}: {ctx}"
                );
                assert_eq!(
                    &bg.dv.data[s * n_k * d..(s + 1) * n_k * d],
                    &g.dv.data[..],
                    "dV slice {s}: {ctx}"
                );
            }
        });
    }

    #[test]
    fn sparse_batched_traffic_invariant_across_worker_counts() {
        // Scheduling must change neither numerics nor modeled traffic —
        // the sparse analogue of the dense invariance test above.
        let mut rng = SplitMix64::new(43);
        let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let masks = vec![BlockMask::butterfly(4, 4)];
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(8, 8);
        let mut h1 = Hbm::new();
        let (base, _) = block_sparse2_forward_batched(
            &q,
            &k,
            &v,
            &masks,
            &cfg,
            blocks,
            &Exec::scoped(1),
            &mut h1,
        )
        .unwrap();
        let mut hb1 = Hbm::new();
        let (gbase, _) = block_sparse2_backward_batched(
            &q,
            &k,
            &v,
            &base.o,
            &dout,
            &base.stats,
            &masks,
            &cfg,
            blocks,
            &Exec::scoped(1),
            &mut hb1,
        )
        .unwrap();
        for workers in [2usize, 5, 16] {
            for exec in [Exec::new(workers), Exec::scoped(workers)] {
                let mode = if exec.is_scoped() { "scoped" } else { "persistent" };
                let mut hw = Hbm::new();
                let (multi, _) = block_sparse2_forward_batched(
                    &q, &k, &v, &masks, &cfg, blocks, &exec, &mut hw,
                )
                .unwrap();
                assert_eq!(base.o.data, multi.o.data, "O at {mode} workers={workers}");
                assert_eq!(
                    (h1.loads, h1.stores),
                    (hw.loads, hw.stores),
                    "fwd hbm at {mode} w={workers}"
                );
                let mut hbw = Hbm::new();
                let (g, _) = block_sparse2_backward_batched(
                    &q, &k, &v, &base.o, &dout, &base.stats, &masks, &cfg, blocks, &exec,
                    &mut hbw,
                )
                .unwrap();
                assert_eq!(gbase.dq.data, g.dq.data, "dQ at {mode} workers={workers}");
                assert_eq!(gbase.dk.data, g.dk.data, "dK at {mode} workers={workers}");
                assert_eq!(gbase.dv.data, g.dv.data, "dV at {mode} workers={workers}");
                assert_eq!(
                    (hb1.loads, hb1.stores),
                    (hbw.loads, hbw.stores),
                    "bwd hbm at {mode} w={workers}"
                );
            }
        }
    }

    #[test]
    fn scoped_guarded_entries_match_persistent_pool() {
        // Migration contract for the removed pre-Exec `_checked` shims:
        // the canonical entries under a per-call scoped, guarded handle
        // (`Exec::scoped(w).with_plan(plan).validated()`) are bitwise
        // identical to the persistent-pool handle on the same inputs.
        let mut rng = SplitMix64::new(47);
        let (b, h, n, d) = (1usize, 2usize, 16usize, 4usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig::new().causal();
        let blocks = Blocks::explicit(4, 4);
        let plan = FaultPlan::none();
        let guarded = Exec::scoped(2).with_plan(&plan).validated();
        let pool = Exec::new(2);
        let (fwd, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &guarded, &mut Hbm::new()).unwrap();
        let (canon, _) =
            flash2_forward_batched(&q, &k, &v, &cfg, blocks, &pool, &mut Hbm::new()).unwrap();
        assert_eq!(fwd.o.data, canon.o.data);
        let (g, _) = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &guarded, &mut Hbm::new(),
        )
        .unwrap();
        let (gc, _) = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &pool, &mut Hbm::new(),
        )
        .unwrap();
        assert_eq!(g.dq.data, gc.dq.data);
        let masks = vec![BlockMask::butterfly(4, 4)];
        let (sf, _) = block_sparse2_forward_batched(
            &q, &k, &v, &masks, &cfg, blocks, &guarded, &mut Hbm::new(),
        )
        .unwrap();
        let (sg, _) = block_sparse2_backward_batched(
            &q, &k, &v, &sf.o, &dout, &sf.stats, &masks, &cfg, blocks, &guarded, &mut Hbm::new(),
        )
        .unwrap();
        assert_eq!(sf.o.shape, vec![b, h, n, d]);
        assert_eq!(sg.dq.shape, vec![b, h, n, d]);
    }
}
