//! Batched multi-head scheduler for the fast kernel pair — work
//! partitioning in the FlashAttention-2 (Dao, 2023) sense: most of the
//! practical speedup comes not from kernel math but from scheduling over
//! batch × heads × row blocks so every worker stays occupied even when a
//! single slice is small.
//!
//! Until this module, every hot path invoked `attn::flash2` once per
//! (batch, head) slice, paying a `std::thread::scope` pool spin-up per
//! slice and idling workers whenever one slice had fewer row blocks than
//! threads. The entry points here flatten **all** batch·head·row-block
//! (and, in the backward, batch·head·column-block) work items into a
//! single dynamically-drained pool:
//!
//! * [`flash2_forward_batched`] / [`flash2_backward_batched`] — the
//!   `[batch, heads, n, d]` entry points; the trainer preflight, the serve
//!   IO model, `attention_backward_batched` and the perf benches route
//!   through these.
//! * [`flash2_forward_many`] / [`flash2_backward_many`] — the
//!   shape-heterogeneous core (each slice carries its own q/k/v and
//!   [`AttnConfig`], including a per-shard `kv_offset`), which also
//!   schedules the sequence-parallel tree schedule's per-shard partials
//!   (`attn::distributed::shard_partials`).
//!
//! Two guarantees, both asserted by the tests below:
//!
//! * **Bitwise parity with the per-slice loop, for any worker count.** A
//!   work item is one (slice, row/column block) pair, dispatched through
//!   exactly the per-slice kernels' block sweeps
//!   (`flash2::row_block_sweep` and friends), and block arithmetic is
//!   self-contained — so output is bitwise identical to calling the
//!   per-slice kernel slice by slice, regardless of worker count or the
//!   dynamic claim order.
//! * **Unchanged per-slice HBM traffic.** Batching reorganises *when*
//!   work runs, never what moves: per the paper's per-slice IO analysis
//!   the instrumented counters must (and do) sum to exactly
//!   slice-count × the per-slice counts — the closed forms
//!   `sim::cost::flash2_fwd_batched` / `flash2_bwd_batched` are asserted
//!   access-for-access in `rust/tests/io_complexity.rs`.
//!
//! Dropout streams stay per-slice: slice `s` runs with
//! `bh_index = cfg.bh_index + s`, exactly what the per-slice loop did.

use std::sync::{Condvar, Mutex, PoisonError};

use super::block_sparse::{
    check_mask_geometry, mask_tile_base, sparse_dq_row_sweep, sparse_row_block_sweep,
};
use super::faults::{
    panic_message, AttnError, FaultKind, FaultPlan, FaultReport, FaultSite, InjectedPanic,
    PoolItem, MAX_ATTEMPTS,
};
use super::flash::Blocks;
use super::flash2::{
    dkv_col_sweep, dkv_col_sweep_filtered, dq_row_sweep, row_block_sweep, Flash2Output,
};
use super::masks::BlockMask;
use super::{AttnConfig, AttnGrads, AttnStats};
use crate::sim::hbm::Hbm;
use crate::tensor::{dot4, Tensor};

/// One independent forward slice for the many-slice scheduler: flat
/// row-major q: [n, d] and k, v: [n_k, d], plus the slice's own config
/// (the sharded driver sets `kv_offset` per shard so every decision is
/// made in global key coordinates; the batched entry points advance
/// `bh_index` per slice).
pub struct AttnSlice<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub n: usize,
    pub n_k: usize,
    pub d: usize,
    pub cfg: AttnConfig,
}

/// One independent backward slice: the forward's inputs and outputs plus
/// dO and the forward's logsumexp row.
pub struct AttnGradSlice<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub o: &'a [f32],
    pub dout: &'a [f32],
    pub lse: &'a [f32],
    pub n: usize,
    pub n_k: usize,
    pub d: usize,
    pub cfg: AttnConfig,
}

/// Softmax statistics for a batched workload: one logsumexp row per
/// (batch, head) slice, stored flat as [slices · n].
#[derive(Clone, Debug)]
pub struct BatchedAttnStats {
    /// Query rows per slice.
    pub n: usize,
    pub lse: Vec<f32>,
}

impl BatchedAttnStats {
    pub fn slices(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.lse.len() / self.n
        }
    }

    /// Borrow slice `s`'s statistics in the per-slice representation.
    pub fn slice(&self, s: usize) -> AttnStats<'_> {
        AttnStats::Lse(&self.lse[s * self.n..(s + 1) * self.n])
    }
}

/// Forward outputs of the batched fast kernel: O shaped
/// [batch, heads, n, d] plus one logsumexp row per slice.
#[derive(Clone, Debug)]
pub struct BatchedFlash2Output {
    pub o: Tensor,
    pub stats: BatchedAttnStats,
}

/// Drain `items` through one `std::thread::scope` pool of (at most)
/// `workers` threads, panicking (with the typed error's message) only
/// after a work item exhausts its retry budget. Items are claimed
/// dynamically — a worker that finishes a cheap item immediately pulls
/// the next, so small slices never strand threads — and each item's
/// arithmetic is self-contained, making the result independent of the
/// claim order and worker count. Per-item HBM counters merge
/// associatively into `hbm`, so traffic totals are partition-independent
/// too.
pub(crate) fn run_pool<T, F>(items: Vec<T>, workers: usize, hbm: &mut Hbm, site: FaultSite, work: F)
where
    T: PoolItem,
    F: Fn(&mut T) -> Hbm + Sync,
{
    if let Err(e) = run_pool_guarded(items, workers, hbm, site, &FaultPlan::none(), false, work) {
        panic!("{e}");
    }
}

/// An item in flight or queued: its original index and attempt counter.
struct Tracked<T> {
    idx: usize,
    attempt: u32,
    item: T,
}

/// Shared pool state behind one mutex: the (re)queue, the count of items
/// being worked on (a faulted one may return to the queue, so "queue
/// empty" alone does not mean "done"), the first fatal error, and the
/// fault bookkeeping.
struct PoolState<T> {
    queue: Vec<Tracked<T>>,
    in_flight: usize,
    error: Option<AttnError>,
    report: FaultReport,
    /// Audit check (c): per-item commit counts — every item must commit
    /// exactly once on a successful run (retries are not commits).
    #[cfg(feature = "audit")]
    commits: Vec<u32>,
}

/// How a finished attempt is disposed of (classified outside the lock —
/// the finiteness scan is O(window) and must not serialize workers).
enum Disposal {
    Commit { delayed: bool },
    Retry { kind: RetryKind, attempt_hbm: Option<Hbm>, message: String },
}

enum RetryKind {
    Panicked,
    Poisoned,
    Dropped,
    NonFinite,
}

/// The fault-tolerant work pool behind every batched and sharded
/// schedule. Semantics (see `attn::faults` and the module docs in
/// `attn::mod`):
///
/// * A worker panic is contained by `catch_unwind`; the item's windows
///   are zeroed and it is requeued, up to [`MAX_ATTEMPTS`] total
///   attempts. Workers race only for items, never output slots, so the
///   re-run performs identical arithmetic into a fresh window and the
///   recovered output is bitwise identical to the fault-free run.
/// * With `validate` on, every item's output windows are scanned for
///   non-finite values before commit; a trip requeues exactly like a
///   panic and, on budget exhaustion, surfaces as
///   [`AttnError::NonFinite`] with (slice, block) provenance.
/// * `plan` injects faults at publish time — after the item's work has
///   run — so every attempt performs and counts its full traffic. Each
///   faulted attempt that ran to completion adds its per-item HBM count
///   to `FaultReport::retry_hbm`; a genuine mid-item panic has
///   unknowable partial traffic and is excluded from all counters.
/// * Worker-local HBM counters merge into `hbm` at join even on error,
///   so counters always reflect work actually performed.
pub(crate) fn run_pool_guarded<T, F>(
    items: Vec<T>,
    workers: usize,
    hbm: &mut Hbm,
    site: FaultSite,
    plan: &FaultPlan,
    validate: bool,
    work: F,
) -> Result<FaultReport, AttnError>
where
    T: PoolItem,
    F: Fn(&mut T) -> Hbm + Sync,
{
    if items.is_empty() {
        return Ok(FaultReport::default());
    }
    // Audit check (a): every item's claimed output windows are disjoint,
    // verified (and optionally fingerprinted) before any worker spawns —
    // workers race for items, never for output slots.
    #[cfg(feature = "audit")]
    let n_items = items.len();
    #[cfg(feature = "audit")]
    {
        let manifest: Vec<super::audit::ItemClaims> = items
            .iter()
            .enumerate()
            .map(|(idx, it)| super::audit::ItemClaims { idx, id: it.id(), claims: it.claims() })
            .collect();
        super::audit::check_and_record(site, &manifest);
    }
    let w = workers.max(1).min(items.len());
    let state = Mutex::new(PoolState {
        queue: items
            .into_iter()
            .enumerate()
            .map(|(idx, item)| Tracked { idx, attempt: 0, item })
            .collect(),
        in_flight: 0,
        error: None,
        report: FaultReport::default(),
        #[cfg(feature = "audit")]
        commits: vec![0; n_items],
    });
    let ready = Condvar::new();
    // A contained panic can poison the mutex between lock() and the
    // guard drop; the inner state is still consistent (the lock is held
    // only for queue bookkeeping, never across item execution), so
    // recover it instead of cascading.
    let lock = || state.lock().unwrap_or_else(PoisonError::into_inner);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..w {
            handles.push(scope.spawn(|| {
                let mut local = Hbm::new();
                loop {
                    let mut st = lock();
                    let claimed = loop {
                        if st.error.is_some() {
                            break None;
                        }
                        if let Some(t) = st.queue.pop() {
                            break Some(t);
                        }
                        if st.in_flight == 0 {
                            break None;
                        }
                        // Queue empty but items in flight: one may yet
                        // fail and requeue, so wait instead of exiting.
                        st = ready.wait(st).unwrap_or_else(PoisonError::into_inner);
                    };
                    let Some(mut t) = claimed else {
                        break;
                    };
                    st.in_flight += 1;
                    drop(st);

                    let fault = plan.fault_for(site, t.idx, t.attempt);
                    if fault == Some(FaultKind::DelayedShard) {
                        // A straggler, not a failure: complete late,
                        // commit normally, add no traffic.
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        let h = work(&mut t.item);
                        if fault == Some(FaultKind::WorkerPanic) {
                            // resume_unwind skips the panic hook (no
                            // stderr spam for planned chaos); the payload
                            // carries the attempt's exact traffic so the
                            // retry accounting stays access-for-access.
                            std::panic::resume_unwind(Box::new(InjectedPanic(h)));
                        }
                        h
                    }));
                    let disposal = match outcome {
                        Ok(h) => {
                            local.merge(&h);
                            if fault == Some(FaultKind::PoisonedPartial) {
                                t.item.poison();
                            }
                            if fault == Some(FaultKind::DroppedMerge) {
                                Disposal::Retry {
                                    kind: RetryKind::Dropped,
                                    attempt_hbm: Some(h),
                                    message: "completion record dropped".into(),
                                }
                            } else if (validate || fault == Some(FaultKind::PoisonedPartial))
                                && !t.item.check_finite()
                            {
                                let kind = if fault == Some(FaultKind::PoisonedPartial) {
                                    RetryKind::Poisoned
                                } else {
                                    RetryKind::NonFinite
                                };
                                Disposal::Retry {
                                    kind,
                                    attempt_hbm: Some(h),
                                    message: "non-finite output".into(),
                                }
                            } else {
                                Disposal::Commit { delayed: fault == Some(FaultKind::DelayedShard) }
                            }
                        }
                        Err(payload) => {
                            let attempt_hbm =
                                payload.downcast_ref::<InjectedPanic>().map(|inj| {
                                    // Injected at publish time: the work
                                    // ran to completion, its traffic is
                                    // real and gets re-done by the retry.
                                    local.merge(&inj.0);
                                    inj.0.clone()
                                });
                            Disposal::Retry {
                                kind: RetryKind::Panicked,
                                attempt_hbm,
                                message: panic_message(&*payload),
                            }
                        }
                    };

                    let mut st = lock();
                    st.in_flight -= 1;
                    match disposal {
                        Disposal::Commit { delayed } => {
                            #[cfg(feature = "audit")]
                            {
                                st.commits[t.idx] += 1;
                            }
                            if delayed {
                                st.report.delayed += 1;
                            }
                        }
                        Disposal::Retry { kind, attempt_hbm, message } => {
                            match kind {
                                RetryKind::Panicked => st.report.panics += 1,
                                RetryKind::Poisoned => st.report.poisoned += 1,
                                RetryKind::Dropped => st.report.dropped += 1,
                                RetryKind::NonFinite => st.report.guardrail += 1,
                            }
                            if let Some(h) = &attempt_hbm {
                                st.report.retry_hbm.merge(h);
                            }
                            if t.attempt + 1 < MAX_ATTEMPTS {
                                st.report.retries += 1;
                                // The backward sweeps accumulate into
                                // their windows (and a poisoned forward
                                // scribbled NaN over them): zero back to
                                // the pre-run state so the re-run
                                // reproduces a fresh run bit for bit.
                                t.item.reset();
                                st.queue.push(Tracked {
                                    idx: t.idx,
                                    attempt: t.attempt + 1,
                                    item: t.item,
                                });
                            } else if st.error.is_none() {
                                let (slice, block) = t.item.id();
                                let attempts = t.attempt + 1;
                                st.error = Some(match kind {
                                    RetryKind::Poisoned | RetryKind::NonFinite => {
                                        AttnError::NonFinite {
                                            site,
                                            slice,
                                            batch: 0,
                                            head: 0,
                                            block,
                                            attempts,
                                        }
                                    }
                                    _ => AttnError::ItemFailed {
                                        site,
                                        slice,
                                        block,
                                        attempts,
                                        message,
                                    },
                                });
                            }
                        }
                    }
                    drop(st);
                    ready.notify_all();
                }
                local
            }));
        }
        for h in handles {
            if let Ok(local) = h.join() {
                hbm.merge(&local);
            }
        }
    });
    let mut st = lock();
    match st.error.take() {
        Some(e) => Err(e),
        None => {
            // Audit check (c): success means every output window was
            // committed by exactly one attempt.
            #[cfg(feature = "audit")]
            super::audit::check_commits(site, &st.commits);
            Ok(std::mem::take(&mut st.report))
        }
    }
}

/// Split `data` into disjoint mutable windows of the given `sizes`
/// (consumed front to back; any tail past the last size is dropped).
pub(crate) fn split_windows<'a>(
    mut data: &'a mut [f32],
    sizes: impl Iterator<Item = usize>,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::new();
    for sz in sizes {
        let (head, tail) = data.split_at_mut(sz);
        out.push(head);
        data = tail;
    }
    out
}

/// Rows covered by row/column block `b` of size `bsz` over `total` rows.
pub(crate) fn block_rows(b: usize, bsz: usize, total: usize) -> usize {
    ((b + 1) * bsz).min(total) - b * bsz
}

/// A strictly-finite window scan (gradient and O windows).
fn all_finite(xs: &[f32]) -> bool {
    xs.iter().all(|x| x.is_finite())
}

/// A logsumexp window scan: finite or *exactly* −∞ (the defined
/// all-masked value) — anything else (NaN, +∞) trips the guardrail.
fn lse_defined(xs: &[f32]) -> bool {
    xs.iter().all(|&x| x.is_finite() || x == f32::NEG_INFINITY)
}

/// One (slice, row block) forward work item: disjoint O and logsumexp
/// windows. Shared by the dense/sparse batched schedulers and the ring
/// schedule (which has a single logical slice, `s = 0`).
pub(crate) struct FwdItem<'a> {
    pub s: usize,
    pub rb: usize,
    pub o_win: &'a mut [f32],
    pub lse_win: &'a mut [f32],
}

impl PoolItem for FwdItem<'_> {
    fn id(&self) -> (usize, usize) {
        (self.s, self.rb)
    }
    fn reset(&mut self) {
        self.o_win.fill(0.0);
        self.lse_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        all_finite(self.o_win) && lse_defined(self.lse_win)
    }
    fn poison(&mut self) {
        self.o_win.fill(f32::NAN);
        self.lse_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        use crate::attn::audit::SlotClaim;
        vec![SlotClaim::of("o", self.o_win), SlotClaim::of("lse", self.lse_win)]
    }
}

/// One (slice, row block) dQ work item.
pub(crate) struct DqItem<'a> {
    pub s: usize,
    pub rb: usize,
    pub dq_win: &'a mut [f32],
}

impl PoolItem for DqItem<'_> {
    fn id(&self) -> (usize, usize) {
        (self.s, self.rb)
    }
    fn reset(&mut self) {
        self.dq_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        all_finite(self.dq_win)
    }
    fn poison(&mut self) {
        self.dq_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        vec![crate::attn::audit::SlotClaim::of("dq", self.dq_win)]
    }
}

/// One (slice, column block) dK/dV work item.
pub(crate) struct DkvItem<'a> {
    pub s: usize,
    pub cb: usize,
    pub dk_win: &'a mut [f32],
    pub dv_win: &'a mut [f32],
}

impl PoolItem for DkvItem<'_> {
    fn id(&self) -> (usize, usize) {
        (self.s, self.cb)
    }
    fn reset(&mut self) {
        self.dk_win.fill(0.0);
        self.dv_win.fill(0.0);
    }
    fn check_finite(&self) -> bool {
        all_finite(self.dk_win) && all_finite(self.dv_win)
    }
    fn poison(&mut self) {
        self.dk_win.fill(f32::NAN);
        self.dv_win.fill(f32::NAN);
    }
    #[cfg(feature = "audit")]
    fn claims(&self) -> Vec<crate::attn::audit::SlotClaim> {
        use crate::attn::audit::SlotClaim;
        vec![SlotClaim::of("dk", self.dk_win), SlotClaim::of("dv", self.dv_win)]
    }
}

/// Fast exact forward over many independent slices through ONE worker
/// pool: every (slice, row block) pair becomes a work item. Outputs (and
/// HBM totals) are bitwise identical to running [`super::flash2::flash2_forward`]
/// per slice, for any `workers`.
pub fn flash2_forward_many(
    slices: &[AttnSlice<'_>],
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> Vec<Flash2Output> {
    let plan = FaultPlan::none();
    match forward_many_sited(slices, blocks, workers, hbm, &plan, false, FaultSite::BatchedFwd) {
        Ok((outs, _)) => outs,
        Err(e) => panic!("{e}"),
    }
}

/// [`flash2_forward_many`] with fault containment, retry, the finiteness
/// guardrail, and (optionally) fault injection: returns the outputs plus
/// a [`FaultReport`], or a typed [`AttnError`] with (slice, block)
/// provenance. Output after any recovered fault schedule is bitwise
/// identical to the fault-free run.
pub fn flash2_forward_many_checked(
    slices: &[AttnSlice<'_>],
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
) -> Result<(Vec<Flash2Output>, FaultReport), AttnError> {
    forward_many_sited(slices, blocks, workers, hbm, plan, true, FaultSite::BatchedFwd)
}

/// Site-parameterised core: the tree schedule routes its per-shard
/// partials through here under [`FaultSite::TreePartial`].
pub(crate) fn forward_many_sited(
    slices: &[AttnSlice<'_>],
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
    validate: bool,
    site: FaultSite,
) -> Result<(Vec<Flash2Output>, FaultReport), AttnError> {
    for (s, sl) in slices.iter().enumerate() {
        assert_eq!(sl.q.len(), sl.n * sl.d, "slice {s}: Q shape mismatch");
        assert_eq!(sl.k.len(), sl.n_k * sl.d, "slice {s}: K shape mismatch");
        assert_eq!(sl.v.len(), sl.n_k * sl.d, "slice {s}: V shape mismatch");
    }
    let mut outs: Vec<Flash2Output> = slices
        .iter()
        .map(|sl| {
            let mut lse = vec![0.0f32; sl.n];
            if sl.n_k == 0 {
                // No keys: same defined all-masked semantics as the
                // per-slice kernel's early return (zero rows, lse = -inf).
                lse.fill(f32::NEG_INFINITY);
            }
            Flash2Output { o: Tensor::zeros(&[sl.n, sl.d]), lse }
        })
        .collect();

    let mut items: Vec<FwdItem<'_>> = Vec::new();
    for (s, (sl, out)) in slices.iter().zip(outs.iter_mut()).enumerate() {
        if sl.n_k == 0 {
            continue;
        }
        let t_r = sl.n.div_ceil(blocks.b_r);
        let o_wins = split_windows(
            &mut out.o.data,
            (0..t_r).map(|rb| block_rows(rb, blocks.b_r, sl.n) * sl.d),
        );
        let lse_wins =
            split_windows(&mut out.lse, (0..t_r).map(|rb| block_rows(rb, blocks.b_r, sl.n)));
        for (rb, (o_win, lse_win)) in o_wins.into_iter().zip(lse_wins).enumerate() {
            items.push(FwdItem { s, rb, o_win, lse_win });
        }
    }

    let report = run_pool_guarded(items, workers, hbm, site, plan, validate, |it| {
        let sl = &slices[it.s];
        let tau = sl.cfg.tau_for(sl.d);
        let kv_limit = sl.cfg.kv_limit(sl.n_k);
        row_block_sweep(
            sl.q, sl.k, sl.v, sl.n, sl.n_k, sl.d, &sl.cfg, blocks, tau, kv_limit, it.rb,
            it.rb + 1, it.o_win, it.lse_win,
        )
    })?;

    Ok((outs, report))
}

/// Fast exact backward over many independent slices through one worker
/// pool per phase: the per-slice D epilogue runs inline, then every
/// (slice, row block) dQ item and every (slice, column block) dK/dV item
/// is scheduled dynamically. Bitwise identical to running
/// [`super::flash2::flash2_backward`] per slice, for any `workers`.
pub fn flash2_backward_many(
    slices: &[AttnGradSlice<'_>],
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> Vec<AttnGrads> {
    match backward_many_core(slices, blocks, workers, hbm, &FaultPlan::none(), false) {
        Ok((grads, _)) => grads,
        Err(e) => panic!("{e}"),
    }
}

/// [`flash2_backward_many`] with fault containment, retry, the finiteness
/// guardrail, and (optionally) fault injection — the gradient counterpart
/// of [`flash2_forward_many_checked`].
pub fn flash2_backward_many_checked(
    slices: &[AttnGradSlice<'_>],
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
) -> Result<(Vec<AttnGrads>, FaultReport), AttnError> {
    backward_many_core(slices, blocks, workers, hbm, plan, true)
}

fn backward_many_core(
    slices: &[AttnGradSlice<'_>],
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
    validate: bool,
) -> Result<(Vec<AttnGrads>, FaultReport), AttnError> {
    for (s, sl) in slices.iter().enumerate() {
        assert_eq!(sl.q.len(), sl.n * sl.d, "slice {s}: Q shape mismatch");
        assert_eq!(sl.k.len(), sl.n_k * sl.d, "slice {s}: K shape mismatch");
        assert_eq!(sl.v.len(), sl.n_k * sl.d, "slice {s}: V shape mismatch");
        assert_eq!(sl.o.len(), sl.n * sl.d, "slice {s}: O shape mismatch");
        assert_eq!(sl.dout.len(), sl.n * sl.d, "slice {s}: dO shape mismatch");
        assert_eq!(sl.lse.len(), sl.n, "slice {s}: stats length mismatch");
    }
    let mut grads: Vec<AttnGrads> = slices
        .iter()
        .map(|sl| AttnGrads {
            dq: Tensor::zeros(&[sl.n, sl.d]),
            dk: Tensor::zeros(&[sl.n_k, sl.d]),
            dv: Tensor::zeros(&[sl.n_k, sl.d]),
        })
        .collect();

    // Phase 0, per slice: D_i = rowsum(dO ∘ O) in one epilogue pass each —
    // the same accounting as the per-slice kernel (dO/O loaded once, D
    // stored once). O(slices·n·d) work, so it stays on this thread; slices
    // with no rows or no keys are skipped exactly like the per-slice
    // kernel's early return (no traffic, zero gradients).
    let d_vecs: Vec<Vec<f32>> = slices
        .iter()
        .map(|sl| {
            if sl.n == 0 || sl.n_k == 0 {
                return Vec::new();
            }
            hbm.load(2 * sl.n * sl.d);
            let dv: Vec<f32> = (0..sl.n)
                .map(|r| dot4(&sl.dout[r * sl.d..(r + 1) * sl.d], &sl.o[r * sl.d..(r + 1) * sl.d]))
                .collect();
            hbm.store(sl.n);
            dv
        })
        .collect();

    let mut dq_items: Vec<DqItem<'_>> = Vec::new();
    let mut dkv_items: Vec<DkvItem<'_>> = Vec::new();
    for (s, (sl, g)) in slices.iter().zip(grads.iter_mut()).enumerate() {
        if sl.n == 0 || sl.n_k == 0 {
            continue;
        }
        let t_r = sl.n.div_ceil(blocks.b_r);
        let t_c = sl.n_k.div_ceil(blocks.b_c);
        let dq_wins = split_windows(
            &mut g.dq.data,
            (0..t_r).map(|rb| block_rows(rb, blocks.b_r, sl.n) * sl.d),
        );
        for (rb, dq_win) in dq_wins.into_iter().enumerate() {
            dq_items.push(DqItem { s, rb, dq_win });
        }
        let dk_wins = split_windows(
            &mut g.dk.data,
            (0..t_c).map(|cb| block_rows(cb, blocks.b_c, sl.n_k) * sl.d),
        );
        let dv_wins = split_windows(
            &mut g.dv.data,
            (0..t_c).map(|cb| block_rows(cb, blocks.b_c, sl.n_k) * sl.d),
        );
        for (cb, (dk_win, dv_win)) in dk_wins.into_iter().zip(dv_wins).enumerate() {
            dkv_items.push(DkvItem { s, cb, dk_win, dv_win });
        }
    }

    // Phase 1: all slices' dQ row blocks through one pool.
    let mut report =
        run_pool_guarded(dq_items, workers, hbm, FaultSite::BatchedDq, plan, validate, |it| {
            let sl = &slices[it.s];
            let tau = sl.cfg.tau_for(sl.d);
            let kv_limit = sl.cfg.kv_limit(sl.n_k);
            dq_row_sweep(
                sl.q, sl.k, sl.v, sl.dout, sl.lse, &d_vecs[it.s], sl.n, sl.n_k, sl.d, &sl.cfg,
                blocks, tau, kv_limit, it.rb, it.rb + 1, it.dq_win,
            )
        })?;

    // Phase 2: all slices' dK/dV column blocks through one pool.
    let dkv_report =
        run_pool_guarded(dkv_items, workers, hbm, FaultSite::BatchedDkv, plan, validate, |it| {
            let sl = &slices[it.s];
            let tau = sl.cfg.tau_for(sl.d);
            let kv_limit = sl.cfg.kv_limit(sl.n_k);
            dkv_col_sweep(
                sl.q, sl.k, sl.v, sl.dout, sl.lse, &d_vecs[it.s], sl.n, sl.n_k, sl.d, &sl.cfg,
                blocks, tau, kv_limit, it.cb, it.cb + 1, it.dk_win, it.dv_win,
            )
        })?;
    report.merge(&dkv_report);

    Ok((grads, report))
}

/// Check and decompose a [batch, heads, rows, d] tensor.
fn dims4(t: &Tensor, what: &str) -> (usize, usize, usize, usize) {
    assert_eq!(t.rank(), 4, "{what} must be [batch, heads, rows, d]");
    (t.shape[0], t.shape[1], t.shape[2], t.shape[3])
}

/// Copy (batch·head) slice `s` of a rank-4 tensor out as an [n, d] matrix
/// (tests and the reference kernels' per-slice fallback paths).
pub fn bh_slice(t: &Tensor, s: usize) -> Tensor {
    let (_, _, n, d) = dims4(t, "bh_slice input");
    Tensor::from_vec(&[n, d], t.data[s * n * d..(s + 1) * n * d].to_vec())
}

/// Batched multi-head fast forward. q: [batch, heads, n, d];
/// k, v: [batch, heads, n_k, d] (rectangular K/V serves cross-attention
/// and sharded layouts). All batch·head·row-block work items run in one
/// `std::thread::scope` pool; the result is bitwise independent of
/// `workers` and bitwise identical to the per-slice loop it replaces.
/// Slice `s` runs with `bh_index = cfg.bh_index + s`, so dropout streams
/// match the per-slice convention.
pub fn flash2_forward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> BatchedFlash2Output {
    match forward_batched_core(q, k, v, cfg, blocks, workers, hbm, &FaultPlan::none(), false) {
        Ok((out, _)) => out,
        Err(e) => panic!("{e}"),
    }
}

/// [`flash2_forward_batched`] with fault containment, retry, the
/// finiteness guardrail and (optionally) fault injection: returns the
/// output plus a [`FaultReport`], or a typed [`AttnError`] whose
/// provenance names the (batch, head) slice and q row block.
#[allow(clippy::too_many_arguments)]
pub fn flash2_forward_batched_checked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
) -> Result<(BatchedFlash2Output, FaultReport), AttnError> {
    forward_batched_core(q, k, v, cfg, blocks, workers, hbm, plan, true)
}

#[allow(clippy::too_many_arguments)]
fn forward_batched_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
    validate: bool,
) -> Result<(BatchedFlash2Output, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "flash2_forward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "flash2_forward_batched K");
    assert_eq!((bk, hk, dk), (b, h, d), "flash2_forward_batched: K batch/heads/feature mismatch");
    assert_eq!(v.shape, k.shape, "flash2_forward_batched: V shape mismatch");
    let slices: Vec<AttnSlice<'_>> = (0..b * h)
        .map(|s| AttnSlice {
            q: &q.data[s * n * d..(s + 1) * n * d],
            k: &k.data[s * n_k * d..(s + 1) * n_k * d],
            v: &v.data[s * n_k * d..(s + 1) * n_k * d],
            n,
            n_k,
            d,
            cfg: AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() },
        })
        .collect();
    let (outs, report) =
        forward_many_sited(&slices, blocks, workers, hbm, plan, validate, FaultSite::BatchedFwd)
            .map_err(|e| e.located(h))?;
    let mut o = Tensor::zeros(&[b, h, n, d]);
    let mut lse = Vec::with_capacity(b * h * n);
    for (s, out) in outs.into_iter().enumerate() {
        o.data[s * n * d..(s + 1) * n * d].copy_from_slice(&out.o.data);
        lse.extend_from_slice(&out.lse);
    }
    Ok((BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } }, report))
}

/// Batched multi-head fast backward: the gradient counterpart of
/// [`flash2_forward_batched`], with every batch·head·block work item of
/// each phase in one pool. `stats` holds one logsumexp row per slice
/// (the batched forward's output). Returns [batch, heads, …, d] gradients;
/// bitwise identical to the per-slice loop for any `workers`.
pub fn flash2_backward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> AttnGrads {
    let plan = FaultPlan::none();
    match backward_batched_core(q, k, v, o, dout, stats, cfg, blocks, workers, hbm, &plan, false) {
        Ok((grads, _)) => grads,
        Err(e) => panic!("{e}"),
    }
}

/// [`flash2_backward_batched`] with fault containment, retry, the
/// finiteness guardrail and (optionally) fault injection — provenance
/// names the (batch, head) slice and the row (dQ) or column (dK/dV)
/// block.
#[allow(clippy::too_many_arguments)]
pub fn flash2_backward_batched_checked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    backward_batched_core(q, k, v, o, dout, stats, cfg, blocks, workers, hbm, plan, true)
}

#[allow(clippy::too_many_arguments)]
fn backward_batched_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
    validate: bool,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "flash2_backward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "flash2_backward_batched K");
    assert_eq!((bk, hk, dk), (b, h, d), "flash2_backward_batched: K batch/heads/feature mismatch");
    assert_eq!(v.shape, k.shape, "flash2_backward_batched: V shape mismatch");
    assert_eq!(o.shape, q.shape, "flash2_backward_batched: O shape mismatch");
    assert_eq!(dout.shape, q.shape, "flash2_backward_batched: dO shape mismatch");
    assert_eq!(stats.n, n, "flash2_backward_batched: stats row-count mismatch");
    assert_eq!(stats.lse.len(), b * h * n, "flash2_backward_batched: stats slice-count mismatch");
    let slices: Vec<AttnGradSlice<'_>> = (0..b * h)
        .map(|s| AttnGradSlice {
            q: &q.data[s * n * d..(s + 1) * n * d],
            k: &k.data[s * n_k * d..(s + 1) * n_k * d],
            v: &v.data[s * n_k * d..(s + 1) * n_k * d],
            o: &o.data[s * n * d..(s + 1) * n * d],
            dout: &dout.data[s * n * d..(s + 1) * n * d],
            lse: &stats.lse[s * n..(s + 1) * n],
            n,
            n_k,
            d,
            cfg: AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() },
        })
        .collect();
    let (per_slice, report) = backward_many_core(&slices, blocks, workers, hbm, plan, validate)
        .map_err(|e| e.located(h))?;
    let mut dq4 = Tensor::zeros(&[b, h, n, d]);
    let mut dk4 = Tensor::zeros(&[b, h, n_k, d]);
    let mut dv4 = Tensor::zeros(&[b, h, n_k, d]);
    for (s, g) in per_slice.into_iter().enumerate() {
        dq4.data[s * n * d..(s + 1) * n * d].copy_from_slice(&g.dq.data);
        dk4.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dk.data);
        dv4.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dv.data);
    }
    Ok((AttnGrads { dq: dq4, dk: dk4, dv: dv4 }, report))
}

/// Resolve the mask for slice `s` of a [batch, heads, …] workload.
/// Masks may be shared (one mask), per-head (`heads` masks, shared
/// across the batch — the common multi-head-sparsity layout), or fully
/// per-slice (`batch · heads` masks).
fn mask_for<'m>(masks: &'m [BlockMask], heads: usize, slices: usize, s: usize) -> &'m BlockMask {
    match masks.len() {
        1 => &masks[0],
        l if l == heads => &masks[s % heads],
        l if l == slices => &masks[s],
        l => panic!(
            "block_sparse2 batched: {l} masks for {slices} slices ({heads} heads); \
             pass 1, heads, or batch*heads masks"
        ),
    }
}

/// Batched multi-head fast **block-sparse** forward: the sparse
/// counterpart of [`flash2_forward_batched`]. q: [batch, heads, n, d];
/// k, v: [batch, heads, n_k, d]. Every batch·head·row-block work item
/// runs through one dynamically-drained pool, dispatching the identical
/// per-block sparse sweep (`attn::block_sparse::sparse_row_block_sweep`),
/// so output is bitwise identical to the per-slice loop for any
/// `workers`. Per-head masks are allowed (see [`mask_for`]); slice `s`
/// runs with `bh_index = cfg.bh_index + s`.
pub fn block_sparse2_forward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> BatchedFlash2Output {
    let plan = FaultPlan::none();
    match sparse_forward_batched_core(q, k, v, masks, cfg, blocks, workers, hbm, &plan, false) {
        Ok((out, _)) => out,
        Err(e) => panic!("{e}"),
    }
}

/// [`block_sparse2_forward_batched`] with fault containment, retry, the
/// finiteness guardrail and (optionally) fault injection.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse2_forward_batched_checked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
) -> Result<(BatchedFlash2Output, FaultReport), AttnError> {
    sparse_forward_batched_core(q, k, v, masks, cfg, blocks, workers, hbm, plan, true)
}

#[allow(clippy::too_many_arguments)]
fn sparse_forward_batched_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
    validate: bool,
) -> Result<(BatchedFlash2Output, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "block_sparse2_forward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "block_sparse2_forward_batched K");
    assert_eq!(
        (bk, hk, dk),
        (b, h, d),
        "block_sparse2_forward_batched: K batch/heads/feature mismatch"
    );
    assert_eq!(v.shape, k.shape, "block_sparse2_forward_batched: V shape mismatch");
    let slices = b * h;
    let mut o = Tensor::zeros(&[b, h, n, d]);
    let mut lse = vec![0.0f32; slices * n];
    if n == 0 || n_k == 0 {
        // No keys: the per-slice kernel's defined all-masked semantics.
        lse.fill(f32::NEG_INFINITY);
        let out = BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } };
        return Ok((out, FaultReport::default()));
    }
    let tile_base = mask_tile_base(cfg.kv_offset, blocks.b_c);
    let t_r = n.div_ceil(blocks.b_r);
    let t_c = n_k.div_ceil(blocks.b_c);
    for s in 0..slices {
        check_mask_geometry(mask_for(masks, h, slices, s), t_r, tile_base, t_c);
    }
    let per_cfg: Vec<AttnConfig> = (0..slices)
        .map(|s| AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() })
        .collect();

    let o_wins = split_windows(
        &mut o.data,
        (0..slices).flat_map(|_| (0..t_r).map(|rb| block_rows(rb, blocks.b_r, n) * d)),
    );
    let lse_wins = split_windows(
        &mut lse,
        (0..slices).flat_map(|_| (0..t_r).map(|rb| block_rows(rb, blocks.b_r, n))),
    );
    let items: Vec<FwdItem<'_>> = o_wins
        .into_iter()
        .zip(lse_wins)
        .enumerate()
        .map(|(idx, (o_win, lse_win))| {
            FwdItem { s: idx / t_r, rb: idx % t_r, o_win, lse_win }
        })
        .collect();

    let report =
        run_pool_guarded(items, workers, hbm, FaultSite::SparseFwd, plan, validate, |it| {
            let cfg_s = &per_cfg[it.s];
            let mask = mask_for(masks, h, slices, it.s);
            sparse_row_block_sweep(
                &q.data[it.s * n * d..(it.s + 1) * n * d],
                &k.data[it.s * n_k * d..(it.s + 1) * n_k * d],
                &v.data[it.s * n_k * d..(it.s + 1) * n_k * d],
                n,
                n_k,
                d,
                mask,
                tile_base,
                cfg_s,
                blocks,
                cfg_s.tau_for(d),
                cfg_s.kv_limit(n_k),
                it.rb,
                it.rb + 1,
                it.o_win,
                it.lse_win,
            )
        })
        .map_err(|e| e.located(h))?;

    Ok((BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } }, report))
}

/// Batched multi-head fast block-sparse backward: the sparse
/// counterpart of [`flash2_backward_batched`] — per-slice D epilogues,
/// then every batch·head·row-block dQ item and batch·head·column-block
/// dK/dV item through one pool per phase, each skipping its mask's zero
/// blocks. Bitwise identical to the per-slice
/// `attn::block_sparse::block_sparse2_backward` loop for any `workers`.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse2_backward_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
) -> AttnGrads {
    let plan = FaultPlan::none();
    match sparse_backward_batched_core(
        q, k, v, o, dout, stats, masks, cfg, blocks, workers, hbm, &plan, false,
    ) {
        Ok((grads, _)) => grads,
        Err(e) => panic!("{e}"),
    }
}

/// [`block_sparse2_backward_batched`] with fault containment, retry, the
/// finiteness guardrail and (optionally) fault injection.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse2_backward_batched_checked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    sparse_backward_batched_core(
        q, k, v, o, dout, stats, masks, cfg, blocks, workers, hbm, plan, true,
    )
}

#[allow(clippy::too_many_arguments)]
fn sparse_backward_batched_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    o: &Tensor,
    dout: &Tensor,
    stats: &BatchedAttnStats,
    masks: &[BlockMask],
    cfg: &AttnConfig,
    blocks: Blocks,
    workers: usize,
    hbm: &mut Hbm,
    plan: &FaultPlan,
    validate: bool,
) -> Result<(AttnGrads, FaultReport), AttnError> {
    let (b, h, n, d) = dims4(q, "block_sparse2_backward_batched Q");
    let (bk, hk, n_k, dk) = dims4(k, "block_sparse2_backward_batched K");
    assert_eq!(
        (bk, hk, dk),
        (b, h, d),
        "block_sparse2_backward_batched: K batch/heads/feature mismatch"
    );
    assert_eq!(v.shape, k.shape, "block_sparse2_backward_batched: V shape mismatch");
    assert_eq!(o.shape, q.shape, "block_sparse2_backward_batched: O shape mismatch");
    assert_eq!(dout.shape, q.shape, "block_sparse2_backward_batched: dO shape mismatch");
    assert_eq!(stats.n, n, "block_sparse2_backward_batched: stats row-count mismatch");
    assert_eq!(
        stats.lse.len(),
        b * h * n,
        "block_sparse2_backward_batched: stats slice-count mismatch"
    );
    let slices = b * h;
    let mut dq4 = Tensor::zeros(&[b, h, n, d]);
    let mut dk4 = Tensor::zeros(&[b, h, n_k, d]);
    let mut dv4 = Tensor::zeros(&[b, h, n_k, d]);
    if n == 0 || n_k == 0 {
        return Ok((AttnGrads { dq: dq4, dk: dk4, dv: dv4 }, FaultReport::default()));
    }
    let tile_base = mask_tile_base(cfg.kv_offset, blocks.b_c);
    let t_r = n.div_ceil(blocks.b_r);
    let t_c = n_k.div_ceil(blocks.b_c);
    for s in 0..slices {
        check_mask_geometry(mask_for(masks, h, slices, s), t_r, tile_base, t_c);
    }
    let per_cfg: Vec<AttnConfig> = (0..slices)
        .map(|s| AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() })
        .collect();

    // Phase 0, per slice: D_i = rowsum(dO ∘ O), one epilogue pass each —
    // identical accounting to the per-slice kernel.
    let d_vecs: Vec<Vec<f32>> = (0..slices)
        .map(|s| {
            hbm.load(2 * n * d);
            let base = s * n * d;
            let dv: Vec<f32> = (0..n)
                .map(|r| {
                    dot4(
                        &dout.data[base + r * d..base + (r + 1) * d],
                        &o.data[base + r * d..base + (r + 1) * d],
                    )
                })
                .collect();
            hbm.store(n);
            dv
        })
        .collect();

    let dq_wins = split_windows(
        &mut dq4.data,
        (0..slices).flat_map(|_| (0..t_r).map(|rb| block_rows(rb, blocks.b_r, n) * d)),
    );
    let dq_items: Vec<DqItem<'_>> = dq_wins
        .into_iter()
        .enumerate()
        .map(|(idx, dq_win)| DqItem { s: idx / t_r, rb: idx % t_r, dq_win })
        .collect();
    let dk_wins = split_windows(
        &mut dk4.data,
        (0..slices).flat_map(|_| (0..t_c).map(|cb| block_rows(cb, blocks.b_c, n_k) * d)),
    );
    let dv_wins = split_windows(
        &mut dv4.data,
        (0..slices).flat_map(|_| (0..t_c).map(|cb| block_rows(cb, blocks.b_c, n_k) * d)),
    );
    let dkv_items: Vec<DkvItem<'_>> = dk_wins
        .into_iter()
        .zip(dv_wins)
        .enumerate()
        .map(|(idx, (dk_win, dv_win))| {
            DkvItem { s: idx / t_c, cb: idx % t_c, dk_win, dv_win }
        })
        .collect();

    // Phase 1: all slices' dQ row blocks through one pool.
    let mut report =
        run_pool_guarded(dq_items, workers, hbm, FaultSite::SparseDq, plan, validate, |it| {
            let cfg_s = &per_cfg[it.s];
            let mask = mask_for(masks, h, slices, it.s);
            sparse_dq_row_sweep(
                &q.data[it.s * n * d..(it.s + 1) * n * d],
                &k.data[it.s * n_k * d..(it.s + 1) * n_k * d],
                &v.data[it.s * n_k * d..(it.s + 1) * n_k * d],
                &dout.data[it.s * n * d..(it.s + 1) * n * d],
                &stats.lse[it.s * n..(it.s + 1) * n],
                &d_vecs[it.s],
                n,
                n_k,
                d,
                mask,
                tile_base,
                cfg_s,
                blocks,
                cfg_s.tau_for(d),
                cfg_s.kv_limit(n_k),
                it.rb,
                it.rb + 1,
                it.dq_win,
            )
        })
        .map_err(|e| e.located(h))?;

    // Phase 2: all slices' dK/dV column blocks through one pool.
    let dkv_report =
        run_pool_guarded(dkv_items, workers, hbm, FaultSite::SparseDkv, plan, validate, |it| {
            let cfg_s = &per_cfg[it.s];
            let mask = mask_for(masks, h, slices, it.s);
            dkv_col_sweep_filtered(
                &q.data[it.s * n * d..(it.s + 1) * n * d],
                &k.data[it.s * n_k * d..(it.s + 1) * n_k * d],
                &v.data[it.s * n_k * d..(it.s + 1) * n_k * d],
                &dout.data[it.s * n * d..(it.s + 1) * n * d],
                &stats.lse[it.s * n..(it.s + 1) * n],
                &d_vecs[it.s],
                n,
                n_k,
                d,
                cfg_s,
                blocks,
                cfg_s.tau_for(d),
                cfg_s.kv_limit(n_k),
                it.cb,
                it.cb + 1,
                it.dk_win,
                it.dv_win,
                |i, j| mask.get(i, tile_base + j),
            )
        })
        .map_err(|e| e.located(h))?;
    report.merge(&dkv_report);

    Ok((AttnGrads { dq: dq4, dk: dk4, dv: dv4 }, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::flash2::{flash2_backward, flash2_forward};
    use crate::attn::{attention_backward_batched, BackwardKernel};
    use crate::util::prop::{choose, for_each_case, usize_in};
    use crate::util::rng::SplitMix64;

    fn rand4(shape: &[usize], rng: &mut SplitMix64) -> Tensor {
        Tensor::randn(shape, rng, 1.0)
    }

    /// Reference: the per-slice loop the batched entry points replace.
    fn per_slice_forward(
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        cfg: &AttnConfig,
        blocks: Blocks,
        workers: usize,
        hbm: &mut Hbm,
    ) -> BatchedFlash2Output {
        let (b, h, n, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
        let mut o = Tensor::zeros(&[b, h, n, d]);
        let mut lse = Vec::new();
        for s in 0..b * h {
            let cfg_s = AttnConfig { bh_index: cfg.bh_index + s as u32, ..cfg.clone() };
            let (qs, ks, vs) = (bh_slice(q, s), bh_slice(k, s), bh_slice(v, s));
            let f = flash2_forward(&qs, &ks, &vs, &cfg_s, blocks, workers, hbm);
            o.data[s * n * d..(s + 1) * n * d].copy_from_slice(&f.o.data);
            lse.extend_from_slice(&f.lse);
        }
        BatchedFlash2Output { o, stats: BatchedAttnStats { n, lse } }
    }

    #[test]
    fn batched_forward_bitwise_matches_per_slice_loop() {
        // The ISSUE grid: batch × heads × (n, n_k) rectangular × causal ×
        // kv_len × dropout × blocks × workers. Parity must be bitwise —
        // the scheduler reuses the identical per-block sweeps.
        for_each_case("batched_fwd_parity", 20, |rng| {
            let b = usize_in(rng, 1, 3);
            let h = usize_in(rng, 1, 3);
            let n = usize_in(rng, 2, 32);
            let n_k = if rng.next_f32() < 0.5 { n } else { usize_in(rng, 1, 40) };
            let d = *choose(rng, &[2usize, 4, 8]);
            let blocks = Blocks::explicit(usize_in(rng, 1, n), usize_in(rng, 1, n_k));
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let q = rand4(&[b, h, n, d], rng);
            let k = rand4(&[b, h, n_k, d], rng);
            let v = rand4(&[b, h, n_k, d], rng);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let ctx = format!(
                "b={b} h={h} n={n} n_k={n_k} d={d} blocks=({},{}) causal={causal} \
                 kv_len={kv_len:?} p={dropout_p} w={workers}",
                blocks.b_r, blocks.b_c
            );
            let loop_out = per_slice_forward(&q, &k, &v, &cfg, blocks, 1, &mut Hbm::new());
            let batched =
                flash2_forward_batched(&q, &k, &v, &cfg, blocks, workers, &mut Hbm::new());
            assert_eq!(batched.o.data, loop_out.o.data, "O not bitwise equal: {ctx}");
            assert_eq!(batched.stats.lse, loop_out.stats.lse, "lse not bitwise equal: {ctx}");
        });
    }

    #[test]
    fn batched_backward_bitwise_matches_per_slice_loop() {
        for_each_case("batched_bwd_parity", 20, |rng| {
            let b = usize_in(rng, 1, 3);
            let h = usize_in(rng, 1, 3);
            let n = usize_in(rng, 2, 28);
            let n_k = if rng.next_f32() < 0.5 { n } else { usize_in(rng, 1, 36) };
            let d = *choose(rng, &[2usize, 4, 8]);
            let blocks = Blocks::explicit(usize_in(rng, 1, n), usize_in(rng, 1, n_k));
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            let q = rand4(&[b, h, n, d], rng);
            let k = rand4(&[b, h, n_k, d], rng);
            let v = rand4(&[b, h, n_k, d], rng);
            let dout = rand4(&[b, h, n, d], rng);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let ctx = format!(
                "b={b} h={h} n={n} n_k={n_k} d={d} blocks=({},{}) causal={causal} \
                 kv_len={kv_len:?} p={dropout_p} w={workers}",
                blocks.b_r, blocks.b_c
            );
            let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, workers, &mut Hbm::new());
            let batched = flash2_backward_batched(
                &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, workers, &mut Hbm::new(),
            );
            // Per-slice loop on identical inputs.
            let (mut dq, mut dk, mut dv) = (
                Tensor::zeros(&[b, h, n, d]),
                Tensor::zeros(&[b, h, n_k, d]),
                Tensor::zeros(&[b, h, n_k, d]),
            );
            for s in 0..b * h {
                let cfg_s = AttnConfig { bh_index: s as u32, ..cfg.clone() };
                let (qs, ks, vs) = (bh_slice(&q, s), bh_slice(&k, s), bh_slice(&v, s));
                let os = bh_slice(&fwd.o, s);
                let dos = bh_slice(&dout, s);
                let g = flash2_backward(
                    &qs, &ks, &vs, &os, &dos, fwd.stats.slice(s), &cfg_s, blocks, 1,
                    &mut Hbm::new(),
                );
                dq.data[s * n * d..(s + 1) * n * d].copy_from_slice(&g.dq.data);
                dk.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dk.data);
                dv.data[s * n_k * d..(s + 1) * n_k * d].copy_from_slice(&g.dv.data);
            }
            assert_eq!(batched.dq.data, dq.data, "dQ not bitwise equal: {ctx}");
            assert_eq!(batched.dk.data, dk.data, "dK not bitwise equal: {ctx}");
            assert_eq!(batched.dv.data, dv.data, "dV not bitwise equal: {ctx}");
        });
    }

    #[test]
    fn batched_deterministic_and_traffic_invariant_across_worker_counts() {
        // Output bitwise identical AND instrumented HBM totals identical
        // for any worker count — scheduling must change neither numerics
        // nor modeled traffic.
        let mut rng = SplitMix64::new(31);
        let (b, h, n, d) = (2usize, 3usize, 40usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig::causal();
        let blocks = Blocks::explicit(8, 8);
        let mut h1 = Hbm::new();
        let base = flash2_forward_batched(&q, &k, &v, &cfg, blocks, 1, &mut h1);
        let mut hb1 = Hbm::new();
        let gbase = flash2_backward_batched(
            &q, &k, &v, &base.o, &dout, &base.stats, &cfg, blocks, 1, &mut hb1,
        );
        for workers in [2usize, 3, 5, 8, 64] {
            let mut hw = Hbm::new();
            let multi = flash2_forward_batched(&q, &k, &v, &cfg, blocks, workers, &mut hw);
            assert_eq!(base.o.data, multi.o.data, "O at workers={workers}");
            assert_eq!(base.stats.lse, multi.stats.lse, "lse at workers={workers}");
            assert_eq!((h1.loads, h1.stores), (hw.loads, hw.stores), "fwd hbm at w={workers}");
            let mut hbw = Hbm::new();
            let g = flash2_backward_batched(
                &q, &k, &v, &base.o, &dout, &base.stats, &cfg, blocks, workers, &mut hbw,
            );
            assert_eq!(gbase.dq.data, g.dq.data, "dQ at workers={workers}");
            assert_eq!(gbase.dk.data, g.dk.data, "dK at workers={workers}");
            assert_eq!(gbase.dv.data, g.dv.data, "dV at workers={workers}");
            assert_eq!((hb1.loads, hb1.stores), (hbw.loads, hbw.stores), "bwd hbm at w={workers}");
        }
    }

    #[test]
    fn batched_backward_grads_match_finite_difference() {
        // FD check straight through the batched pair: d(sum O)/dx by
        // central differences on a [2, 2, n, d] causal+padded workload.
        let mut rng = SplitMix64::new(33);
        let (b, h, n, d) = (2usize, 2usize, 6usize, 4usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig { causal: true, kv_len: Some(5), ..Default::default() };
        let blocks = Blocks::explicit(2, 3);
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, 2, &mut Hbm::new());
        let dout = Tensor::full(&[b, h, n, d], 1.0);
        let g = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, 2, &mut Hbm::new(),
        );
        let f = |q_: &Tensor, k_: &Tensor, v_: &Tensor| -> f32 {
            flash2_forward_batched(q_, k_, v_, &cfg, blocks, 1, &mut Hbm::new())
                .o
                .data
                .iter()
                .sum()
        };
        let eps = 1e-3f32;
        // Indices spread across all four slices.
        for (which, (x, gx)) in [(0, (&q, &g.dq)), (1, (&k, &g.dk)), (2, (&v, &g.dv))] {
            for idx in [0usize, 13, 29, 41, 57, 73, 89] {
                let mut xp = x.clone();
                xp.data[idx] += eps;
                let mut xm = x.clone();
                xm.data[idx] -= eps;
                let (fp, fm) = match which {
                    0 => (f(&xp, &k, &v), f(&xm, &k, &v)),
                    1 => (f(&q, &xp, &v), f(&q, &xm, &v)),
                    _ => (f(&q, &k, &xp), f(&q, &k, &xm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                let an = gx.data[idx];
                assert!(
                    (fd - an).abs() < 2e-2 + 0.05 * an.abs(),
                    "which={which} idx={idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn entry_point_reference_kernels_agree_with_batched_fast_path() {
        // attention_backward_batched: every BackwardKernel role accepts
        // the [batch, heads, n, d] layout and they agree numerically —
        // gradient producers pick a policy role, not a layout.
        let mut rng = SplitMix64::new(35);
        let (b, h, n, d) = (2usize, 2usize, 16usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let cfg = AttnConfig::causal();
        let blocks = Blocks::explicit(4, 4);
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, 2, &mut Hbm::new());
        let grads: Vec<AttnGrads> = [
            BackwardKernel::Standard,
            BackwardKernel::Flash,
            BackwardKernel::Flash2 { workers: 3 },
        ]
        .into_iter()
        .map(|kernel| {
            attention_backward_batched(
                kernel, &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, &mut Hbm::new(),
            )
        })
        .collect();
        for g in &grads[1..] {
            assert!(grads[0].dq.max_abs_diff(&g.dq) < 1e-4);
            assert!(grads[0].dk.max_abs_diff(&g.dk) < 1e-4);
            assert!(grads[0].dv.max_abs_diff(&g.dv) < 1e-4);
        }
        assert_eq!(grads[2].dq.shape, vec![b, h, n, d]);
    }

    #[test]
    fn many_entry_handles_heterogeneous_slices() {
        // The sharded-driver shape: slices with different key counts and
        // per-slice kv_len remaps in one pool, bitwise equal to per-slice
        // calls.
        let mut rng = SplitMix64::new(37);
        let q = Tensor::randn(&[24, 8], &mut rng, 1.0);
        let k = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let v = Tensor::randn(&[40, 8], &mut rng, 1.0);
        let blocks = Blocks::explicit(8, 8);
        let ranges = [(0usize, 12usize, Some(12usize)), (12, 20, Some(8)), (20, 40, Some(1))];
        let slices: Vec<AttnSlice<'_>> = ranges
            .iter()
            .map(|&(lo, hi, kv)| AttnSlice {
                q: &q.data[..],
                k: &k.data[lo * 8..hi * 8],
                v: &v.data[lo * 8..hi * 8],
                n: 24,
                n_k: hi - lo,
                d: 8,
                cfg: AttnConfig { kv_len: kv, ..Default::default() },
            })
            .collect();
        let outs = flash2_forward_many(&slices, blocks, 3, &mut Hbm::new());
        for (i, (&(lo, hi, kv), out)) in ranges.iter().zip(&outs).enumerate() {
            let ks = k.slice_rows(lo, hi);
            let vs = v.slice_rows(lo, hi);
            let cfg = AttnConfig { kv_len: kv, ..Default::default() };
            let reference = flash2_forward(&q, &ks, &vs, &cfg, blocks, 1, &mut Hbm::new());
            assert_eq!(out.o.data, reference.o.data, "shard {i} O");
            assert_eq!(out.lse, reference.lse, "shard {i} lse");
        }
    }

    #[test]
    fn no_keys_slice_keeps_all_masked_semantics() {
        // n_k = 0 (an empty shard / fully-dead slice) must reproduce the
        // per-slice kernel's defined semantics with no NaN anywhere.
        let mut rng = SplitMix64::new(39);
        let (b, h, n, d) = (1usize, 2usize, 8usize, 4usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = Tensor::zeros(&[b, h, 0, d]);
        let v = Tensor::zeros(&[b, h, 0, d]);
        let blocks = Blocks::explicit(4, 4);
        let fwd =
            flash2_forward_batched(&q, &k, &v, &AttnConfig::default(), blocks, 2, &mut Hbm::new());
        assert!(fwd.o.data.iter().all(|&x| x == 0.0));
        assert!(fwd.stats.lse.iter().all(|&x| x == f32::NEG_INFINITY));
        let dout = Tensor::full(&[b, h, n, d], 1.0);
        let g = flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &AttnConfig::default(), blocks, 2,
            &mut Hbm::new(),
        );
        assert!(g.dq.data.iter().all(|&x| x == 0.0));
        assert_eq!(g.dk.numel(), 0);
        assert_eq!(g.dv.numel(), 0);
    }

    #[test]
    fn batched_hbm_equals_sum_of_per_slice_counts() {
        // The tentpole IO invariant: batching must not change per-slice
        // traffic, so totals are exactly slices × the per-slice count.
        let mut rng = SplitMix64::new(41);
        let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let blocks = Blocks::explicit(8, 8);
        let cfg = AttnConfig::default();
        let mut h_batched = Hbm::new();
        let fwd = flash2_forward_batched(&q, &k, &v, &cfg, blocks, 3, &mut h_batched);
        let mut h_slice = Hbm::new();
        let qs = bh_slice(&q, 0);
        let ks = bh_slice(&k, 0);
        let vs = bh_slice(&v, 0);
        flash2_forward(&qs, &ks, &vs, &cfg, blocks, 1, &mut h_slice);
        assert_eq!(h_batched.loads, 4 * h_slice.loads);
        assert_eq!(h_batched.stores, 4 * h_slice.stores);
        // Backward too.
        let dout = rand4(&[b, h, n, d], &mut rng);
        let mut hb_batched = Hbm::new();
        flash2_backward_batched(
            &q, &k, &v, &fwd.o, &dout, &fwd.stats, &cfg, blocks, 3, &mut hb_batched,
        );
        let f = flash2_forward(&qs, &ks, &vs, &cfg, blocks, 1, &mut Hbm::new());
        let mut hb_slice = Hbm::new();
        let dos = bh_slice(&dout, 0);
        flash2_backward(
            &qs, &ks, &vs, &f.o, &dos, f.stats(), &cfg, blocks, 1, &mut hb_slice,
        );
        assert_eq!(hb_batched.loads, 4 * hb_slice.loads);
        assert_eq!(hb_batched.stores, 4 * hb_slice.stores);
    }

    #[test]
    fn sparse_batched_bitwise_matches_per_slice_loop() {
        // The sparse scheduler contract, per-head masks included: a
        // [b, h, n, d] workload through block_sparse2_forward_batched /
        // _backward_batched must be BITWISE equal to the per-slice
        // block_sparse2 loop, for any worker count.
        use crate::attn::block_sparse::{block_sparse2_backward, block_sparse2_forward};
        for_each_case("sparse_batched_parity", 12, |rng| {
            let b = usize_in(rng, 1, 2);
            let h = usize_in(rng, 1, 3);
            let n = 8 * usize_in(rng, 1, 4);
            let n_k = 8 * usize_in(rng, 1, 5);
            let d = *choose(rng, &[2usize, 4, 8]);
            let blocks = Blocks::explicit(8, 8);
            let (t_r, t_c) = (n / 8, n_k / 8);
            let causal = rng.next_f32() < 0.5;
            let kv_len = if rng.next_f32() < 0.5 { Some(usize_in(rng, 1, n_k)) } else { None };
            let dropout_p = if rng.next_f32() < 0.3 { 0.2 } else { 0.0 };
            let workers = usize_in(rng, 1, 6);
            // Per-head masks (shared across the batch): butterfly for
            // even heads, local_global for odd.
            let masks: Vec<BlockMask> = (0..h)
                .map(|hh| {
                    if hh % 2 == 0 {
                        BlockMask::butterfly(t_r, t_c)
                    } else {
                        BlockMask::local_global(t_r, t_c, 1, 1)
                    }
                })
                .collect();
            let q = rand4(&[b, h, n, d], rng);
            let k = rand4(&[b, h, n_k, d], rng);
            let v = rand4(&[b, h, n_k, d], rng);
            let dout = rand4(&[b, h, n, d], rng);
            let cfg =
                AttnConfig { causal, kv_len, dropout_p, dropout_seed: 7, ..Default::default() };
            let ctx = format!(
                "b={b} h={h} n={n} n_k={n_k} d={d} causal={causal} kv_len={kv_len:?} \
                 p={dropout_p} w={workers}"
            );
            let bfwd = block_sparse2_forward_batched(
                &q, &k, &v, &masks, &cfg, blocks, workers, &mut Hbm::new(),
            );
            let bg = block_sparse2_backward_batched(
                &q, &k, &v, &bfwd.o, &dout, &bfwd.stats, &masks, &cfg, blocks, workers,
                &mut Hbm::new(),
            );
            for s in 0..b * h {
                let cfg_s = AttnConfig { bh_index: s as u32, ..cfg.clone() };
                let mask = &masks[s % h];
                let (qs, ks, vs) = (bh_slice(&q, s), bh_slice(&k, s), bh_slice(&v, s));
                let f = block_sparse2_forward(
                    &qs, &ks, &vs, mask, &cfg_s, blocks, 1, &mut Hbm::new(),
                );
                assert_eq!(
                    &bfwd.o.data[s * n * d..(s + 1) * n * d],
                    &f.o.data[..],
                    "O slice {s}: {ctx}"
                );
                assert_eq!(&bfwd.stats.lse[s * n..(s + 1) * n], &f.lse[..], "lse {s}: {ctx}");
                let g = block_sparse2_backward(
                    &qs, &ks, &vs, &f.o, &bh_slice(&dout, s), f.stats(), mask, &cfg_s, blocks,
                    1, &mut Hbm::new(),
                );
                assert_eq!(
                    &bg.dq.data[s * n * d..(s + 1) * n * d],
                    &g.dq.data[..],
                    "dQ slice {s}: {ctx}"
                );
                assert_eq!(
                    &bg.dk.data[s * n_k * d..(s + 1) * n_k * d],
                    &g.dk.data[..],
                    "dK slice {s}: {ctx}"
                );
                assert_eq!(
                    &bg.dv.data[s * n_k * d..(s + 1) * n_k * d],
                    &g.dv.data[..],
                    "dV slice {s}: {ctx}"
                );
            }
        });
    }

    #[test]
    fn sparse_batched_traffic_invariant_across_worker_counts() {
        // Scheduling must change neither numerics nor modeled traffic —
        // the sparse analogue of the dense invariance test above.
        let mut rng = SplitMix64::new(43);
        let (b, h, n, d) = (2usize, 2usize, 32usize, 8usize);
        let q = rand4(&[b, h, n, d], &mut rng);
        let k = rand4(&[b, h, n, d], &mut rng);
        let v = rand4(&[b, h, n, d], &mut rng);
        let dout = rand4(&[b, h, n, d], &mut rng);
        let masks = vec![BlockMask::butterfly(4, 4)];
        let cfg = AttnConfig::causal();
        let blocks = Blocks::explicit(8, 8);
        let mut h1 = Hbm::new();
        let base = block_sparse2_forward_batched(&q, &k, &v, &masks, &cfg, blocks, 1, &mut h1);
        let mut hb1 = Hbm::new();
        let gbase = block_sparse2_backward_batched(
            &q, &k, &v, &base.o, &dout, &base.stats, &masks, &cfg, blocks, 1, &mut hb1,
        );
        for workers in [2usize, 5, 16] {
            let mut hw = Hbm::new();
            let multi =
                block_sparse2_forward_batched(&q, &k, &v, &masks, &cfg, blocks, workers, &mut hw);
            assert_eq!(base.o.data, multi.o.data, "O at workers={workers}");
            assert_eq!((h1.loads, h1.stores), (hw.loads, hw.stores), "fwd hbm at w={workers}");
            let mut hbw = Hbm::new();
            let g = block_sparse2_backward_batched(
                &q, &k, &v, &base.o, &dout, &base.stats, &masks, &cfg, blocks, workers, &mut hbw,
            );
            assert_eq!(gbase.dq.data, g.dq.data, "dQ at workers={workers}");
            assert_eq!(gbase.dk.data, g.dk.data, "dK at workers={workers}");
            assert_eq!(gbase.dv.data, g.dv.data, "dV at workers={workers}");
            assert_eq!((hb1.loads, hb1.stores), (hbw.loads, hbw.stores), "bwd hbm at w={workers}");
        }
    }
}
