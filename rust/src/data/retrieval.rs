//! Retrieval task (LRA Retrieval analogue): two documents are concatenated
//! with a separator; the label is whether they share the same "topic key".
//! Each document embeds its topic as a sparse motif of key-dependent
//! tokens, so the model must compare information across the two halves —
//! the long-range *cross-document* dependency the LRA task tests.

use super::batch::ClsDataset;
use crate::util::rng::SplitMix64;

pub struct Retrieval {
    pub n_topics: usize,
    /// Motif tokens embedded per document half.
    pub n_motif: usize,
}

impl Default for Retrieval {
    fn default() -> Self {
        Retrieval { n_topics: 8, n_motif: 6 }
    }
}

/// vocab: 0..=15 filler, 16..=23 topic motif tokens, 24 separator.
const MOTIF_BASE: i32 = 16;
const SEP: i32 = 24;

impl Retrieval {
    fn fill_half(&self, out: &mut [i32], topic: usize, rng: &mut SplitMix64) {
        for t in out.iter_mut() {
            *t = rng.below(16) as i32;
        }
        let len = out.len();
        let stride = (len / self.n_motif).max(1);
        for i in 0..self.n_motif {
            let jitter = rng.below(stride as u64) as usize;
            let pos = (i * stride + jitter).min(len - 1);
            out[pos] = MOTIF_BASE + topic as i32;
        }
    }
}

impl ClsDataset for Retrieval {
    fn name(&self) -> &'static str {
        "Retrieval"
    }

    fn n_classes(&self) -> usize {
        2
    }

    fn vocab(&self) -> usize {
        25
    }

    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        let half = (seq - 1) / 2;
        let label = (rng.next_f32() < 0.5) as i32;
        let topic_a = rng.below(self.n_topics as u64) as usize;
        let topic_b = if label == 1 {
            topic_a
        } else {
            let mut t = rng.below(self.n_topics as u64) as usize;
            while t == topic_a {
                t = rng.below(self.n_topics as u64) as usize;
            }
            t
        };
        let mut toks = vec![0i32; seq];
        {
            let (a, rest) = toks.split_at_mut(half);
            self.fill_half(a, topic_a, rng);
            rest[0] = SEP;
            let blen = rest.len() - 1;
            self.fill_half(&mut rest[1..1 + blen], topic_b, rng);
        }
        (toks, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_matches_topic_agreement() {
        let ds = Retrieval::default();
        let mut rng = SplitMix64::new(0);
        for _ in 0..100 {
            let (toks, label) = ds.sample(129, &mut rng);
            let sep = toks.iter().position(|&t| t == SEP).unwrap();
            let topic = |half: &[i32]| {
                half.iter().find(|&&t| t >= MOTIF_BASE).map(|&t| t - MOTIF_BASE)
            };
            let ta = topic(&toks[..sep]).unwrap();
            let tb = topic(&toks[sep + 1..]).unwrap();
            assert_eq!((ta == tb) as i32, label);
        }
    }

    #[test]
    fn balanced() {
        let ds = Retrieval::default();
        let mut rng = SplitMix64::new(1);
        let ones: i32 = (0..600).map(|_| ds.sample(65, &mut rng).1).sum();
        assert!((200..400).contains(&ones), "{ones}");
    }

    #[test]
    fn in_vocab() {
        let ds = Retrieval::default();
        let mut rng = SplitMix64::new(2);
        let (toks, _) = ds.sample(128, &mut rng);
        assert!(toks.iter().all(|&t| (t as usize) < ds.vocab()));
    }
}
