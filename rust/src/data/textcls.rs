//! Byte-classification task (LRA Text analogue): the label is decided by
//! "sentiment" marker tokens scattered uniformly over the whole sequence
//! amid filler noise — a model must aggregate signal across the full range
//! to beat chance, and local-window models degrade as the sequence grows.

use super::batch::ClsDataset;
use crate::util::rng::SplitMix64;

pub struct TextCls {
    /// Number of marker tokens hidden in the sequence.
    pub n_markers: usize,
}

impl Default for TextCls {
    fn default() -> Self {
        TextCls { n_markers: 9 }
    }
}

/// vocab: 0..=15 filler, 16 = positive marker, 17 = negative marker.
const POS: i32 = 16;
const NEG: i32 = 17;

impl ClsDataset for TextCls {
    fn name(&self) -> &'static str {
        "Text"
    }

    fn n_classes(&self) -> usize {
        2
    }

    fn vocab(&self) -> usize {
        18
    }

    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        let mut toks: Vec<i32> = (0..seq).map(|_| rng.below(16) as i32).collect();
        // Majority class decided up-front; markers placed at uniform slots.
        let label = (rng.next_f32() < 0.5) as i32;
        let n = self.n_markers.min(seq);
        let majority = (n / 2) + 1;
        let mut kinds: Vec<i32> = (0..n)
            .map(|i| {
                if i < majority {
                    if label == 1 {
                        POS
                    } else {
                        NEG
                    }
                } else if label == 1 {
                    NEG
                } else {
                    POS
                }
            })
            .collect();
        rng.shuffle(&mut kinds);
        // Uniform placement => evidence spans the entire sequence.
        let stride = seq / n.max(1);
        for (i, kind) in kinds.into_iter().enumerate() {
            let jitter = rng.below(stride.max(1) as u64) as usize;
            let pos = (i * stride + jitter).min(seq - 1);
            toks[pos] = kind;
        }
        (toks, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_recoverable_by_majority() {
        let ds = TextCls::default();
        let mut rng = SplitMix64::new(0);
        for _ in 0..200 {
            let (toks, label) = ds.sample(128, &mut rng);
            let pos = toks.iter().filter(|&&t| t == POS).count() as i32;
            let neg = toks.iter().filter(|&&t| t == NEG).count() as i32;
            assert_eq!((pos > neg) as i32, label);
        }
    }

    #[test]
    fn markers_spread_across_sequence() {
        let ds = TextCls::default();
        let mut rng = SplitMix64::new(1);
        let (toks, _) = ds.sample(256, &mut rng);
        let marker_pos: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, &t)| t >= POS)
            .map(|(i, _)| i)
            .collect();
        assert!(marker_pos.first().copied().unwrap_or(256) < 64);
        assert!(marker_pos.last().copied().unwrap_or(0) > 192);
    }

    #[test]
    fn balanced_classes() {
        let ds = TextCls::default();
        let mut rng = SplitMix64::new(2);
        let ones: i32 = (0..1000).map(|_| ds.sample(64, &mut rng).1).sum();
        assert!((350..650).contains(&ones), "{ones}");
    }
}
