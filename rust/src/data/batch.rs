//! Shared batch containers and the classification-dataset interface.

use crate::util::rng::SplitMix64;

/// A batch of token sequences (+ optional labels) in artifact layout.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    /// Row-major [batch, seq] token ids.
    pub tokens: Vec<i32>,
    /// [batch] class labels (empty for LM batches).
    pub labels: Vec<i32>,
}

impl Batch {
    pub fn new_lm(batch: usize, seq: usize, tokens: Vec<i32>) -> Batch {
        assert_eq!(tokens.len(), batch * seq);
        Batch { batch, seq, tokens, labels: Vec::new() }
    }

    pub fn new_cls(batch: usize, seq: usize, tokens: Vec<i32>, labels: Vec<i32>) -> Batch {
        assert_eq!(tokens.len(), batch * seq);
        assert_eq!(labels.len(), batch);
        Batch { batch, seq, tokens, labels }
    }

    pub fn row(&self, b: usize) -> &[i32] {
        &self.tokens[b * self.seq..(b + 1) * self.seq]
    }
}

/// A generator of labelled sequences for the classifier experiments.
pub trait ClsDataset {
    /// Informative name for logs/tables.
    fn name(&self) -> &'static str;
    /// Number of classes (labels are 0..n_classes).
    fn n_classes(&self) -> usize;
    /// Vocabulary size the tokens are drawn from.
    fn vocab(&self) -> usize;
    /// Generate one (tokens, label) example of exactly `seq` tokens.
    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32);

    /// Assemble a batch.
    fn batch(&self, batch: usize, seq: usize, rng: &mut SplitMix64) -> Batch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (t, l) = self.sample(seq, rng);
            assert_eq!(t.len(), seq, "{}: wrong length", self.name());
            debug_assert!(t.iter().all(|&x| (x as usize) < self.vocab()));
            tokens.extend_from_slice(&t);
            labels.push(l);
        }
        Batch::new_cls(batch, seq, tokens, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_batch_layout() {
        let b = Batch::new_lm(2, 3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(b.row(0), &[1, 2, 3]);
        assert_eq!(b.row(1), &[4, 5, 6]);
    }

    #[test]
    #[should_panic]
    fn wrong_size_panics() {
        Batch::new_lm(2, 3, vec![1, 2, 3]);
    }
}
