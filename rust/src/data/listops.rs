//! ListOps-style task (LRA): evaluate a nested prefix expression over
//! digits with MAX / MIN / MED / SM (sum mod 10) operators. The label is
//! the expression's value (10 classes). Long-range structure comes from
//! deep nesting: the answer depends on tokens far apart.
//!
//! Token vocabulary (fits the cls_* artifact vocab of 32):
//!   0..=9  digits, 10 '[', 11 ']', 12 MAX, 13 MIN, 14 MED, 15 SM, 16 PAD.

use super::batch::ClsDataset;
use crate::util::rng::SplitMix64;

pub const TOK_OPEN: i32 = 10;
pub const TOK_CLOSE: i32 = 11;
pub const TOK_MAX: i32 = 12;
pub const TOK_MIN: i32 = 13;
pub const TOK_MED: i32 = 14;
pub const TOK_SM: i32 = 15;
pub const TOK_PAD: i32 = 16;

pub struct ListOps {
    pub max_depth: usize,
    pub max_args: usize,
}

impl Default for ListOps {
    fn default() -> Self {
        ListOps { max_depth: 4, max_args: 5 }
    }
}

impl ListOps {
    /// Generate one expression tree; returns (tokens, value).
    fn gen_expr(&self, depth: usize, budget: &mut usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        // Leaf if out of budget or depth, or randomly.
        if depth >= self.max_depth || *budget < 8 || rng.next_f32() < 0.35 {
            let d = rng.below(10) as i32;
            *budget = budget.saturating_sub(1);
            return (vec![d], d);
        }
        let op = TOK_MAX + rng.below(4) as i32;
        let n_args = 2 + rng.below((self.max_args - 1) as u64) as usize;
        let mut toks = vec![TOK_OPEN, op];
        *budget = budget.saturating_sub(3);
        let mut vals = Vec::new();
        for _ in 0..n_args {
            let (t, v) = self.gen_expr(depth + 1, budget, rng);
            toks.extend(t);
            vals.push(v);
        }
        toks.push(TOK_CLOSE);
        let val = match op {
            TOK_MAX => *vals.iter().max().unwrap(),
            TOK_MIN => *vals.iter().min().unwrap(),
            TOK_MED => {
                let mut s = vals.clone();
                s.sort();
                s[s.len() / 2]
            }
            _ => vals.iter().sum::<i32>() % 10, // SM
        };
        (toks, val)
    }
}

impl ClsDataset for ListOps {
    fn name(&self) -> &'static str {
        "ListOps"
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn vocab(&self) -> usize {
        17
    }

    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        let mut budget = seq.saturating_sub(4);
        let (mut toks, val) = self.gen_expr(0, &mut budget, rng);
        toks.truncate(seq);
        while toks.len() < seq {
            toks.push(TOK_PAD);
        }
        (toks, val)
    }
}

/// Independent evaluator used to cross-check generation (tests).
pub fn eval_tokens(toks: &[i32]) -> Option<i32> {
    fn parse(toks: &[i32], pos: &mut usize) -> Option<i32> {
        let t = *toks.get(*pos)?;
        *pos += 1;
        if (0..=9).contains(&t) {
            return Some(t);
        }
        if t != TOK_OPEN {
            return None;
        }
        let op = *toks.get(*pos)?;
        *pos += 1;
        let mut vals = Vec::new();
        while *toks.get(*pos)? != TOK_CLOSE {
            vals.push(parse(toks, pos)?);
        }
        *pos += 1; // consume ']'
        Some(match op {
            TOK_MAX => *vals.iter().max()?,
            TOK_MIN => *vals.iter().min()?,
            TOK_MED => {
                let mut s = vals.clone();
                s.sort();
                s[s.len() / 2]
            }
            TOK_SM => vals.iter().sum::<i32>() % 10,
            _ => return None,
        })
    }
    let mut pos = 0;
    parse(toks, &mut pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_independent_evaluator() {
        let ds = ListOps::default();
        let mut rng = SplitMix64::new(0);
        let mut checked = 0;
        for _ in 0..200 {
            let (toks, label) = ds.sample(128, &mut rng);
            // Strip padding for the evaluator.
            let core: Vec<i32> = toks.iter().cloned().filter(|&t| t != TOK_PAD).collect();
            if let Some(v) = eval_tokens(&core) {
                assert_eq!(v, label, "tokens {core:?}");
                checked += 1;
            }
        }
        assert!(checked > 150, "only {checked} parseable");
    }

    #[test]
    fn labels_in_range_and_varied() {
        let ds = ListOps::default();
        let mut rng = SplitMix64::new(1);
        let mut seen = [false; 10];
        for _ in 0..500 {
            let (_, l) = ds.sample(64, &mut rng);
            assert!((0..10).contains(&l));
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8);
    }

    #[test]
    fn tokens_within_vocab() {
        let ds = ListOps::default();
        let mut rng = SplitMix64::new(2);
        let (toks, _) = ds.sample(256, &mut rng);
        assert_eq!(toks.len(), 256);
        assert!(toks.iter().all(|&t| (t as usize) < ds.vocab()));
    }
}
