//! Synthetic data substrates for the paper's quality experiments
//! (DESIGN.md §4 substitutions):
//!
//! * [`corpus`] — byte-level LM corpus (GPT-2 / Table 2/4 analogue);
//! * [`listops`] — nested list-operation expressions (LRA ListOps);
//! * [`textcls`] — long-range byte classification (LRA Text);
//! * [`retrieval`] — two-document topic matching (LRA Retrieval);
//! * [`image`] — shape images one pixel per token (LRA Image);
//! * [`pathfinder`] — connected-path images fed pixel-by-pixel
//!   (LRA Pathfinder / Path-X / Path-256);
//! * [`longdoc`] — documents whose label needs evidence spread across the
//!   whole document (MIMIC-III / ECtHR, Table 5).
//!
//! All generators are deterministic given a seed.

pub mod batch;
pub mod corpus;
pub mod image;
pub mod listops;
pub mod longdoc;
pub mod pathfinder;
pub mod retrieval;
pub mod textcls;

pub use batch::{Batch, ClsDataset};
