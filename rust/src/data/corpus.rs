//! Byte-level language-modelling corpus: a built-in public-domain text
//! (stand-in for OpenWebText at laptop scale) plus a Markov-expanded
//! synthetic continuation so windows of any context length are available.
//! Byte tokenizer => vocab 256, matching the `gpt_*` artifact configs.

use super::batch::Batch;
use crate::util::rng::SplitMix64;

/// Public-domain seed text (Project Gutenberg openings + common prose).
const SEED_TEXT: &str = "\
It is a truth universally acknowledged, that a single man in possession \
of a good fortune, must be in want of a wife. However little known the \
feelings or views of such a man may be on his first entering a \
neighbourhood, this truth is so well fixed in the minds of the \
surrounding families, that he is considered the rightful property of \
some one or other of their daughters. \
Call me Ishmael. Some years ago, never mind how long precisely, having \
little or no money in my purse, and nothing particular to interest me on \
shore, I thought I would sail about a little and see the watery part of \
the world. It is a way I have of driving off the spleen and regulating \
the circulation. \
Whether I shall turn out to be the hero of my own life, or whether that \
station will be held by anybody else, these pages must show. To begin my \
life with the beginning of my life, I record that I was born on a Friday, \
at twelve o'clock at night. \
In the beginning the Universe was created. This has made a lot of people \
very angry and been widely regarded as a bad move. All happy families \
are alike; each unhappy family is unhappy in its own way. It was the \
best of times, it was the worst of times, it was the age of wisdom, it \
was the age of foolishness, it was the epoch of belief, it was the epoch \
of incredulity, it was the season of Light, it was the season of \
Darkness, it was the spring of hope, it was the winter of despair. ";

/// The training corpus: seed text expanded by an order-3 byte Markov chain
/// to `target_len` bytes, so statistics stay English-like but the model can
/// always find fresh windows.
pub struct Corpus {
    pub bytes: Vec<u8>,
}

impl Corpus {
    pub fn builtin(target_len: usize, seed: u64) -> Corpus {
        let base = SEED_TEXT.as_bytes().to_vec();
        if target_len <= base.len() {
            return Corpus { bytes: base };
        }
        // Order-3 Markov expansion.
        let mut rng = SplitMix64::new(seed);
        let mut out = base.clone();
        let ctx_of = |bytes: &[u8], i: usize| {
            (bytes[i] as usize) | (bytes[i + 1] as usize) << 8 | (bytes[i + 2] as usize) << 16
        };
        // successor lists keyed by 3-byte context
        let mut succ: std::collections::HashMap<usize, Vec<u8>> = std::collections::HashMap::new();
        for i in 0..base.len().saturating_sub(3) {
            succ.entry(ctx_of(&base, i)).or_default().push(base[i + 3]);
        }
        while out.len() < target_len {
            let i = out.len() - 3;
            let key = ctx_of(&out, i);
            let next = match succ.get(&key) {
                Some(cands) => cands[rng.below(cands.len() as u64) as usize],
                None => b' ',
            };
            out.push(next);
        }
        Corpus { bytes: out }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Sample an LM batch of [batch, seq+1] token windows (inputs+targets).
    pub fn lm_batch(&self, batch: usize, seq: usize, rng: &mut SplitMix64) -> Batch {
        let window = seq + 1;
        assert!(self.bytes.len() > window, "corpus shorter than window");
        let mut tokens = Vec::with_capacity(batch * window);
        for _ in 0..batch {
            let start = rng.below((self.bytes.len() - window) as u64) as usize;
            tokens.extend(self.bytes[start..start + window].iter().map(|&b| b as i32));
        }
        Batch::new_lm(batch, window, tokens)
    }

    /// A deterministic validation batch (fixed offsets, disjoint-ish from
    /// random training windows in expectation).
    pub fn eval_batch(&self, batch: usize, seq: usize) -> Batch {
        let window = seq + 1;
        let stride = (self.bytes.len() - window) / batch.max(1);
        let mut tokens = Vec::with_capacity(batch * window);
        for b in 0..batch {
            let start = b * stride;
            tokens.extend(self.bytes[start..start + window].iter().map(|&x| x as i32));
        }
        Batch::new_lm(batch, window, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expands_to_target() {
        let c = Corpus::builtin(10_000, 0);
        assert!(c.len() >= 10_000);
    }

    #[test]
    fn expansion_is_asciiish() {
        let c = Corpus::builtin(5_000, 1);
        let printable = c.bytes.iter().filter(|&&b| (32..127).contains(&b)).count();
        assert!(printable as f64 / c.len() as f64 > 0.99);
    }

    #[test]
    fn lm_batch_shape_and_range() {
        let c = Corpus::builtin(4_000, 2);
        let mut rng = SplitMix64::new(3);
        let b = c.lm_batch(4, 64, &mut rng);
        assert_eq!(b.batch, 4);
        assert_eq!(b.seq, 65);
        assert!(b.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn deterministic_given_seed() {
        let c = Corpus::builtin(4_000, 7);
        let b1 = c.lm_batch(2, 32, &mut SplitMix64::new(9));
        let b2 = c.lm_batch(2, 32, &mut SplitMix64::new(9));
        assert_eq!(b1.tokens, b2.tokens);
    }

    #[test]
    fn eval_batch_fixed() {
        let c = Corpus::builtin(4_000, 7);
        assert_eq!(c.eval_batch(2, 32).tokens, c.eval_batch(2, 32).tokens);
    }
}
