//! Pathfinder-style task (LRA Pathfinder / Path-X / Path-256): a square
//! black-and-white image contains dashed curves; two endpoints are marked.
//! Label: 1 if the endpoints lie on the *same* curve. The image is fed to
//! the transformer one pixel per token, so an s x s grid is a sequence of
//! length s² — the paper scales s from 32 (Pathfinder) to 128 (Path-X,
//! 16K tokens) and 256 (Path-256, 64K tokens); we scale s to the artifact
//! context lengths (s=11 -> 121 tokens, s=16 -> 256, s=22 -> 484).
//!
//! vocab: 0 empty, 1 path pixel, 2 endpoint marker.

use super::batch::ClsDataset;
use crate::util::rng::SplitMix64;

pub struct Pathfinder {
    pub side: usize,
    /// Number of distractor curves.
    pub n_distractors: usize,
}

impl Pathfinder {
    pub fn for_seq(seq: usize) -> Pathfinder {
        let side = (seq as f64).sqrt().floor() as usize;
        Pathfinder { side, n_distractors: 2 }
    }
}

fn walk(
    grid: &mut [i32],
    side: usize,
    start: (usize, usize),
    len: usize,
    rng: &mut SplitMix64,
) -> (usize, usize) {
    let (mut r, mut c) = start;
    grid[r * side + c] = 1;
    let mut dir = rng.below(4) as i32;
    for _ in 0..len {
        // Mostly continue straight; occasionally turn — curve-like walks.
        if rng.next_f32() < 0.35 {
            dir = (dir + if rng.next_f32() < 0.5 { 1 } else { 3 }) % 4;
        }
        let (dr, dc): (i32, i32) = match dir {
            0 => (0, 1),
            1 => (1, 0),
            2 => (0, -1),
            _ => (-1, 0),
        };
        let nr = r as i32 + dr;
        let nc = c as i32 + dc;
        if nr < 0 || nc < 0 || nr >= side as i32 || nc >= side as i32 {
            dir = (dir + 2) % 4; // bounce
            continue;
        }
        r = nr as usize;
        c = nc as usize;
        grid[r * side + c] = 1;
    }
    (r, c)
}

impl ClsDataset for Pathfinder {
    fn name(&self) -> &'static str {
        "Pathfinder"
    }

    fn n_classes(&self) -> usize {
        2
    }

    fn vocab(&self) -> usize {
        3
    }

    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        let side = self.side;
        assert!(side * side <= seq, "side {side} too large for seq {seq}");
        let mut grid = vec![0i32; side * side];
        let path_len = side * 2;
        let rand_cell = |rng: &mut SplitMix64| {
            (rng.below(side as u64) as usize, rng.below(side as u64) as usize)
        };

        let label = (rng.next_f32() < 0.5) as i32;
        let a = rand_cell(rng);
        let end_a = walk(&mut grid, side, a, path_len, rng);
        let (e1, e2) = if label == 1 {
            // Positive: endpoints on the same curve.
            (a, end_a)
        } else {
            // Negative: second endpoint on a *different* curve.
            let mut b = rand_cell(rng);
            while grid[b.0 * side + b.1] == 1 {
                b = rand_cell(rng);
            }
            let _ = walk(&mut grid, side, b, path_len, rng);
            (a, b)
        };
        for _ in 0..self.n_distractors {
            let s = rand_cell(rng);
            let _ = walk(&mut grid, side, s, path_len / 2, rng);
        }
        grid[e1.0 * side + e1.1] = 2;
        grid[e2.0 * side + e2.1] = 2;

        let mut toks = grid;
        toks.resize(seq, 0);
        (toks, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_two_endpoints() {
        let ds = Pathfinder::for_seq(128);
        let mut rng = SplitMix64::new(0);
        for _ in 0..50 {
            let (toks, _) = ds.sample(128, &mut rng);
            assert_eq!(toks.iter().filter(|&&t| t == 2).count(), 2);
        }
    }

    #[test]
    fn balanced_and_in_vocab() {
        let ds = Pathfinder::for_seq(128);
        let mut rng = SplitMix64::new(1);
        let mut ones = 0;
        for _ in 0..300 {
            let (toks, l) = ds.sample(128, &mut rng);
            assert!(toks.iter().all(|&t| (0..3).contains(&t)));
            ones += l;
        }
        assert!((90..210).contains(&ones), "{ones}");
    }

    #[test]
    fn side_scales_with_seq() {
        assert_eq!(Pathfinder::for_seq(121).side, 11);
        assert_eq!(Pathfinder::for_seq(256).side, 16);
        assert_eq!(Pathfinder::for_seq(512).side, 22);
    }

    #[test]
    fn positive_examples_have_connected_endpoints() {
        // BFS over path pixels: endpoints must be connected when label=1.
        let ds = Pathfinder { side: 11, n_distractors: 0 };
        let mut rng = SplitMix64::new(2);
        let mut pos_checked = 0;
        for _ in 0..100 {
            let (toks, label) = ds.sample(128, &mut rng);
            if label != 1 {
                continue;
            }
            let side = 11;
            let idx: Vec<usize> = toks
                .iter()
                .take(side * side)
                .enumerate()
                .filter(|(_, &t)| t == 2)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(idx.len(), 2);
            // BFS from idx[0] over nonzero cells.
            let mut seen = vec![false; side * side];
            let mut queue = vec![idx[0]];
            seen[idx[0]] = true;
            while let Some(p) = queue.pop() {
                let (r, c) = (p / side, p % side);
                for (dr, dc) in [(0i32, 1i32), (1, 0), (0, -1), (-1, 0)] {
                    let nr = r as i32 + dr;
                    let nc = c as i32 + dc;
                    if nr < 0 || nc < 0 || nr >= side as i32 || nc >= side as i32 {
                        continue;
                    }
                    let np = nr as usize * side + nc as usize;
                    if !seen[np] && toks[np] != 0 {
                        seen[np] = true;
                        queue.push(np);
                    }
                }
            }
            assert!(seen[idx[1]], "positive example endpoints disconnected");
            pos_checked += 1;
        }
        assert!(pos_checked > 20);
    }
}
