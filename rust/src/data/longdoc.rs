//! Long-document classification (Table 5 analogue of MIMIC-III / ECtHR):
//! each "document" has a fixed *native* length; `n_evidence` tokens whose
//! sum (mod 10) is the label are spread uniformly across that native
//! length. Training at a shorter context truncates the document and loses
//! evidence — so accuracy rises with context length, reproducing the
//! lift-from-longer-sequences shape of Table 5.

use super::batch::ClsDataset;
use crate::util::rng::SplitMix64;

pub struct LongDoc {
    /// Native document length (evidence is spread over this many tokens).
    pub doc_len: usize,
    pub n_evidence: usize,
}

impl Default for LongDoc {
    fn default() -> Self {
        LongDoc { doc_len: 512, n_evidence: 8 }
    }
}

/// vocab: 0..=15 filler prose, 16..=25 evidence digits (value = t - 16).
const EV_BASE: i32 = 16;

impl ClsDataset for LongDoc {
    fn name(&self) -> &'static str {
        "LongDoc"
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn vocab(&self) -> usize {
        26
    }

    /// Returns the first `seq` tokens of a native-length document — the
    /// truncation a short-context model would see.
    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        let mut doc: Vec<i32> = (0..self.doc_len).map(|_| rng.below(16) as i32).collect();
        let stride = self.doc_len / self.n_evidence;
        let mut total = 0i32;
        for i in 0..self.n_evidence {
            let v = rng.below(10) as i32;
            total += v;
            let jitter = rng.below(stride.max(1) as u64) as usize;
            let pos = (i * stride + jitter).min(self.doc_len - 1);
            doc[pos] = EV_BASE + v;
        }
        let label = total % 10;
        doc.truncate(seq);
        doc.resize(seq, 0);
        (doc, label)
    }
}

/// Fraction of evidence visible at a context length (analysis helper).
pub fn expected_evidence_fraction(doc_len: usize, ctx: usize) -> f64 {
    (ctx.min(doc_len) as f64) / doc_len as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_is_sum_of_evidence_at_full_context() {
        let ds = LongDoc { doc_len: 256, n_evidence: 8 };
        let mut rng = SplitMix64::new(0);
        for _ in 0..100 {
            let (toks, label) = ds.sample(256, &mut rng);
            let sum: i32 = toks.iter().filter(|&&t| t >= EV_BASE).map(|&t| t - EV_BASE).sum();
            assert_eq!(sum % 10, label);
        }
    }

    #[test]
    fn truncation_hides_evidence() {
        let ds = LongDoc { doc_len: 512, n_evidence: 8 };
        let mut rng = SplitMix64::new(1);
        let mut visible = 0usize;
        for _ in 0..100 {
            let (toks, _) = ds.sample(128, &mut rng);
            visible += toks.iter().filter(|&&t| t >= EV_BASE).count();
        }
        // ~ 1/4 of the 8 evidence tokens should survive a 128/512 truncation.
        let avg = visible as f64 / 100.0;
        assert!((1.0..3.5).contains(&avg), "avg evidence visible {avg}");
    }

    #[test]
    fn fraction_helper() {
        assert_eq!(expected_evidence_fraction(512, 512), 1.0);
        assert_eq!(expected_evidence_fraction(512, 128), 0.25);
        assert_eq!(expected_evidence_fraction(512, 1024), 1.0);
    }
}
