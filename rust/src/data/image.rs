//! Image classification task (LRA Image/CIFAR analogue): small grayscale
//! images of parametric shapes, fed one pixel per token. Classes are shape
//! types (full-height vertical bar, horizontal bar, diagonal, filled
//! square) — global structure a pixel-sequence model must integrate over
//! the whole image.

use super::batch::ClsDataset;
use crate::util::rng::SplitMix64;

pub struct ImageCls {
    pub side: usize,
    /// Pixel intensity levels (vocab).
    pub levels: usize,
    /// Probability a pixel is noise-flipped.
    pub noise: f32,
}

impl ImageCls {
    pub fn for_seq(seq: usize) -> ImageCls {
        ImageCls { side: (seq as f64).sqrt().floor() as usize, levels: 4, noise: 0.05 }
    }
}

impl ClsDataset for ImageCls {
    fn name(&self) -> &'static str {
        "Image"
    }

    fn n_classes(&self) -> usize {
        4
    }

    fn vocab(&self) -> usize {
        self.levels
    }

    fn sample(&self, seq: usize, rng: &mut SplitMix64) -> (Vec<i32>, i32) {
        let s = self.side;
        assert!(s * s <= seq);
        let label = rng.below(4) as i32;
        let bright = (self.levels - 1) as i32;
        let mut img = vec![0i32; s * s];
        let pos = 1 + rng.below((s - 2) as u64) as usize;
        match label {
            0 => {
                for r in 0..s {
                    img[r * s + pos] = bright; // vertical bar
                }
            }
            1 => {
                for c in 0..s {
                    img[pos * s + c] = bright; // horizontal bar
                }
            }
            2 => {
                for i in 0..s {
                    img[i * s + i] = bright; // main diagonal
                }
            }
            _ => {
                let half = s / 2;
                for r in pos.saturating_sub(half / 2)..(pos + half / 2).min(s) {
                    for c in pos.saturating_sub(half / 2)..(pos + half / 2).min(s) {
                        img[r * s + c] = bright; // filled square
                    }
                }
            }
        }
        for p in img.iter_mut() {
            if rng.next_f32() < self.noise {
                *p = rng.below(self.levels as u64) as i32;
            }
        }
        let mut toks = img;
        toks.resize(seq, 0);
        (toks, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_distinguishable_by_projections() {
        // Column-sums identify vertical bars; row-sums horizontal — sanity
        // that classes are structurally distinct.
        let ds = ImageCls { side: 11, levels: 4, noise: 0.0 };
        let mut rng = SplitMix64::new(0);
        for _ in 0..50 {
            let (toks, label) = ds.sample(128, &mut rng);
            let s = 11;
            let col_max: i32 =
                (0..s).map(|c| (0..s).map(|r| toks[r * s + c]).sum::<i32>()).max().unwrap();
            let row_max: i32 =
                (0..s).map(|r| (0..s).map(|c| toks[r * s + c]).sum::<i32>()).max().unwrap();
            match label {
                0 => assert_eq!(col_max, 3 * s as i32),
                1 => assert_eq!(row_max, 3 * s as i32),
                _ => {}
            }
        }
    }

    #[test]
    fn labels_uniform_and_in_vocab() {
        let ds = ImageCls::for_seq(128);
        let mut rng = SplitMix64::new(1);
        let mut counts = [0usize; 4];
        for _ in 0..400 {
            let (toks, l) = ds.sample(128, &mut rng);
            counts[l as usize] += 1;
            assert!(toks.iter().all(|&t| (t as usize) < ds.vocab()));
        }
        assert!(counts.iter().all(|&c| c > 50), "{counts:?}");
    }
}
