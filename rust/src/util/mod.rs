//! Small self-contained utilities: PRNG, JSON, CLI parsing, table printing,
//! and a mini property-testing harness (the crate universe available offline
//! has no rand/serde/clap/proptest, so these are built in-repo).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
