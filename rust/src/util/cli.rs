//! Tiny CLI argument parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_options_and_flags() {
        // NOTE: a bare `--x` immediately followed by a positional is parsed
        // as `--x <value>`; flags therefore go last or use `--x=...`.
        let a = parse("train extra --steps 100 --lr=3e-4 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 3e-4);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("device", "a100"), "a100");
        assert_eq!(a.get_usize("n", 64), 64);
        assert!(!a.has_flag("x"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--dry-run --steps 5");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_usize("steps", 0), 5);
    }
}
