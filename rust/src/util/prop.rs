//! Mini property-testing harness (no proptest offline): seeded random case
//! generation with failure reporting. Shrinking is replaced by reporting the
//! failing seed so a case can be replayed deterministically.

use super::rng::SplitMix64;

/// Run `body` over `cases` seeded RNGs; panic with the failing case index
/// and seed on the first assertion failure.
pub fn for_each_case(name: &str, cases: usize, mut body: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = 0xF1A5_4A77 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Uniform usize in [lo, hi] (inclusive).
pub fn usize_in(rng: &mut SplitMix64, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Pick one element of a slice.
pub fn choose<'a, T>(rng: &mut SplitMix64, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len() as u64) as usize]
}

/// Assert |a - b| <= atol + rtol * |b| elementwise.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "{what}: element {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        for_each_case("count", 17, |_| count += 1);
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn reports_failing_case() {
        for_each_case("fails", 5, |rng| {
            let x = rng.next_f32();
            assert!(x < 2.0); // always true
            assert!(false, "boom");
        });
    }

    #[test]
    fn usize_in_bounds() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..1000 {
            let x = usize_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&x));
        }
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0, "eq");
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn allclose_rejects_diff() {
        assert_allclose(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 0.0, "diff");
    }
}
