//! Minimal recursive-descent JSON parser — enough to read
//! `artifacts/manifest.json` (no serde in the offline crate set).
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null). Numbers are kept as f64.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.s[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.s.len() && (self.s[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.s[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"artifacts": {"x": {"file": "x.hlo.txt",
                 "inputs": [{"name": "q", "shape": [8, 128, 64], "dtype": "float32"}],
                 "outputs": []}}}"#,
        )
        .unwrap();
        let a = j.get("artifacts").unwrap().get("x").unwrap();
        assert_eq!(a.get("file").unwrap().as_str(), Some("x.hlo.txt"));
        let inp = &a.get("inputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![8, 128, 64]);
    }
}
