//! Aligned table printing + CSV dump — every bench target prints the same
//! rows the paper reports through this.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(|c| c.as_str()).unwrap_or("");
                let _ = write!(s, " {:<w$} |", cell, w = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Append-safe CSV dump for downstream plotting.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format a float with engineering-friendly precision.
pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["seq", "ms"]);
        t.row(vec!["128".into(), "0.43".into()]);
        t.row(vec!["65536".into(), "9341.30".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.lines().count() >= 4);
        // every data line has the same width
        let lens: Vec<usize> = r.lines().skip(1).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{r}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(12345.6), "12346");
        assert_eq!(fmt(41.7), "41.7");
        assert_eq!(fmt(7.3), "7.300");
        assert_eq!(fmt(0.0001), "1.00e-4");
    }
}
