//! Deterministic PRNGs: SplitMix64 for data/test generation, plus the same
//! counter-based murmur3-finalizer hash the L1 kernels use for dropout
//! (python/compile/kernels/prng.py) so Rust can reproduce kernel dropout
//! masks bit-exactly.

/// SplitMix64 — fast, seedable, full-period 64-bit generator.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Rejection-free modulo is fine for our n << 2^64 use cases.
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fill a vector with N(0, scale^2) samples.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// murmur3 fmix32 over `counter * GOLDEN + seed` — identical to
/// `hash_u32` in python/compile/kernels/prng.py.
pub fn kernel_hash_u32(counter: u32, seed: u32) -> u32 {
    let mut h = counter.wrapping_mul(0x9E37_79B9).wrapping_add(seed);
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    h
}

/// Uniform [0,1) float from the top 24 bits — mirrors `uniform01`.
pub fn kernel_uniform01(counter: u32, seed: u32) -> f32 {
    (kernel_hash_u32(counter, seed) >> 8) as f32 * (1.0 / (1 << 24) as f32)
}

/// Dropout keep decision for attention entry (bh, row, col) of an
/// [BH, n, n] attention matrix — mirrors the kernels' `keep_from_counter`
/// + `tile_counters` composition.
pub fn kernel_dropout_keep(bh: u32, row: u32, col: u32, n: u32, seed: u32, p_drop: f32) -> bool {
    let counter = (bh.wrapping_mul(n).wrapping_add(row))
        .wrapping_mul(n)
        .wrapping_add(col);
    kernel_uniform01(counter, seed) >= p_drop
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_f32_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dropout_rate_matches_p() {
        let n = 256u32;
        let mut dropped = 0usize;
        for row in 0..n {
            for col in 0..n {
                if !kernel_dropout_keep(0, row, col, n, 9, 0.3) {
                    dropped += 1;
                }
            }
        }
        let rate = dropped as f64 / (n as f64 * n as f64);
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
