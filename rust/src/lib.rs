//! # flashattn — IO-aware exact attention, reproduced end to end
//!
//! A three-layer reproduction of *FlashAttention: Fast and Memory-Efficient
//! Exact Attention with IO-Awareness* (Dao et al., NeurIPS 2022):
//!
//! * **L1** — Pallas kernels (Algorithms 2/4/5) under `python/compile/kernels/`,
//!   AOT-lowered to HLO text artifacts;
//! * **L2** — JAX transformer models calling those kernels (`python/compile/`);
//! * **L3** — this crate: the PJRT runtime that loads and executes the
//!   artifacts ([`runtime`]), the training/serving coordinator
//!   ([`coordinator`], [`data`]), pure-Rust mirrors of the paper's
//!   algorithms with instrumented HBM accounting ([`attn`], [`tensor`]),
//!   and the GPU memory-hierarchy simulator that regenerates every table
//!   and figure of the paper's evaluation ([`sim`]).
//!
//! See DESIGN.md for the system inventory and per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

// Lint policy (CI runs `cargo clippy -p flashattn -- -D warnings`): the
// kernel mirrors index tile buffers with explicit `for i in 0..n` loops so
// the code maps line-for-line onto the paper's pseudo-code — iterator
// rewrites would obscure that mapping — and tiled kernels pass their full
// tile geometry (shapes, block ranges, scratch windows) as explicit
// arguments rather than bundling them into ad-hoc structs.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// Invariant R3 (see the catalog in `attn`): the whole tree is safe Rust.
// The `lint` workspace member additionally scans for `unsafe` tokens so a
// future `#[allow]` can't quietly reopen the door.
#![forbid(unsafe_code)]

pub mod attn;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;
