//! flashattn CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   info                         manifest + artifact summary
//!   train      [--model gpt_flash --steps 200 ...]       LM training
//!   train-cls  [--model cls_flash --task listops ...]    classifier training
//!   serve      [--prompt "..." --max-new 64 ...]         batched inference demo
//!   sim        [--table fig1|fig3|mem --device a100]     simulator tables
//!
//! Benchmarks regenerating every paper table/figure live under
//! `cargo bench` (rust/benches/); runnable examples under examples/.

use std::path::Path;

use anyhow::{bail, Result};
use flashattn::attn::Exec;
use flashattn::coordinator::server::Server;
use flashattn::coordinator::{tasks, LmTrainer, TrainConfig};
use flashattn::data::corpus::Corpus;
use flashattn::data::listops::ListOps;
use flashattn::data::longdoc::LongDoc;
use flashattn::data::pathfinder::Pathfinder;
use flashattn::data::textcls::TextCls;
use flashattn::data::ClsDataset;
use flashattn::runtime::Runtime;
use flashattn::sim::baselines::{Method, SWEEP_METHODS};
use flashattn::sim::device::GpuSpec;
use flashattn::sim::roofline::{BenchConfig, Pass, Roofline};
use flashattn::util::cli::Args;
use flashattn::util::table::Table;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "train" => train(&args),
        "train-cls" => train_cls(&args),
        "serve" => serve(&args),
        "sim" => sim(&args),
        _ => {
            println!(
                "usage: flashattn <info|train|train-cls|serve|sim> [options]\n\
                 see `cargo bench` for the paper table/figure reproductions"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn info(args: &Args) -> Result<()> {
    let mut rt = Runtime::cpu(Path::new(&artifacts_dir(args)))?;
    println!("platform: {} ({} devices)", rt.client.platform_name(), rt.client.device_count());
    println!("artifacts: {}", rt.manifest.artifacts.len());
    let mut t = Table::new("models", &["tag", "attention", "ctx", "params"]);
    for (name, m) in &rt.manifest.models {
        t.row(vec![
            name.clone(),
            m.cfg_str("attention").unwrap_or("?").to_string(),
            m.cfg_usize("n_ctx").unwrap_or(0).to_string(),
            m.n_params.to_string(),
        ]);
    }
    t.print();
    // Smoke-run the quickstart artifact.
    let name = "attn_flash_fwd";
    if rt.manifest.artifacts.contains_key(name) {
        rt.load(name)?;
        println!("compiled {name} OK ({:.2}s)", rt.compile_seconds);
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let mut rt = Runtime::cpu(Path::new(&artifacts_dir(args)))?;
    let cfg = TrainConfig {
        model: args.get_or("model", "gpt_flash").to_string(),
        steps: args.get_usize("steps", 200),
        warmup_steps: args.get_usize("warmup", 20),
        lr_max: args.get_f64("lr", 3e-3),
        lr_min: args.get_f64("lr-min", 3e-4),
        eval_every: args.get_usize("log-every", 25),
        seed: args.get_usize("seed", 0) as u64,
    };
    let corpus = Corpus::builtin(args.get_usize("corpus-bytes", 200_000), 1);
    let exec = Exec::new(args.get_usize("workers", 4));
    let mut tr = LmTrainer::new(&mut rt, cfg, &exec)?;
    println!("model {} — {} parameters", tr.cfg.model, tr.n_params());
    let (first, last) = tr.train(&mut rt, &corpus)?;
    let eval = tr.eval_loss(&mut rt, &corpus.eval_batch(tr.batch, tr.n_ctx))?;
    println!(
        "done: loss {first:.4} -> {last:.4} (eval {eval:.4}, ppl {:.2}) in {:.1}s",
        eval.exp(),
        tr.metrics.total_seconds()
    );
    if let Some(csv) = args.get("csv") {
        tr.metrics.write_csv(Path::new(csv))?;
        println!("wrote {csv}");
    }
    if let Some(ckpt) = args.get("save") {
        tr.save(Path::new(ckpt))?;
        println!("saved checkpoint {ckpt}");
    }
    Ok(())
}

fn dataset_by_name(name: &str, n_ctx: usize) -> Result<Box<dyn ClsDataset>> {
    Ok(match name {
        "listops" => Box::new(ListOps::default()),
        "text" => Box::new(TextCls::default()),
        "pathfinder" => Box::new(Pathfinder::for_seq(n_ctx)),
        "longdoc" => Box::new(LongDoc::default()),
        _ => bail!("unknown task {name:?} (listops|text|pathfinder|longdoc)"),
    })
}

fn train_cls(args: &Args) -> Result<()> {
    let mut rt = Runtime::cpu(Path::new(&artifacts_dir(args)))?;
    let model = args.get_or("model", "cls_flash").to_string();
    let n_ctx = rt.manifest.model(&model)?.cfg_usize("n_ctx").unwrap_or(128);
    let ds = dataset_by_name(args.get_or("task", "listops"), n_ctx)?;
    let steps = args.get_usize("steps", 150);
    let exec = Exec::new(args.get_usize("workers", 4));
    let res = tasks::run_task(
        &mut rt,
        &model,
        ds.as_ref(),
        steps,
        args.get_usize("seed", 0) as u64,
        &exec,
    )?;
    println!(
        "{} on {}: accuracy {:.3} (chance {:.3}), eval loss {:.4}, {:.0} ms/step, {:.1}s total",
        res.model,
        res.task,
        res.accuracy,
        tasks::chance_accuracy(ds.as_ref()),
        res.eval_loss,
        res.ms_per_step,
        res.seconds
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let mut rt = Runtime::cpu(Path::new(&artifacts_dir(args)))?;
    let cfg = TrainConfig {
        model: args.get_or("model", "gpt_flash").to_string(),
        steps: args.get_usize("warm-steps", 50),
        ..Default::default()
    };
    let corpus = Corpus::builtin(100_000, 1);
    let exec = Exec::new(args.get_usize("workers", 4));
    let mut tr = LmTrainer::new(&mut rt, cfg, &exec)?;
    if let Some(ckpt) = args.get("ckpt") {
        tr.load(Path::new(ckpt))?;
        println!("loaded checkpoint {ckpt}");
    } else {
        println!("no --ckpt: warming the model with {} quick steps", tr.cfg.steps);
        tr.train(&mut rt, &corpus)?;
    }
    let mut server = Server::new(tr);
    let prompt = args.get_or("prompt", "It is a truth ").to_string();
    let max_new = args.get_usize("max-new", 64);
    for i in 0..args.get_usize("requests", 3) {
        let c = server.complete(&mut rt, &prompt, max_new)?;
        println!("[req {i}] {:.0} ms: {}{}", c.latency_ms, c.prompt, c.text);
    }
    println!(
        "served {} requests, {:.1} tok/s, mean latency {:.0} ms",
        server.stats.requests,
        server.stats.tokens_per_second(),
        server.stats.mean_latency_ms()
    );
    let (io_flash, io_flash2) = server.modeled_attn_io();
    println!(
        "modeled attention O/stats write traffic per forward ({} head slices at n_ctx): \
         flash {io_flash} vs batched flash2 {io_flash2} elems ({:.2}x fewer accumulator \
         round-trips from the Q-outer kernel; heads share one worker pool)",
        server.trainer.n_head,
        io_flash as f64 / io_flash2 as f64
    );
    Ok(())
}

fn sim(args: &Args) -> Result<()> {
    let spec = GpuSpec::by_name(args.get_or("device", "a100"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let rl = Roofline::new(spec);
    let cfg = BenchConfig::default()
        .with_dropout(args.has_flag("dropout"))
        .with_mask(args.has_flag("mask"));
    match args.get_or("table", "fig3") {
        "fig1" => {
            let mut t = Table::new(
                &format!("Fig 1 right — attention speedup over PyTorch ({})", rl.spec.name),
                &["seq len", "PyTorch ms", "Flash ms", "speedup"],
            );
            for n in [128u64, 256, 512, 1024, 2048, 4096] {
                let py = rl.time_ms(Method::PyTorch, Pass::FwdBwd, n, &cfg);
                let fl = rl.time_ms(Method::FlashAttention, Pass::FwdBwd, n, &cfg);
                let sp = match (py, fl) {
                    (Some(p), Some(f)) => format!("{:.2}x", p / f),
                    _ => "-".into(),
                };
                t.row(vec![
                    n.to_string(),
                    flashattn::bench::ms_cell(py),
                    flashattn::bench::ms_cell(fl),
                    sp,
                ]);
            }
            t.print();
        }
        "mem" => {
            let mut t = Table::new(
                "Table 21 — memory (MB)",
                &["method", "1024", "8192", "65536"],
            );
            for m in SWEEP_METHODS {
                t.row(vec![
                    m.name().to_string(),
                    flashattn::bench::ms_cell(rl.mem_mb(*m, 1024, &cfg)),
                    flashattn::bench::ms_cell(rl.mem_mb(*m, 8192, &cfg)),
                    flashattn::bench::ms_cell(rl.mem_mb(*m, 65536, &cfg)),
                ]);
            }
            t.print();
        }
        _ => {
            let ns = [128u64, 512, 1024, 4096, 16384, 65536];
            let mut headers = vec!["method".to_string()];
            headers.extend(ns.iter().map(|n| n.to_string()));
            let mut t = Table::new(
                &format!("Fig 3 left — fwd+bwd runtime ms ({})", rl.spec.name),
                &headers.iter().map(String::as_str).collect::<Vec<_>>(),
            );
            for m in SWEEP_METHODS {
                let mut row = vec![m.name().to_string()];
                for &n in &ns {
                    row.push(flashattn::bench::ms_cell(rl.time_ms(*m, Pass::FwdBwd, n, &cfg)));
                }
                t.row(row);
            }
            t.print();
        }
    }
    Ok(())
}
