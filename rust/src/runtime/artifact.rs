//! Artifact manifest: the calling convention emitted by python/compile/aot.py
//! (`artifacts/manifest.json`) — per-artifact input/output names, shapes and
//! dtypes, plus per-model parameter ordering and configs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("float32")
                .to_string(),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub config: BTreeMap<String, Json>,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub n_params: usize,
}

impl ModelInfo {
    pub fn cfg_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(Json::as_usize)
    }

    pub fn cfg_str(&self, key: &str) -> Option<&str> {
        self.config.get(key).and_then(Json::as_str)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                        .to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        let mut models = BTreeMap::new();
        if let Some(ms) = j.get("models").and_then(Json::as_obj) {
            for (name, m) in ms {
                let param_names = m
                    .get("param_names")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_str().map(String::from))
                    .collect();
                let param_shapes = m
                    .get("param_shapes")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| {
                        v.as_arr().map(|a| {
                            a.iter().map(|x| x.as_usize().unwrap_or(0)).collect::<Vec<_>>()
                        })
                    })
                    .collect();
                models.insert(
                    name.clone(),
                    ModelInfo {
                        config: m
                            .get("config")
                            .and_then(Json::as_obj)
                            .cloned()
                            .unwrap_or_default(),
                        param_names,
                        param_shapes,
                        n_params: m.get("n_params").and_then(Json::as_usize).unwrap_or(0),
                    },
                );
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>()
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// Validate a set of host values against the artifact's input specs.
pub fn check_inputs(spec: &ArtifactSpec, values: &[super::Value]) -> Result<()> {
    if values.len() != spec.inputs.len() {
        bail!(
            "artifact {}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            values.len()
        );
    }
    for (ts, v) in spec.inputs.iter().zip(values) {
        if ts.shape != v.shape() {
            bail!(
                "artifact {} input {:?}: expected shape {:?}, got {:?}",
                spec.name,
                ts.name,
                ts.shape,
                v.shape()
            );
        }
        if ts.dtype != v.dtype_name() {
            bail!(
                "artifact {} input {:?}: expected {}, got {}",
                spec.name,
                ts.name,
                ts.dtype,
                v.dtype_name()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Value;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "attn": {
          "file": "attn.hlo.txt",
          "inputs": [
            {"name": "q", "shape": [2, 4, 8], "dtype": "float32"},
            {"name": "seed", "shape": [], "dtype": "int32"}
          ],
          "outputs": [{"name": "o", "shape": [2, 4, 8], "dtype": "float32"}]
        }
      },
      "models": {
        "gpt": {
          "config": {"vocab": 256, "n_ctx": 128, "attention": "flash"},
          "param_names": ["wte", "wpe"],
          "param_shapes": [[256, 128], [128, 128]],
          "n_params": 49152
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.artifact("attn").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 4, 8]);
        assert_eq!(a.inputs[1].dtype, "int32");
        let g = m.model("gpt").unwrap();
        assert_eq!(g.cfg_usize("vocab"), Some(256));
        assert_eq!(g.cfg_str("attention"), Some("flash"));
        assert_eq!(g.param_names, vec!["wte", "wpe"]);
        assert_eq!(g.n_params, 49152);
    }

    #[test]
    fn unknown_artifact_err() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn input_validation() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.artifact("attn").unwrap();
        let good = vec![
            Value::F32 { shape: vec![2, 4, 8], data: vec![0.0; 64] },
            Value::scalar_i32(0),
        ];
        assert!(check_inputs(a, &good).is_ok());
        let bad_shape = vec![
            Value::F32 { shape: vec![2, 4, 4], data: vec![0.0; 32] },
            Value::scalar_i32(0),
        ];
        assert!(check_inputs(a, &bad_shape).is_err());
        let bad_dtype = vec![
            Value::F32 { shape: vec![2, 4, 8], data: vec![0.0; 64] },
            Value::scalar_f32(0.0),
        ];
        assert!(check_inputs(a, &bad_dtype).is_err());
        assert!(check_inputs(a, &good[..1].to_vec()).is_err());
    }
}
