//! Host tensors crossing the PJRT boundary: f32 and i32, shape-carrying.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, Shape};

use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Value {
        Value::I32 { shape: vec![], data: vec![x] }
    }

    pub fn from_tensor(t: &Tensor) -> Value {
        Value::F32 { shape: t.shape.clone(), data: t.data.clone() }
    }

    pub fn zeros_like_shape(shape: &[usize]) -> Value {
        Value::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Value::F32 { .. } => "float32",
            Value::I32 { .. } => "int32",
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("value is {}, expected float32", self.dtype_name()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            _ => bail!("value is {}, expected int32", self.dtype_name()),
        }
    }

    pub fn to_tensor(&self) -> Result<Tensor> {
        Ok(Tensor::from_vec(self.shape(), self.as_f32()?.to_vec()))
    }

    /// Scalar extraction (rank-0 or single-element).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {:?}", self.shape());
        }
        Ok(d[0])
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32 { data, .. } => Literal::vec1(data),
            Value::I32 { data, .. } => Literal::vec1(data),
        };
        lit.reshape(&dims).context("reshape literal")
    }

    pub fn from_literal(lit: &Literal) -> Result<Value> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(Value::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            ElementType::S32 => Ok(Value::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported artifact dtype {other:?}"),
        }
    }

    /// Destructure a (possibly nested 1-tuple of) tuple literal into Values.
    pub fn from_result_literal(lit: Literal) -> Result<Vec<Value>> {
        match lit.shape()? {
            Shape::Tuple(_) => {
                let parts = lit.to_tuple()?;
                parts.iter().map(Value::from_literal).collect()
            }
            _ => Ok(vec![Value::from_literal(&lit)?]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let v = Value::scalar_f32(3.5);
        assert_eq!(v.scalar().unwrap(), 3.5);
        assert_eq!(v.shape(), &[] as &[usize]);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let v = Value::from_tensor(&t);
        assert_eq!(v.to_tensor().unwrap(), t);
        assert_eq!(v.numel(), 6);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let v = Value::scalar_i32(1);
        assert!(v.as_f32().is_err());
        assert!(v.as_i32().is_ok());
    }
}
