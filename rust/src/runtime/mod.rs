//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path. Python is never on the request path — the Rust binary
//! is self-contained once `make artifacts` has run.
//!
//! Interchange is HLO *text* (see python/compile/aot.py for why), parsed by
//! `xla::HloModuleProto::from_text_file`, compiled with the PJRT CPU client,
//! and executed with `Literal` inputs built from [`Value`] host tensors.

pub mod artifact;
pub mod exec;
pub mod value;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use exec::{Executable, Runtime};
pub use value::Value;
