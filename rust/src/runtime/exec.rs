//! The PJRT execution engine: compile-once cache of loaded executables,
//! typed execute with input validation, and simple step timing.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifact::{check_inputs, ArtifactSpec, Manifest};
use super::value::Value;

/// One compiled artifact, ready to run.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with host values; returns host values (one per manifest output).
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        check_inputs(&self.spec, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = Value::from_result_literal(lit)?;
        anyhow::ensure!(
            outs.len() == self.spec.outputs.len(),
            "artifact {}: expected {} outputs, got {}",
            self.spec.name,
            self.spec.outputs.len(),
            outs.len()
        );
        Ok(outs)
    }

    /// Run and report wall time (used by the perf harness).
    pub fn run_timed(&self, inputs: &[Value]) -> Result<(Vec<Value>, f64)> {
        let t0 = Instant::now();
        let out = self.run(inputs)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    }
}

/// PJRT client + manifest + compile cache. The single entry point the
/// coordinator uses to talk to the artifacts.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, Executable>,
    /// Cumulative compile seconds (visible in metrics).
    pub compile_seconds: f64,
}

impl Runtime {
    /// CPU PJRT client over the given artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new(), compile_seconds: 0.0 })
    }

    /// Compile (or fetch cached) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.artifact(name)?.clone();
            let path = self.manifest.hlo_path(&spec);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.compile_seconds += t0.elapsed().as_secs_f64();
            self.cache.insert(name.to_string(), Executable { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Convenience: load + run in one call.
    pub fn run(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.load(name)?;
        self.cache[name].run(inputs)
    }

    pub fn is_cached(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }
}
