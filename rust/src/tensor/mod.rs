//! Minimal contiguous f32 ndarray — the substrate for the pure-Rust mirrors
//! of the paper's algorithms (attn/) and for host-side verification of the
//! PJRT artifacts. Row-major, owned storage, no views; blocked matmul for
//! the hot paths.

use crate::util::rng::SplitMix64;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], rng: &mut SplitMix64, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, scale) }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major linear offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < dim, "index {x} out of bounds for axis {i} (dim {dim})");
            off = off * dim + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// [r, c] matrix view helpers for rank-2 tensors.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// C = A @ B for 2-D tensors; ikj loop order (B rows stream, vectorises).
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(ka, kb, "matmul inner dims {ka} != {kb}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
        out
    }

    /// C = A @ B^T — avoids materialising the transpose (hot in attention:
    /// S = Q K^T with both operands row-major [n, d]).
    pub fn matmul_bt(&self, b: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (n, kb) = (b.rows(), b.cols());
        assert_eq!(ka, kb, "matmul_bt inner dims {ka} != {kb}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = b.row(j);
                let mut acc = 0.0f32;
                for k in 0..ka {
                    acc += arow[k] * brow[k];
                }
                orow[j] = acc;
            }
        }
        out
    }

    /// C = A^T @ B.
    pub fn matmul_at(&self, b: &Tensor) -> Tensor {
        let (ka, m) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(ka, kb, "matmul_at inner dims {ka} != {kb}");
        let mut out = Tensor::zeros(&[m, n]);
        for k in 0..ka {
            let arow = self.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += aki * brow[j];
                }
            }
        }
        out
    }

    /// Row-wise numerically-stable softmax (rank-2).
    pub fn softmax_rows(&self) -> Tensor {
        let mut out = self.clone();
        let c = self.cols();
        for r in 0..self.rows() {
            let row = &mut out.data[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0;
            for x in row.iter_mut() {
                *x = (*x - m).exp();
                z += *x;
            }
            for x in row.iter_mut() {
                *x /= z;
            }
        }
        out
    }

    pub fn scale(mut self, s: f32) -> Tensor {
        for x in &mut self.data {
            *x *= s;
        }
        self
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Slice rows [lo, hi) of a rank-2 tensor into a new tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        Tensor::from_vec(&[hi - lo, c], self.data[lo * c..hi * c].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, for_each_case, usize_in};

    #[test]
    fn matmul_identity() {
        let mut rng = SplitMix64::new(0);
        let a = Tensor::randn(&[4, 4], &mut rng, 1.0);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data[i * 4 + i] = 1.0;
        }
        assert_allclose(&a.matmul(&eye).data, &a.data, 1e-6, 0.0, "A@I");
        assert_allclose(&eye.matmul(&a).data, &a.data, 1e-6, 0.0, "I@A");
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_bt_equals_matmul_of_transpose() {
        for_each_case("bt", 10, |rng| {
            let (m, k, n) = (usize_in(rng, 1, 8), usize_in(rng, 1, 8), usize_in(rng, 1, 8));
            let a = Tensor::randn(&[m, k], rng, 1.0);
            let b = Tensor::randn(&[n, k], rng, 1.0);
            assert_allclose(&a.matmul_bt(&b).data, &a.matmul(&b.t()).data, 1e-5, 1e-5, "bt");
        });
    }

    #[test]
    fn matmul_at_equals_transpose_matmul() {
        for_each_case("at", 10, |rng| {
            let (m, k, n) = (usize_in(rng, 1, 8), usize_in(rng, 1, 8), usize_in(rng, 1, 8));
            let a = Tensor::randn(&[k, m], rng, 1.0);
            let b = Tensor::randn(&[k, n], rng, 1.0);
            assert_allclose(&a.matmul_at(&b).data, &a.t().matmul(&b).data, 1e-5, 1e-5, "at");
        });
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = SplitMix64::new(2);
        let a = Tensor::randn(&[5, 7], &mut rng, 3.0);
        let p = a.softmax_rows();
        for r in 0..5 {
            let s: f32 = p.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[1, 3], vec![1001.0, 1002.0, 1003.0]);
        assert_allclose(&a.softmax_rows().data, &b.softmax_rows().data, 1e-6, 0.0, "shift");
    }

    #[test]
    fn transpose_involution() {
        let mut rng = SplitMix64::new(3);
        let a = Tensor::randn(&[3, 5], &mut rng, 1.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn offset_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 0, 0]), 12);
    }

    #[test]
    fn slice_rows_roundtrip() {
        let mut rng = SplitMix64::new(4);
        let a = Tensor::randn(&[6, 3], &mut rng, 1.0);
        let s = a.slice_rows(2, 5);
        assert_eq!(s.shape, vec![3, 3]);
        assert_eq!(s.row(0), a.row(2));
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_dim_check() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        a.matmul(&b);
    }
}
