//! Minimal contiguous f32 ndarray — the substrate for the pure-Rust mirrors
//! of the paper's algorithms (attn/) and for host-side verification of the
//! PJRT artifacts. Row-major, owned storage, no views; blocked matmul for
//! the hot paths.

use crate::util::rng::SplitMix64;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], rng: &mut SplitMix64, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, scale) }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major linear offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < dim, "index {x} out of bounds for axis {i} (dim {dim})");
            off = off * dim + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// [r, c] matrix view helpers for rank-2 tensors.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// C = A @ B for 2-D tensors; ikj loop order (B rows stream, vectorises).
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(ka, kb, "matmul inner dims {ka} != {kb}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
        out
    }

    /// C = A @ B^T — avoids materialising the transpose (hot in attention:
    /// S = Q K^T with both operands row-major [n, d]).
    pub fn matmul_bt(&self, b: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (n, kb) = (b.rows(), b.cols());
        assert_eq!(ka, kb, "matmul_bt inner dims {ka} != {kb}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = b.row(j);
                let mut acc = 0.0f32;
                for k in 0..ka {
                    acc += arow[k] * brow[k];
                }
                orow[j] = acc;
            }
        }
        out
    }

    /// C = A^T @ B.
    pub fn matmul_at(&self, b: &Tensor) -> Tensor {
        let (ka, m) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(ka, kb, "matmul_at inner dims {ka} != {kb}");
        let mut out = Tensor::zeros(&[m, n]);
        for k in 0..ka {
            let arow = self.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += aki * brow[j];
                }
            }
        }
        out
    }

    /// Row-wise numerically-stable softmax (rank-2).
    pub fn softmax_rows(&self) -> Tensor {
        let mut out = self.clone();
        let c = self.cols();
        for r in 0..self.rows() {
            let row = &mut out.data[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0;
            for x in row.iter_mut() {
                *x = (*x - m).exp();
                z += *x;
            }
            for x in row.iter_mut() {
                *x /= z;
            }
        }
        out
    }

    pub fn scale(mut self, s: f32) -> Tensor {
        for x in &mut self.data {
            *x *= s;
        }
        self
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Slice rows [lo, hi) of a rank-2 tensor into a new tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        Tensor::from_vec(&[hi - lo, c], self.data[lo * c..hi * c].to_vec())
    }

}

/// out[i*n + j] = scale * (a row i · b row j) for row-major `a`: [m, k] and
/// `b`: [n, k] given as flat slices — the register-blocked fast path behind
/// S = tau·Q·Kᵀ in `attn::flash2`. Unlike [`Tensor::matmul_bt`] it takes
/// raw slices and a caller-provided output buffer (no Tensor views, no
/// allocation in the tile loop) and fuses the softmax scale. The dot
/// products run through [`dot4`], which reassociates the f32 sum
/// (4 accumulator chains), so results differ from `matmul_bt` by rounding
/// only — the reference kernel keeps its strictly sequential sum for the
/// instrumented mirrors.
pub fn matmul_bt_scaled_into(a: &[f32], b: &[f32], k: usize, scale: f32, out: &mut [f32]) {
    assert!(k > 0, "matmul_bt_scaled_into: k must be positive");
    debug_assert_eq!(a.len() % k, 0, "a not a whole number of rows");
    debug_assert_eq!(b.len() % k, 0, "b not a whole number of rows");
    let m = a.len() / k;
    let n = b.len() / k;
    assert!(out.len() >= m * n, "output buffer too small: {} < {}", out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = scale * dot4(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Dot product with 4 unrolled accumulators. f32 addition is not
/// associative, so the single-chain reduction in `matmul_bt` cannot be
/// vectorised or pipelined by the compiler; four independent chains expose
/// the ILP/SIMD the hardware has, at the cost of a reassociated (but
/// equally accurate) sum.
#[inline]
pub fn dot4(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot4 length mismatch");
    let k = a.len().min(b.len());
    let mut ca = a[..k].chunks_exact(4);
    let mut cb = b[..k].chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        s0 += xa[0] * xb[0];
        s1 += xa[1] * xb[1];
        s2 += xa[2] * xb[2];
        s3 += xa[3] * xb[3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (xa, xb) in ca.remainder().iter().zip(cb.remainder()) {
        s += xa * xb;
    }
    s
}

/// acc[c] += Σ_cc p[cc] · v[cc*d + c] — the P̃·V micro-kernel for
/// `attn::flash2`: row-of-V-major (contiguous, vectorisable across c) with
/// 4 V rows in flight per pass so the accumulator row is loaded/stored once
/// per group instead of once per weight. Groups whose 4 weights are all
/// zero (dropout) are skipped.
#[inline]
pub fn pv_accum(p: &[f32], v: &[f32], d: usize, acc: &mut [f32]) {
    debug_assert!(v.len() >= p.len() * d, "V too small for P");
    let accd = &mut acc[..d];
    let bc = p.len();
    let bc4 = bc - bc % 4;
    let mut cc = 0;
    while cc < bc4 {
        let (w0, w1, w2, w3) = (p[cc], p[cc + 1], p[cc + 2], p[cc + 3]);
        if w0 != 0.0 || w1 != 0.0 || w2 != 0.0 || w3 != 0.0 {
            let v0 = &v[cc * d..(cc + 1) * d];
            let v1 = &v[(cc + 1) * d..(cc + 2) * d];
            let v2 = &v[(cc + 2) * d..(cc + 3) * d];
            let v3 = &v[(cc + 3) * d..(cc + 4) * d];
            for c in 0..d {
                accd[c] += w0 * v0[c] + w1 * v1[c] + w2 * v2[c] + w3 * v3[c];
            }
        }
        cc += 4;
    }
    while cc < bc {
        let w = p[cc];
        if w != 0.0 {
            let vr = &v[cc * d..(cc + 1) * d];
            for c in 0..d {
                accd[c] += w * vr[c];
            }
        }
        cc += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, for_each_case, usize_in};

    #[test]
    fn matmul_identity() {
        let mut rng = SplitMix64::new(0);
        let a = Tensor::randn(&[4, 4], &mut rng, 1.0);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data[i * 4 + i] = 1.0;
        }
        assert_allclose(&a.matmul(&eye).data, &a.data, 1e-6, 0.0, "A@I");
        assert_allclose(&eye.matmul(&a).data, &a.data, 1e-6, 0.0, "I@A");
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_bt_equals_matmul_of_transpose() {
        for_each_case("bt", 10, |rng| {
            let (m, k, n) = (usize_in(rng, 1, 8), usize_in(rng, 1, 8), usize_in(rng, 1, 8));
            let a = Tensor::randn(&[m, k], rng, 1.0);
            let b = Tensor::randn(&[n, k], rng, 1.0);
            assert_allclose(&a.matmul_bt(&b).data, &a.matmul(&b.t()).data, 1e-5, 1e-5, "bt");
        });
    }

    #[test]
    fn matmul_at_equals_transpose_matmul() {
        for_each_case("at", 10, |rng| {
            let (m, k, n) = (usize_in(rng, 1, 8), usize_in(rng, 1, 8), usize_in(rng, 1, 8));
            let a = Tensor::randn(&[k, m], rng, 1.0);
            let b = Tensor::randn(&[k, n], rng, 1.0);
            assert_allclose(&a.matmul_at(&b).data, &a.t().matmul(&b).data, 1e-5, 1e-5, "at");
        });
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = SplitMix64::new(2);
        let a = Tensor::randn(&[5, 7], &mut rng, 3.0);
        let p = a.softmax_rows();
        for r in 0..5 {
            let s: f32 = p.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[1, 3], vec![1001.0, 1002.0, 1003.0]);
        assert_allclose(&a.softmax_rows().data, &b.softmax_rows().data, 1e-6, 0.0, "shift");
    }

    #[test]
    fn transpose_involution() {
        let mut rng = SplitMix64::new(3);
        let a = Tensor::randn(&[3, 5], &mut rng, 1.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn offset_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 0, 0]), 12);
    }

    #[test]
    fn slice_rows_roundtrip() {
        let mut rng = SplitMix64::new(4);
        let a = Tensor::randn(&[6, 3], &mut rng, 1.0);
        let s = a.slice_rows(2, 5);
        assert_eq!(s.shape, vec![3, 3]);
        assert_eq!(s.row(0), a.row(2));
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_dim_check() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        a.matmul(&b);
    }

    #[test]
    fn dot4_matches_sequential_sum() {
        let mut rng = SplitMix64::new(7);
        for len in [0usize, 1, 3, 4, 5, 8, 17, 64, 100] {
            let a = rng.normal_vec(len, 1.0);
            let b = rng.normal_vec(len, 1.0);
            let seq: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot4(&a, &b);
            assert!(
                (seq - fast).abs() <= 1e-5 + 1e-5 * seq.abs(),
                "len {len}: {seq} vs {fast}"
            );
        }
    }

    #[test]
    fn matmul_bt_scaled_into_matches_reference() {
        for_each_case("bt_into", 10, |rng| {
            let (m, k, n) = (usize_in(rng, 1, 9), usize_in(rng, 1, 9), usize_in(rng, 1, 9));
            let a = Tensor::randn(&[m, k], rng, 1.0);
            let b = Tensor::randn(&[n, k], rng, 1.0);
            let scale = 0.5 + rng.next_f32();
            let reference = a.matmul_bt(&b).scale(scale);
            let mut out = vec![0.0f32; m * n];
            matmul_bt_scaled_into(&a.data, &b.data, k, scale, &mut out);
            assert_allclose(&out, &reference.data, 1e-5, 1e-4, "bt_into");
        });
    }

    #[test]
    fn pv_accum_matches_naive_and_accumulates() {
        for_each_case("pv", 10, |rng| {
            let (bc, d) = (usize_in(rng, 1, 11), usize_in(rng, 1, 9));
            let p = rng.normal_vec(bc, 1.0);
            let v = rng.normal_vec(bc * d, 1.0);
            let init = rng.normal_vec(d, 1.0);
            let mut acc = init.clone();
            pv_accum(&p, &v, d, &mut acc);
            for c in 0..d {
                let naive: f32 =
                    init[c] + (0..bc).map(|cc| p[cc] * v[cc * d + c]).sum::<f32>();
                assert!((acc[c] - naive).abs() < 1e-4, "c={c}: {} vs {naive}", acc[c]);
            }
        });
    }

    #[test]
    fn pv_accum_skips_zero_weight_groups() {
        // All-zero P must leave the accumulator untouched (dropout path).
        let p = vec![0.0f32; 8];
        let v = vec![1.0f32; 8 * 4];
        let mut acc = vec![2.5f32; 4];
        pv_accum(&p, &v, 4, &mut acc);
        assert_eq!(acc, vec![2.5; 4]);
    }
}
