//! One-point calibration of the roofline model against the paper's own
//! published measurements (Appendix E, A100-40GB, batch 16 × 8 heads × d 64,
//! fp16, no dropout/mask: Tables 18/19/21; FMHA from Table 7).
//!
//! For each method we keep ONE scalar per pass:
//!     scale = paper_ms(N=1024) / raw_model_ms(N=1024)
//! so the model *equals* the paper at the anchor and extrapolates purely by
//! algorithmic structure everywhere else. Scales are derived on the A100 and
//! reused on other devices (they encode kernel quality, not hardware).

use super::baselines::Method;
use super::cost::Cost;
use super::device::GpuSpec;
use super::roofline::{BenchConfig, Pass, Roofline};

const ANCHOR_N: u64 = 1024;

/// Paper anchor runtimes in ms at N=1024 (Tables 18 and 19).
pub fn paper_anchor_ms(m: Method, pass: Pass) -> f64 {
    let (fwd, bwd) = match m {
        Method::PyTorch => (1.27, 2.44),
        Method::Megatron => (1.33, 2.59),
        Method::Reformer => (9.74, 16.12),
        Method::LocalAttention => (1.90, 3.60),
        Method::Linformer => (0.50, 0.80),
        Method::Smyrf => (5.69, 9.42),
        Method::LSFormer => (3.31, 7.40),
        Method::BlockSparseOpenAI => (2.16, 2.91),
        Method::Longformer => (1.56, 1.85),
        Method::BigBird => (1.48, 1.69),
        Method::FlashAttention => (0.68, 1.62),
        Method::BlockSparseFlash => (0.65, 0.38),
        // Table 7 (N=512, batch 64, 16 heads, mask+dropout):
        // anchored separately in `runtime_scale`.
        Method::ApexFmha => (1.14, 1.81),
    };
    match pass {
        Pass::Fwd => fwd,
        Pass::Bwd => bwd,
        Pass::FwdBwd => fwd + bwd,
    }
}

/// Paper anchor memory (MB) at N=1024 (Table 21).
pub fn paper_anchor_mem_mb(m: Method) -> f64 {
    match m {
        Method::PyTorch | Method::Megatron | Method::ApexFmha => 1184.0,
        Method::Reformer => 3016.0,
        Method::LocalAttention => 592.0,
        Method::Linformer => 287.0,
        Method::Smyrf => 1737.0,
        Method::LSFormer => 796.0,
        Method::BlockSparseOpenAI => 408.0,
        Method::Longformer => 277.0,
        Method::BigBird => 294.0,
        Method::FlashAttention | Method::BlockSparseFlash => 209.0,
    }
}

fn anchor_cfg(m: Method) -> (BenchConfig, u64) {
    match m {
        // FMHA was measured at BERT-large shape with mask+dropout (Table 7).
        Method::ApexFmha => (
            BenchConfig { batch: 64, heads: 16, dropout: true, masked: true, ..Default::default() },
            512,
        ),
        _ => (BenchConfig::default(), ANCHOR_N),
    }
}

fn raw_pass_ms(m: Method, pass: Pass, spec: &GpuSpec, cfg: &BenchConfig, n: u64) -> f64 {
    let rl = Roofline::new(spec.clone());
    let c: Cost = match pass {
        Pass::Fwd => m.fwd_cost(n, cfg.d, cfg.dropout, cfg.masked, spec),
        Pass::Bwd => m.bwd_cost(n, cfg.d, cfg.dropout, cfg.masked, spec),
        Pass::FwdBwd => m
            .fwd_cost(n, cfg.d, cfg.dropout, cfg.masked, spec)
            .add(m.bwd_cost(n, cfg.d, cfg.dropout, cfg.masked, spec)),
    };
    rl.raw_time(&c, cfg) * 1e3
}

/// paper / raw at the anchor point — the per-(method, pass) scale.
pub fn runtime_scale(m: Method, pass: Pass, _rl: &Roofline) -> f64 {
    let spec = GpuSpec::a100_40gb();
    // FMHA was only ever measured in Table 7 *next to* FlashAttention at
    // the BERT config — so anchor it by RATIO to the calibrated flash
    // model at that exact point. This keeps Table 7's flash-vs-FMHA
    // comparison meaningful even though the two tables use different
    // benchmark configs.
    if m == Method::ApexFmha {
        if let Pass::FwdBwd = pass {
            let f = runtime_scale(m, Pass::Fwd, _rl);
            let b = runtime_scale(m, Pass::Bwd, _rl);
            let raw_f = {
                let (cfg, n) = anchor_cfg(m);
                raw_pass_ms(m, Pass::Fwd, &spec, &cfg, n)
            };
            let raw_b = {
                let (cfg, n) = anchor_cfg(m);
                raw_pass_ms(m, Pass::Bwd, &spec, &cfg, n)
            };
            return (f * raw_f + b * raw_b) / (raw_f + raw_b);
        }
        let (cfg, n) = anchor_cfg(m);
        // Paper Table 7 at N=512: flash fwd 0.81 / FMHA 1.14; bwd 2.00 / 1.81.
        let (paper_flash, paper_fmha) = match pass {
            Pass::Fwd => (0.81, 1.14),
            _ => (2.00, 1.81),
        };
        let flash_scale = runtime_scale(Method::FlashAttention, pass, _rl);
        let flash_model = raw_pass_ms(Method::FlashAttention, pass, &spec, &cfg, n) * flash_scale;
        let target = flash_model * paper_fmha / paper_flash;
        return target / raw_pass_ms(m, pass, &spec, &cfg, n);
    }
    match pass {
        Pass::FwdBwd => {
            // Calibrate fwd and bwd independently; FwdBwd is their sum, so
            // use the blended scale implied by the anchor sums.
            let (cfg, n) = anchor_cfg(m);
            let raw = raw_pass_ms(m, Pass::Fwd, &spec, &cfg, n)
                + raw_pass_ms(m, Pass::Bwd, &spec, &cfg, n);
            paper_anchor_ms(m, Pass::FwdBwd) / raw
        }
        p => {
            let (cfg, n) = anchor_cfg(m);
            paper_anchor_ms(m, p) / raw_pass_ms(m, p, &spec, &cfg, n)
        }
    }
}

/// paper / raw memory scale at the anchor.
pub fn memory_scale(m: Method, _rl: &Roofline) -> f64 {
    let cfg = BenchConfig::default();
    let raw_mb = m.mem_elems(ANCHOR_N, cfg.d) as f64 * cfg.bytes_per_elem * cfg.bh() as f64 / 1e6;
    paper_anchor_mem_mb(m) / raw_mb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_are_order_one() {
        // A structural model that needed a 100x fudge would be wrong; all
        // calibration scales must be within [0.1, 10].
        let rl = Roofline::a100();
        for m in super::super::baselines::SWEEP_METHODS {
            for pass in [Pass::Fwd, Pass::Bwd] {
                let s = runtime_scale(*m, pass, &rl);
                assert!((0.05..20.0).contains(&s), "{} {:?}: scale {s}", m.name(), pass);
            }
            let ms = memory_scale(*m, &rl);
            assert!((0.1..10.0).contains(&ms), "{}: mem scale {ms}", m.name());
        }
    }

    #[test]
    fn anchors_consistent() {
        assert!(paper_anchor_ms(Method::FlashAttention, Pass::FwdBwd) > 2.0);
        assert!(paper_anchor_mem_mb(Method::FlashAttention) < paper_anchor_mem_mb(Method::PyTorch));
    }
}
