//! Published GPU specs used by the roofline model (paper §2.1 and App. E.5).

#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM capacity in bytes.
    pub hbm_bytes: u64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// On-chip SRAM per SM, bytes (the paper's M).
    pub sram_bytes_per_sm: usize,
    pub n_sm: usize,
    /// Peak fp16/bf16 tensor-core throughput, FLOP/s.
    pub peak_flops_fp16: f64,
    /// Peak fp32 throughput, FLOP/s.
    pub peak_flops_fp32: f64,
    /// Per-kernel launch overhead, seconds.
    pub launch_overhead: f64,
    /// Achievable fraction of peak bandwidth for attention-shaped access.
    pub bw_efficiency: f64,
    /// Achievable fraction of peak FLOPs for attention-shaped matmuls.
    pub flop_efficiency: f64,
}

impl GpuSpec {
    /// A100-SXM4-40GB: 1.555 TB/s, 192 KB SRAM/SM, 108 SMs, 312 TFLOPs fp16.
    pub fn a100_40gb() -> GpuSpec {
        GpuSpec {
            name: "A100-40GB",
            hbm_bytes: 40 * (1 << 30),
            hbm_bw: 1.555e12,
            sram_bytes_per_sm: 192 * 1024,
            n_sm: 108,
            peak_flops_fp16: 312e12,
            peak_flops_fp32: 19.5e12,
            launch_overhead: 5e-6,
            bw_efficiency: 0.65,
            flop_efficiency: 0.45,
        }
    }

    /// A100-SXM4-80GB: 2.0 TB/s variant.
    pub fn a100_80gb() -> GpuSpec {
        GpuSpec {
            name: "A100-80GB",
            hbm_bytes: 80 * (1 << 30),
            hbm_bw: 2.0e12,
            ..GpuSpec::a100_40gb()
        }
    }

    /// RTX 3090: 936 GB/s, 128 KB SRAM/SM, 82 SMs, 71 TFLOPs fp16 (dense).
    pub fn rtx3090() -> GpuSpec {
        GpuSpec {
            name: "RTX3090",
            hbm_bytes: 24 * (1 << 30),
            hbm_bw: 936e9,
            sram_bytes_per_sm: 128 * 1024,
            n_sm: 82,
            peak_flops_fp16: 71e12,
            peak_flops_fp32: 35.6e12,
            launch_overhead: 5e-6,
            bw_efficiency: 0.65,
            flop_efficiency: 0.45,
        }
    }

    /// T4: 320 GB/s, 96 KB SRAM/SM (64 KB usable shared), 40 SMs, 65 TFLOPs.
    pub fn t4() -> GpuSpec {
        GpuSpec {
            name: "T4",
            hbm_bytes: 16 * (1 << 30),
            hbm_bw: 320e9,
            sram_bytes_per_sm: 64 * 1024,
            n_sm: 40,
            peak_flops_fp16: 65e12,
            peak_flops_fp32: 8.1e12,
            launch_overhead: 5e-6,
            bw_efficiency: 0.6,
            flop_efficiency: 0.4,
        }
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "a100" | "a100-40gb" => Some(GpuSpec::a100_40gb()),
            "a100-80gb" => Some(GpuSpec::a100_80gb()),
            "rtx3090" | "3090" => Some(GpuSpec::rtx3090()),
            "t4" => Some(GpuSpec::t4()),
            _ => None,
        }
    }

    /// The paper's M: on-chip memory per SM in f32 elements (fp16: x2).
    pub fn sram_floats(&self) -> usize {
        self.sram_bytes_per_sm / 4
    }

    /// Effective bandwidth/FLOP rates.
    pub fn eff_bw(&self) -> f64 {
        self.hbm_bw * self.bw_efficiency
    }

    pub fn eff_flops_fp16(&self) -> f64 {
        self.peak_flops_fp16 * self.flop_efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuSpec::by_name("a100").unwrap().name, "A100-40GB");
        assert_eq!(GpuSpec::by_name("T4").unwrap().name, "T4");
        assert!(GpuSpec::by_name("h100").is_none());
    }

    #[test]
    fn a100_sram_is_48k_floats() {
        // The paper's "M around 100KB" for fp16 => 48K f32 elements.
        assert_eq!(GpuSpec::a100_40gb().sram_floats(), 48 * 1024);
    }

    #[test]
    fn bandwidth_ordering_matches_paper_e5() {
        // App. E.5: speedups higher on 3090 than A100 (lower bw), and T4
        // lowest bw of all.
        let a = GpuSpec::a100_40gb();
        let r = GpuSpec::rtx3090();
        let t = GpuSpec::t4();
        assert!(a.hbm_bw > r.hbm_bw && r.hbm_bw > t.hbm_bw);
        assert!(t.sram_bytes_per_sm < a.sram_bytes_per_sm);
    }
}
